//! END-TO-END driver: the full three-layer stack on a real (synthetic-
//! corpus) workload.
//!
//!   Layer 2/1: `make artifacts` lowered the JAX LSTM LM (with the Pallas
//!              alternating-quantization kernel) to HLO text.
//!   Layer 3:   this binary generates the ptb-like corpus, drives a few
//!              hundred AOT train steps through PJRT with the paper's SGD
//!              schedule, logs the loss curve, then quantizes the trained
//!              weights with every method and reports the Table-1 panel
//!              plus serving-side numbers.
//!
//! Run: `cargo run --release --example train_lm -- [--steps N] [--epochs E]`
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::path::Path;

use amq::cli::Cli;
use amq::data::{Corpus, DatasetSpec};
use amq::exp::quant_tables;
use amq::model::lm::{PrecisionPolicy, RnnLm};
use amq::train::{LmTrainer, SgdSchedule};

fn main() -> anyhow::Result<()> {
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(std::env::args().skip(1)))?;
    let epochs = cli.get_usize("epochs", 6)?;
    let steps = cli.get_usize("steps", 60)?;
    let eval_steps = cli.get_usize("eval-steps", 20)?;
    let tag = cli.get_str("tag", "lstm_fp");
    let artifacts = Path::new("artifacts");

    // --- corpus --------------------------------------------------------------
    let spec = DatasetSpec::ptb_like().scaled(cli.get_usize("scale", 8)?, 5);
    let corpus = Corpus::generate(spec);
    println!(
        "corpus {}: {} train / {} valid / {} test tokens, vocab {}, unigram ppl {:.0}",
        corpus.spec.name,
        corpus.train.len(),
        corpus.valid.len(),
        corpus.test.len(),
        corpus.spec.vocab,
        corpus.unigram_perplexity()
    );

    // --- train through the AOT artifacts --------------------------------------
    let mut trainer = LmTrainer::load(artifacts, &tag)?;
    println!(
        "training {tag} ({} params tensors, {} steps/epoch x {epochs} epochs, paper schedule)…",
        trainer.manifest.params.len(),
        steps
    );
    let t0 = std::time::Instant::now();
    let schedule = SgdSchedule::new(cli.get_f64("lr", 20.0)?, 1.2, 1e-3, 80);
    let report = trainer.fit(
        &corpus.train,
        &corpus.valid,
        schedule,
        epochs,
        Some(steps),
        Some(eval_steps),
        |e, loss, val, lr| {
            println!("  epoch {e:>2}  train-nll {loss:.4}  val-ppw {val:>8.1}  lr {lr:>6.3}")
        },
    )?;
    let test_ppw = trainer.evaluate(&corpus.test, Some(eval_steps))?;
    println!(
        "trained {} steps in {:.1}s — best val ppw {:.1}, test ppw {:.1}",
        report.steps,
        t0.elapsed().as_secs_f64(),
        report.best_val_ppw,
        test_ppw
    );
    // Loss curve must actually go down (the E2E validation contract).
    let first = *report.epoch_losses.first().unwrap();
    let last = *report.epoch_losses.last().unwrap();
    anyhow::ensure!(last < first, "loss curve did not descend: {first:.3} → {last:.3}");

    // --- checkpoint + quantization panel --------------------------------------
    std::fs::create_dir_all("runs")?;
    let ckpt_path = Path::new("runs").join(format!("{tag}.amqt"));
    trainer.checkpoint().save(&ckpt_path)?;
    println!("checkpoint -> {}", ckpt_path.display());

    let config = trainer.manifest.lm_config();
    let (weights, source) =
        quant_tables::load_or_surrogate_weights(Some(&ckpt_path), &config, 0);
    anyhow::ensure!(source == "trained-checkpoint");
    let bits = [2usize, 3, 4];
    let eval_tokens = 2000.min(corpus.test.len());
    let (rows, fp_ppw) =
        quant_tables::table1_2(config.kind, &corpus, &config, &weights, &bits, eval_tokens);
    print!("{}", quant_tables::render(config.kind, &rows, fp_ppw, &bits, source));
    if let Err(e) = quant_tables::check_shape(&rows) {
        println!("!! shape check: {e}");
    }

    // --- serving panel ---------------------------------------------------------
    println!("\nserving the trained model (quantized 2/2 vs FP), 200 tokens:");
    for (name, policy) in [
        ("FP  ", PrecisionPolicy::full()),
        ("W2A2", PrecisionPolicy::quantized(2, 2)),
    ] {
        let lm = RnnLm::from_weights(config, &weights, policy);
        let t = std::time::Instant::now();
        let mut state = lm.zero_state();
        let mut tok = corpus.test[0];
        for _ in 0..200 {
            let logits = lm.step(tok, &mut state);
            tok = amq::model::math::argmax(&logits);
        }
        let dt = t.elapsed().as_secs_f64();
        println!(
            "  {name}: {:>7.1} tokens/s, {:>9} weight bytes",
            200.0 / dt,
            lm.bytes()
        );
    }
    println!("\nE2E OK");
    Ok(())
}
