//! Serving example: start the coordinator with a quantized model, hammer it
//! with concurrent clients over TCP, and print the latency/throughput
//! profile — the paper's §1 server scenario.
//!
//! Run: `cargo run --release --example serve_lm -- [--clients 8] [--requests 5] [--threads 0]`
//!
//! `--threads` sizes the execution engine's worker pool (1 = serial,
//! 0 = auto) — same knob as `amq serve --threads`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::cli::Cli;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Work};
use amq::server::tcp;
use amq::util::Summary;

fn main() -> anyhow::Result<()> {
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(std::env::args().skip(1)))?;
    let clients = cli.get_usize("clients", 8)?;
    let requests = cli.get_usize("requests", 5)?;
    let new_tokens = cli.get_usize("tokens", 12)?;
    let w_bits = cli.get_usize("w-bits", 2)?;
    let a_bits = cli.get_usize("a-bits", 2)?;
    let threads = cli.get_usize("threads", 0)?;

    // Trained checkpoint if available, else random weights (same code path).
    let config = LmConfig { kind: RnnKind::Lstm, vocab: 2000, hidden: 200, layers: 1 };
    let ckpt = std::path::Path::new("runs/lstm_fp.amqt");
    let policy = if w_bits > 0 {
        PrecisionPolicy::quantized(w_bits, a_bits)
    } else {
        PrecisionPolicy::full()
    };
    let model = if ckpt.exists() {
        let c = amq::data::checkpoint::Checkpoint::load(ckpt)?;
        let w = amq::train::trainer::weights_from_checkpoint(&c, &config)?;
        println!("serving trained checkpoint {} (W{w_bits}A{a_bits})", ckpt.display());
        RnnLm::from_weights(config, &w, policy)
    } else {
        println!("serving randomly initialized model (run train_lm for a trained one)");
        RnnLm::random(config, 7, policy)
    };
    println!("model bytes: {}", model.bytes());

    let exec_cfg = amq::exec::ExecConfig::with_threads(threads);
    let server = InferenceServer::new(
        Arc::new(model),
        BatcherConfig { exec: exec_cfg, ..Default::default() },
    );
    println!("exec threads: {}", server.exec().threads());
    let latency = server.latency.clone();
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    std::thread::spawn(move || server.run(work_rx));
    let (addr_tx, addr_rx) = mpsc::channel();
    let wt = work_tx.clone();
    let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flag = shutdown.clone();
    std::thread::spawn(move || {
        let _ = tcp::serve("127.0.0.1:0", wt, flag, move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv()?;
    println!("listening on {addr}, {clients} clients x {requests} requests x {new_tokens} tokens");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut lat = Summary::new();
                for r in 0..requests {
                    let t = Instant::now();
                    let mut conn = TcpStream::connect(addr).unwrap();
                    let prime = (c * 31 + r * 7 + 1) % 2000;
                    writeln!(conn, "GEN {c} {new_tokens} {prime}").unwrap();
                    let mut line = String::new();
                    BufReader::new(conn).read_line(&mut line).unwrap();
                    assert!(line.starts_with("OK GEN "), "{line}");
                    lat.add(t.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut all = Summary::new();
    for h in handles {
        let mut s = h.join().unwrap();
        for p in [0.0, 50.0, 100.0] {
            let _ = s.percentile(p); // consume
        }
        all.add(s.mean());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_tokens = (clients * requests * new_tokens) as f64;
    println!(
        "done in {wall:.2}s: {:.0} tokens/s aggregate, mean client latency {:.1} ms",
        total_tokens / wall,
        all.mean()
    );
    println!("{}", latency.snapshot().report("server-side"));
    shutdown.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = work_tx.send(Work::Shutdown);
    Ok(())
}
