//! Figures 1–3 as text: the optimal 2-bit partition (Fig. 1), the BST over
//! the composite codes (Fig. 2), and the packed binary layout the GEMV
//! kernel consumes (Fig. 3, right).
//!
//! Run: `cargo run --release --example quant_levels`

use amq::quant::{alternating, bst};
use amq::util::Rng;

fn main() {
    // Quantize a sample vector to get real coefficients.
    let w = Rng::new(1).normal_vec(512, 0.5);
    let q = alternating::quantize(&w, 2, 2);
    let (a1, a2) = (q.alphas[0], q.alphas[1]);
    println!("alternating 2-bit on 512 gaussians -> alpha1 = {a1:.4}, alpha2 = {a2:.4}\n");

    // Fig. 1: codes and partition boundaries.
    let codes = bst::enumerate_codes(&q.alphas);
    let mids = bst::midpoints(&codes);
    println!("Fig. 1 — the four composite codes and the optimal boundaries:");
    for (i, c) in codes.iter().enumerate() {
        let b1 = if c.pattern & 1 != 0 { "+1" } else { "-1" };
        let b2 = if c.pattern & 2 != 0 { "+1" } else { "-1" };
        println!("  code {i}: {:+.4}   (b1={b1}, b2={b2})", c.value);
        if i < mids.len() {
            println!("      boundary: {:+.4}", mids[i]);
        }
    }

    // Fig. 2: the BST descent.
    println!("\nFig. 2 — binary search tree (w compared against each node):");
    println!("                 [{:+.4}]", mids[1]);
    println!("                /        \\");
    println!("        [{:+.4}]          [{:+.4}]", mids[0], mids[2]);
    println!("        /      \\          /      \\");
    println!(
        "  {:+.3}    {:+.3}    {:+.3}    {:+.3}",
        codes[0].value, codes[1].value, codes[2].value, codes[3].value
    );

    // Demonstrate k comparisons per entry.
    for sample in [-1.0f32, -0.3, 0.2, 2.0] {
        let idx = bst::assign_one(sample, &mids);
        println!("  w = {sample:+.2} -> code {idx} ({:+.4})", codes[idx].value);
    }

    // Fig. 3: the packed layout.
    println!("\nFig. 3 (right) — bit-packed planes fed to XNOR/popcount:");
    for (i, plane) in q.planes.iter().enumerate().take(2) {
        let word = plane.words()[0];
        println!("  b{} (first 64 of 512 entries): {:064b}", i + 1, word);
    }
    println!(
        "\n  dot(b1, b2) via popcount identity: {}  (n - 2*popcount(xor))",
        q.planes[0].dot_i32(&q.planes[1])
    );
}
