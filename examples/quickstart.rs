//! Quickstart: the paper's method in five minutes.
//!
//! 1. Quantize a weight vector with every method from §2 and compare errors.
//! 2. Quantize a matrix row-by-row, run the XNOR/popcount GEMV, and check it
//!    against the dense product.
//! 3. Show the memory/compute savings the abstract claims.
//!
//! Run: `cargo run --release --example quickstart`

use amq::kernels::{binary, cost, dense};
use amq::quant::{self, Method, RowQuantized};
use amq::util::Rng;

fn main() {
    // --- 1. Vector quantization, all methods --------------------------------
    let mut rng = Rng::new(42);
    let w = rng.laplace_vec(4096, 0.1); // trained-weight-like statistics
    println!("Quantizing a 4096-dim weight vector (Laplace, scale 0.1):\n");
    println!("{:<14}{:>12}{:>12}{:>12}", "method", "k=2 rMSE", "k=3 rMSE", "k=4 rMSE");
    for m in Method::table_order() {
        print!("{:<14}", m.name());
        for k in [2, 3, 4] {
            let q = quant::quantize(&w, k, m);
            print!("{:>12.4}", quant::relative_mse(&w, &q.dequantize()));
        }
        println!();
    }

    // --- 2. Quantized GEMV vs dense -----------------------------------------
    let (m, n) = (256, 512);
    let wm = rng.normal_vec(m * n, 0.1);
    let x = rng.normal_vec(n, 0.5);
    let wq = RowQuantized::quantize(&wm, m, n, 2, Method::Alternating { t: 2 });
    let mut y_q = vec![0.0; m];
    binary::online_gemv(&wq, &x, 2, &mut y_q); // quantizes x online (T=2)
    let mut y_fp = vec![0.0; m];
    dense::gemv(&wm, m, n, &x, &mut y_fp);
    let err: f64 = {
        let num: f64 = y_q.iter().zip(&y_fp).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = y_fp.iter().map(|&v| (v as f64).powi(2)).sum();
        num / den
    };
    println!("\n2-bit XNOR/popcount GEMV ({m}x{n}) vs dense: output rMSE {err:.4}");

    // --- 2b. Batch-first serving path ---------------------------------------
    // A batch of activations is quantized once into shared bit-planes and
    // multiplied in ONE sweep over the packed weight planes (Fig. 3 right) —
    // bit-identical to running the GEMV per vector.
    let batch = 8;
    let prep = binary::PreparedGemm::new(&wq);
    let xs: Vec<f32> = (0..batch).flat_map(|_| rng.normal_vec(n, 0.5)).collect();
    let mut y_batch = vec![0.0; batch * m];
    prep.online_gemm(&xs, batch, 2, &mut y_batch);
    let mut y_one = vec![0.0; m];
    prep.online_gemv(&xs[..n], 2, &mut y_one);
    assert_eq!(&y_batch[..m], &y_one[..], "batching is exact");
    println!("batched GEMM: {batch} activation vectors served by one weight-plane sweep (bit-exact)");

    // --- 3. The headline numbers --------------------------------------------
    println!("\nPaper's headline savings at W_h in R^(4096x1024):");
    for k in [2u64, 3] {
        println!(
            "  {k}-bit: ~{:.1}x memory saving, theoretical gamma {:.1}x",
            cost::memory_saving(4096, 1024, k),
            cost::theoretical_speedup(4096, 1024, k, k),
        );
    }
    println!("\nNext: `cargo run --release --example train_lm` (end-to-end training)");
}
