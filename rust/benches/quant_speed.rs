//! Bench: the cost of quantization itself (§3's 2Tk²n + 2(T+1)kn op count
//! and Table 6's "Quant" column): alternating-quantization throughput
//! across n and k, compared across methods, plus the BST assignment in
//! isolation.
//!
//! Run: `cargo bench --bench quant_speed`

use amq::kernels::cost;
use amq::quant::{self, bst, Method};
use amq::util::timer::{bench_fn, black_box};
use amq::util::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[1024] } else { &[1024, 4096, 16384] };
    let samples = if quick { 5 } else { 11 };

    println!("Online quantization cost (alternating, T=2) vs vector length:");
    for &n in sizes {
        let w = Rng::new(n as u64).normal_vec(n, 0.5);
        for k in [1usize, 2, 3, 4] {
            let r = bench_fn(&format!("alt n={n} k={k}"), samples, || {
                black_box(quant::alternating::quantize(&w, k, 2));
            });
            let c = cost::quantization_cost(n as u64, k as u64, 2);
            let ops = c.binary_ops as f64 / 32.0 + c.nonbinary_ops as f64;
            println!(
                "  n={n:>6} k={k}: {:>9.1} µs  ({:.2} model-ops/ns)",
                r.median_ns / 1e3,
                ops / r.median_ns
            );
        }
    }

    println!("\nMethod comparison at n=4096, k=2 (time to quantize):");
    let w = Rng::new(7).laplace_vec(4096, 0.1);
    for m in [
        Method::Uniform,
        Method::Balanced,
        Method::Greedy,
        Method::Refined,
        Method::Alternating { t: 2 },
    ] {
        let r = bench_fn(m.name(), samples, || {
            black_box(quant::quantize(&w, 2, m));
        });
        let q = quant::quantize(&w, 2, m);
        let e = quant::relative_mse(&w, &q.dequantize());
        println!("  {:<12} {:>9.1} µs  rMSE {:.4}", m.name(), r.median_ns / 1e3, e);
    }

    println!("\nBST code assignment alone (Algorithm 1), n=16384:");
    let w = Rng::new(8).normal_vec(16384, 0.5);
    for k in [2usize, 3, 4] {
        let alphas: Vec<f32> = (0..k).map(|i| 0.5f32 / (1 << i) as f32).collect();
        let r = bench_fn(&format!("bst k={k}"), samples, || {
            black_box(bst::assign(&w, &alphas));
        });
        println!(
            "  k={k}: {:>9.1} µs  ({:.1} ns/entry, {k} comparisons each)",
            r.median_ns / 1e3,
            r.median_ns / 16384.0
        );
    }
    eprintln!("ok");
}
