//! Bench: Tables 1–2 — approximation quality (relative MSE + testing PPW)
//! of Uniform / Balanced / Greedy / Refined / Alternating on LSTM and GRU
//! weights, plus the T-convergence ablation behind the paper's "two cycles
//! suffice" claim (§3).
//!
//! Run: `cargo bench --bench quant_error`
//! Uses the trained checkpoint from `runs/` when present (produced by
//! `cargo run --release --example train_lm`), else the Laplace surrogate.

use amq::exp::quant_tables;
use amq::quant::alternating;
use amq::util::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (scale, eval_tokens) = if quick { (64, 800) } else { (8, 4000) };
    print!("{}", quant_tables::run_default(scale, 5, eval_tokens, std::path::Path::new("runs")));

    // Ablation: error vs number of alternating cycles (T) — the paper sets
    // T = 2; the trace shows why.
    println!("Ablation — relative error vs alternating cycles (k=2, laplace 64K):");
    let w = Rng::new(2024).laplace_vec(65536, 0.1);
    let den: f64 = w.iter().map(|&x| (x as f64).powi(2)).sum();
    let trace = alternating::error_trace(&w, 2, 6);
    for (t, e) in trace.iter().enumerate() {
        let marker = if t == 2 { "  <- paper setting" } else { "" };
        println!("  T={t}: rMSE {:.5}{marker}", e / den);
    }
    // On heavy-tailed (Laplace) data T=2 captures ~3/4 of the achievable
    // gain; the residual tail past T=2 must stay small relative to init.
    let gain_after_2 = (trace[2] - trace[6]) / trace[0];
    assert!(gain_after_2 < 0.05, "T=2 should be near-converged (tail {gain_after_2:.3})");
    eprintln!("ok");
}
