//! Bench: Table 6 (Appendix A) — binary XNOR/popcount GEMV vs f32 GEMV at
//! the paper's exact shapes (4096×1024 hidden product, 42000×1024 Text8
//! softmax), with the online-quantization share broken out, plus the §4
//! cost model comparison.
//!
//! Run: `cargo bench --bench binary_gemv` (full shapes; takes a minute).

use amq::exp::{costmodel, kernel_tables, table6};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let shapes: &[(usize, usize)] = if quick {
        &[(1024, 1024)]
    } else {
        &[(4096, 1024), (42000, 1024)]
    };
    let samples = if quick { 7 } else { 15 };
    eprintln!("benchmarking binary GEMV at {shapes:?} …");
    let rows = table6(shapes, samples);
    print!("{}", kernel_tables::render_table6(&rows));
    print!("{}", costmodel(shapes, &rows));

    // Self-check: quantized must beat FP at every shape (the paper's
    // headline 2-bit ≈ 6×, 3-bit ≈ 3× on the larger shape).
    for r in rows.iter().filter(|r| r.bits.is_some()) {
        assert!(
            r.accel > 1.0,
            "no acceleration at {}x{} k={:?}",
            r.m,
            r.n,
            r.bits
        );
    }
    eprintln!("ok");
}
