//! Bench: Table 6 (Appendix A) — binary XNOR/popcount GEMV vs f32 GEMV at
//! the paper's exact shapes (4096×1024 hidden product, 42000×1024 Text8
//! softmax), with the online-quantization share broken out, plus the §4
//! cost model comparison — and the batched-GEMM sweep over
//! B ∈ {1, 4, 16, 64} behind the batch-first serving API (Fig. 3 right).
//!
//! Run: `cargo bench --bench binary_gemv` (full shapes; takes a minute).

use amq::exp::{costmodel, gemm_batch_sweep, kernel_tables, render_batch_sweep, table6};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let shapes: &[(usize, usize)] = if quick {
        &[(1024, 1024)]
    } else {
        &[(4096, 1024), (42000, 1024)]
    };
    let samples = if quick { 7 } else { 15 };
    eprintln!("benchmarking binary GEMV at {shapes:?} …");
    let rows = table6(shapes, samples);
    print!("{}", kernel_tables::render_table6(&rows));
    print!("{}", costmodel(shapes, &rows));

    // Batched sweep: one sweep over the packed weight planes serves all B
    // columns, so per-vector cost must fall as B grows.
    let sweep_shapes: &[(usize, usize)] = if quick { &[(1024, 1024)] } else { &[(4096, 1024)] };
    let batches: &[usize] = &[1, 4, 16, 64];
    let sweep = gemm_batch_sweep(sweep_shapes, batches, 2, samples.min(9));
    print!("{}", render_batch_sweep(&sweep));

    // Self-check: quantized must beat FP at every shape (the paper's
    // headline 2-bit ≈ 6×, 3-bit ≈ 3× on the larger shape).
    for r in rows.iter().filter(|r| r.bits.is_some()) {
        assert!(
            r.accel > 1.0,
            "no acceleration at {}x{} k={:?}",
            r.m,
            r.n,
            r.bits
        );
    }
    // Self-check: batching must improve per-vector throughput.
    let b1 = sweep.iter().find(|r| r.batch == 1).unwrap();
    let b16 = sweep.iter().find(|r| r.batch == 16).unwrap();
    assert!(
        b16.vecs_per_sec > b1.vecs_per_sec,
        "batched GEMM not faster per vector: B=16 {:.0}/s vs B=1 {:.0}/s",
        b16.vecs_per_sec,
        b1.vecs_per_sec
    );
    eprintln!("ok");
}
