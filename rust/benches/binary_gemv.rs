//! Bench: Table 6 (Appendix A) — binary XNOR/popcount GEMV vs f32 GEMV at
//! the paper's exact shapes (4096×1024 hidden product, 42000×1024 Text8
//! softmax), with the online-quantization share broken out, plus the §4
//! cost model comparison — the batched-GEMM sweep over B ∈ {1, 4, 16, 64}
//! behind the batch-first serving API (Fig. 3 right), the worker-pool
//! thread-scaling sweep of the row-sharded GEMM (`exec` engine), the
//! kernel-backend sweep (portable scalar vs every runtime-detected SIMD
//! backend, incl. AVX-512's two arms — bit-identical outputs, wall time
//! only), the fused-vs-pairwise sweep of the count primitive at both
//! plane-length regimes (16 words = the serving shape, 128 words =
//! Harley–Seal), a measured **stream-bandwidth roof** (memcpy + triad)
//! every shape's effective GB/s is reported against, and the
//! **cache-tiled vs untiled** sweep at the large-vocab shape.
//!
//! Run: `cargo bench --bench binary_gemv [-- --quick] [--json PATH]`
//!
//! The final stdout line is a machine-readable JSON summary containing the
//! batch sweep, the thread-scaling curve, the backend sweep (with per-shape
//! `gbps` + `roof_fraction`), the bandwidth roof, the tiled sweep, the
//! fused-block ratios, and the active kernel + detected CPU features;
//! `--json PATH` additionally writes it to a file (CI records it as
//! `BENCH_binary_gemv.json`) so perf trajectories can be tracked across
//! PRs.

use amq::exp::{
    costmodel, fused_vs_pairwise_sweep, gemm_backend_sweep, gemm_batch_sweep, gemm_thread_sweep,
    kernel_tables, render_backend_sweep, render_batch_sweep, render_fused_sweep, render_roof,
    render_scalar_floor, render_thread_sweep, render_tiled_sweep, scalar_fp_floor, stream_roof,
    table6, tiled_vs_untiled_sweep,
};
use amq::kernels::{backend, Kernel};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let shapes: &[(usize, usize)] = if quick {
        &[(1024, 1024)]
    } else {
        &[(4096, 1024), (42000, 1024)]
    };
    let samples = if quick { 7 } else { 15 };
    eprintln!(
        "benchmarking binary GEMV at {shapes:?} … (kernel={}, cpu features: {})",
        backend::describe(backend::active()),
        backend::cpu_features().join(",")
    );
    let rows = table6(shapes, samples);
    print!("{}", kernel_tables::render_table6(&rows));
    print!("{}", costmodel(shapes, &rows));

    // Batched sweep: one sweep over the packed weight planes serves all B
    // columns, so per-vector cost must fall as B grows.
    let sweep_shapes: &[(usize, usize)] = if quick { &[(1024, 1024)] } else { &[(4096, 1024)] };
    let batches: &[usize] = &[1, 4, 16, 64];
    let sweep = gemm_batch_sweep(sweep_shapes, batches, 2, samples.min(9));
    print!("{}", render_batch_sweep(&sweep));

    // Thread-scaling sweep: the same B=16 GEMM row-sharded across worker
    // pools of growing size (bit-identical output, wall time only).
    let threads: &[usize] = &[1, 2, 4];
    let tsweep = gemm_thread_sweep(sweep_shapes, 16, 2, threads, samples.min(9));
    print!("{}", render_thread_sweep(&tsweep));

    // Stream-bandwidth roof: a memcpy + triad probe over buffers far past
    // L2. Every shape's effective GB/s (packed bytes touched / time) is
    // reported as a fraction of this roof — the honest ceiling for a
    // memory-bound kernel, and the context for the tiled-vs-untiled gate.
    let roof = stream_roof(samples.min(5), quick);
    print!("{}", render_roof(&roof));

    // Kernel-backend sweep: the same W2A2 B=16 GEMM forced onto every
    // backend this host can run (scalar always; AVX2/AVX-512/NEON when
    // detected). Two regimes: the serving shape (short planes — 1024 cols
    // = 16 words, the SIMD LUT loop) and a long-plane shape (8192 cols =
    // 128 words per plane) that engages the Harley–Seal main loop, where
    // the SIMD margin over scalar `popcnt` is structural.
    let hs_shape: (usize, usize) = (256, 8192);
    let backend_shapes: Vec<(usize, usize)> = {
        let mut v = sweep_shapes.to_vec();
        v.push(hs_shape);
        v
    };
    let ksweep = gemm_backend_sweep(&backend_shapes, 16, 2, samples.min(9), roof.roof_gbps);
    print!("{}", render_backend_sweep(&ksweep));

    // Cache-tiled vs untiled sweep at the large-vocab shape (the shape
    // whose B=64 activation planes overflow L2): the same GEMM run with
    // column tiling disabled (one tile), auto (detected/overridden L2),
    // and a deliberately tiny budget — byte-identical outputs asserted
    // inside the sweep, wall time + predicted traffic advantage reported.
    let (tile_m, tile_n) = *shapes.last().unwrap();
    let tiled = tiled_vs_untiled_sweep(tile_m, tile_n, 2, 64, samples.min(9), roof.roof_gbps);
    print!("{}", render_tiled_sweep(&tiled));

    // Fused-vs-pairwise sweep of the count primitive itself, at the
    // serving plane length (16 words) and the Harley–Seal regime (128
    // words): the same integer counts computed as one fused block call vs
    // one 1×1×1 call per plane pair — this PR's headline ratio, tracked
    // across PRs via the JSON together with the micro-model's prediction.
    let fsweep = fused_vs_pairwise_sweep(&[16, 128], 4, 2, samples.min(9));
    print!("{}", render_fused_sweep(&fsweep));

    // Scalar absolute-speed floor (the ROADMAP item open since the fused
    // kernel refactor dropped scalar's const-generic specialization):
    // forced-scalar W2A2 GEMV vs dense f32 at the long-plane shape. Hard
    // gate — scalar is the universal fallback, so losing to FP would
    // silently erase the paper's headline win on scalar-only hosts.
    let floor = scalar_fp_floor(hs_shape.0, hs_shape.1, 2, samples.min(9));
    print!("{}", render_scalar_floor(&floor));

    // Self-check (the scalar floor gate): the portable scalar backend must
    // beat dense f32 at W2A2 on long planes, prequantized kernel vs kernel.
    assert!(
        floor.kernel_ratio > 1.0,
        "scalar W2A2 GEMV slower than dense f32 at {}x{}: {:.2}x",
        floor.m,
        floor.n,
        floor.kernel_ratio
    );

    // Self-check: quantized must beat FP at every shape (the paper's
    // headline 2-bit ≈ 6×, 3-bit ≈ 3× on the larger shape).
    for r in rows.iter().filter(|r| r.bits.is_some()) {
        assert!(
            r.accel > 1.0,
            "no acceleration at {}x{} k={:?}",
            r.m,
            r.n,
            r.bits
        );
    }
    // Self-check: batching must improve per-vector throughput.
    let b1 = sweep.iter().find(|r| r.batch == 1).unwrap();
    let b16 = sweep.iter().find(|r| r.batch == 16).unwrap();
    assert!(
        b16.vecs_per_sec > b1.vecs_per_sec,
        "batched GEMM not faster per vector: B=16 {:.0}/s vs B=1 {:.0}/s",
        b16.vecs_per_sec,
        b1.vecs_per_sec
    );
    // Self-check (the CI smoke gate): on a multi-core machine the threaded
    // B=16 GEMM must not be slower than serial.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let best = tsweep
        .iter()
        .filter(|r| r.threads > 1)
        .map(|r| r.speedup)
        .fold(f64::NAN, f64::max);
    if cores >= 2 {
        assert!(
            best > 1.0,
            "threaded B=16 GEMM slower than serial: best speedup {best:.2}x on {cores} cores"
        );
    } else {
        eprintln!("note: single-core machine — skipping the thread-scaling assertion");
    }
    // Self-check (the CI smoke gate): when a SIMD backend was detected,
    // the auto-selected backend must beat forced scalar at W2A2 B=16 at
    // **both** regimes — the Harley–Seal long-plane shape, where its
    // margin over scalar `popcnt` is structural, and the short-plane
    // serving shape (1024 cols = 16 words per plane), where the fused
    // block kernel pays its per-chain reduction once per row instead of
    // once per plane-pair pass. The serving-shape gate used to be
    // report-only (the pairwise decomposition hovered around 1×); the
    // fused primitive makes it a strict win, so it is asserted like the
    // long-plane gate. Guarded: asserted only when the feature exists, so
    // the bench stays green on scalar-only hosts.
    let detected = Kernel::detect();
    if detected != Kernel::Scalar {
        for &(m, n) in &backend_shapes {
            let simd = ksweep
                .iter()
                .find(|r| r.m == m && r.n == n && r.backend == detected.name())
                .expect("detected backend in sweep");
            let regime = if (m, n) == hs_shape { "long planes" } else { "serving shape" };
            assert!(
                simd.speedup_vs_scalar > 1.0,
                "{} backend slower than scalar at {}x{} B=16 ({regime}): {:.2}x",
                detected,
                m,
                n,
                simd.speedup_vs_scalar
            );
            eprintln!(
                "note: {} vs scalar at {}x{} B=16 ({regime}): {:.2}x",
                detected, m, n, simd.speedup_vs_scalar
            );
        }
        // The primitive-level sweep must agree: fused beats pairwise at
        // the serving plane length on the detected SIMD backend.
        let fshort = fsweep
            .iter()
            .find(|r| r.words == 16 && r.backend == detected.name())
            .expect("detected backend in fused sweep");
        assert!(
            fshort.speedup > 1.0,
            "fused block kernel slower than pairwise passes at 16 words: {:.2}x",
            fshort.speedup
        );
    } else {
        eprintln!("note: no SIMD backend detected — skipping the backend-speedup assertions");
    }

    // Self-check (the tiling gate): at the large-vocab shape the
    // auto-tiled GEMM must not lose to the untiled one. The work is
    // identical when the auto tile covers the whole batch, so a small
    // tolerance absorbs timer noise; when the batch overflows L2 the tiled
    // walk should win outright.
    let untiled = tiled.iter().find(|r| r.config == "untiled").expect("untiled row");
    let auto = tiled.iter().find(|r| r.config == "auto").expect("auto row");
    assert!(
        auto.total_ms <= untiled.total_ms * 1.08,
        "auto-tiled GEMM slower than untiled at {}x{} B=64: {:.3} ms vs {:.3} ms",
        tile_m,
        tile_n,
        auto.total_ms,
        untiled.total_ms
    );
    eprintln!(
        "note: tiled vs untiled at {}x{} B=64: {:.2}x (tile_cols={}, predicted {:.2}x)",
        tile_m, tile_n, auto.speedup_vs_untiled, auto.tile_cols, auto.predicted
    );

    // Self-check (the AVX-512 gate): when both 256-bit and 512-bit
    // backends exist, AVX-512 must not lose to AVX2 at the long-plane
    // W2A2 B=16 shape. Both may sit at the memory roof, so the gate is
    // "not slower" with a 5% noise allowance rather than a strict win.
    if Kernel::Avx512.is_available() && Kernel::Avx2.is_available() {
        let row = |name: &str| {
            ksweep
                .iter()
                .find(|r| r.m == hs_shape.0 && r.n == hs_shape.1 && r.backend == name)
                .expect("backend row at the long-plane shape")
        };
        let (a512, a2) = (row("avx512"), row("avx2"));
        assert!(
            a512.total_ms <= a2.total_ms * 1.05,
            "avx512 slower than avx2 at {}x{} B=16: {:.3} ms vs {:.3} ms (arm: {})",
            hs_shape.0,
            hs_shape.1,
            a512.total_ms,
            a2.total_ms,
            backend::avx512_arm().unwrap_or("?")
        );
        eprintln!(
            "note: avx512({}) vs avx2 at {}x{} B=16: {:.2}x",
            backend::avx512_arm().unwrap_or("?"),
            hs_shape.0,
            hs_shape.1,
            a2.total_ms / a512.total_ms
        );
    } else {
        eprintln!("note: avx512+avx2 not both available — skipping the AVX-512-vs-AVX2 gate");
    }

    // Machine-readable summary (batch sweep + thread scaling + backends +
    // bandwidth roof + tiling).
    let mut json = format!(
        "{{\"bench\":\"binary_gemv\",\"kernel\":\"{}\",\"cpu_features\":[{}],\"roof\":{{\"memcpy_gbps\":{:.2},\"triad_gbps\":{:.2},\"roof_gbps\":{:.2},\"buffer_bytes\":{}}},\"batch_sweep\":[",
        backend::describe(backend::active()),
        backend::cpu_features()
            .iter()
            .map(|f| format!("\"{f}\""))
            .collect::<Vec<_>>()
            .join(","),
        roof.memcpy_gbps,
        roof.triad_gbps,
        roof.roof_gbps,
        roof.buffer_bytes
    );
    for (i, r) in sweep.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"m\":{},\"n\":{},\"k\":{},\"batch\":{},\"total_ms\":{:.4},\"vecs_per_sec\":{:.1}}}",
            r.m, r.n, r.k, r.batch, r.total_ms, r.vecs_per_sec
        ));
    }
    json.push_str("],\"thread_scaling\":[");
    for (i, r) in tsweep.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"m\":{},\"n\":{},\"k\":{},\"batch\":{},\"threads\":{},\"total_ms\":{:.4},\"speedup\":{:.3}}}",
            r.m, r.n, r.k, r.batch, r.threads, r.total_ms, r.speedup
        ));
    }
    json.push_str("],\"backend_sweep\":[");
    for (i, r) in ksweep.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"m\":{},\"n\":{},\"k\":{},\"batch\":{},\"backend\":\"{}\",\"total_ms\":{:.4},\"speedup_vs_scalar\":{:.3},\"gbps\":{:.2},\"roof_fraction\":{:.3}}}",
            r.m, r.n, r.k, r.batch, r.backend, r.total_ms, r.speedup_vs_scalar, r.gbps,
            r.roof_fraction
        ));
    }
    json.push_str("],\"tiled\":[");
    for (i, r) in tiled.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"m\":{},\"n\":{},\"k\":{},\"batch\":{},\"config\":\"{}\",\"tile_cols\":{},\"total_ms\":{:.4},\"speedup_vs_untiled\":{:.3},\"gbps\":{:.2},\"roof_fraction\":{:.3},\"predicted\":{:.3}}}",
            r.m, r.n, r.k, r.batch, r.config, r.tile_cols, r.total_ms, r.speedup_vs_untiled,
            r.gbps, r.roof_fraction, r.predicted
        ));
    }
    json.push_str("],\"fused_block\":[");
    for (i, r) in fsweep.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"words\":{},\"k\":{},\"batch\":{},\"backend\":\"{}\",\"fused_ms\":{:.4},\"pairwise_ms\":{:.4},\"speedup\":{:.3},\"predicted\":{:.3}}}",
            r.words, r.k, r.batch, r.backend, r.fused_ms, r.pairwise_ms, r.speedup, r.predicted
        ));
    }
    json.push_str(&format!(
        "],\"scalar_fp_floor\":{{\"m\":{},\"n\":{},\"k\":{},\"fp_ms\":{:.4},\"scalar_ms\":{:.4},\"online_ms\":{:.4},\"kernel_ratio\":{:.3},\"online_ratio\":{:.3}}}}}",
        floor.m,
        floor.n,
        floor.k,
        floor.fp_ms,
        floor.scalar_ms,
        floor.online_ms,
        floor.kernel_ratio,
        floor.online_ratio
    ));
    if let Some(path) = json_path {
        std::fs::write(&path, &json).expect("write json summary");
        eprintln!("json summary written to {path}");
    }
    println!("{json}");
    eprintln!("ok");
}
