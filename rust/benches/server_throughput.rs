//! Bench: the serving coordinator — tokens/sec and per-request latency as a
//! function of batch size, full precision vs 2/2 and 3/3 quantized models.
//! This regenerates the paper's *motivating* claim (§1, abstract): quantized
//! inference serves more concurrent requests per machine at lower latency —
//! and, with the batch-first forward API, that the dynamic batcher's
//! timestep groups execute as true batched GEMMs whose throughput grows
//! with B (one sweep over the weight planes per batch, Fig. 3 right).
//!
//! Run: `cargo bench --bench server_throughput [--quick] [--json PATH]`
//!
//! The final stdout line is a machine-readable JSON summary (tokens/sec per
//! model per batch size); `--json PATH` additionally writes it to a file so
//! perf trajectories can be tracked across PRs.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Request};

struct Sample {
    model: &'static str,
    batch: usize,
    tokens_per_sec: f64,
    batch_ms: f64,
    bytes: usize,
}

fn run_batch(model: Arc<RnnLm>, batch: usize, new_tokens: usize) -> (f64, f64) {
    let mut server = InferenceServer::new(
        model,
        BatcherConfig { max_batch: batch, ..Default::default() },
    );
    let mut rxs = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..batch {
        let (tx, rx) = mpsc::channel();
        reqs.push(Request {
            session: i as u64,
            max_new: new_tokens,
            prime: vec![(i * 13 + 1) % 500],
            respond: tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let t = Instant::now();
    server.process_batch(reqs);
    let elapsed = t.elapsed().as_secs_f64();
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), new_tokens);
    }
    let tokens = (batch * new_tokens) as f64;
    (tokens / elapsed, elapsed * 1e3)
}

fn json_summary(config: &LmConfig, new_tokens: usize, samples: &[Sample]) -> String {
    let mut s = format!(
        "{{\"bench\":\"server_throughput\",\"kind\":\"{}\",\"vocab\":{},\"hidden\":{},\"new_tokens\":{},\"results\":[",
        config.kind.name(),
        config.vocab,
        config.hidden,
        new_tokens
    );
    for (i, r) in samples.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"{}\",\"batch\":{},\"tokens_per_sec\":{:.1},\"batch_ms\":{:.3},\"weight_bytes\":{}}}",
            r.model, r.batch, r.tokens_per_sec, r.batch_ms, r.bytes
        ));
    }
    s.push_str("]}");
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let config = LmConfig {
        kind: RnnKind::Lstm,
        vocab: if quick { 500 } else { 2000 },
        hidden: if quick { 128 } else { 256 },
        layers: 1,
    };
    let new_tokens = if quick { 8 } else { 16 };
    println!(
        "Serving throughput, LSTM vocab={} hidden={} ({} new tokens/request):",
        config.vocab, config.hidden, new_tokens
    );
    println!(
        "{:<10} {:>10} {:>14} {:>12} {:>10}",
        "model", "batch", "tokens/s", "batch-ms", "bytes"
    );
    let variants: Vec<(&'static str, PrecisionPolicy)> = vec![
        ("FP", PrecisionPolicy::full()),
        ("W2A2", PrecisionPolicy::quantized(2, 2)),
        ("W3A3", PrecisionPolicy::quantized(3, 3)),
    ];
    let batches: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let mut samples: Vec<Sample> = Vec::new();
    for (name, policy) in variants {
        let model = Arc::new(RnnLm::random(config, 99, policy));
        let bytes = model.bytes();
        for &b in batches {
            let (tps, ms) = run_batch(model.clone(), b, new_tokens);
            println!("{name:<10} {b:>10} {tps:>14.0} {ms:>12.2} {bytes:>10}");
            samples.push(Sample { model: name, batch: b, tokens_per_sec: tps, batch_ms: ms, bytes });
        }
    }

    let tps = |model: &str, batch: usize| {
        samples
            .iter()
            .find(|s| s.model == model && s.batch == batch)
            .map(|s| s.tokens_per_sec)
            .unwrap_or(0.0)
    };
    let max_b = *batches.last().unwrap();
    let speedup = tps("W2A2", max_b) / tps("FP", max_b);
    println!("\nW2A2 vs FP serving speedup at batch {max_b}: {speedup:.2}x");
    let batch_gain = tps("W2A2", 16) / tps("W2A2", 1);
    println!("W2A2 batching gain, B=16 vs B=1: {batch_gain:.2}x");

    let json = json_summary(&config, new_tokens, &samples);
    if let Some(path) = json_path {
        std::fs::write(&path, &json).expect("write json summary");
        eprintln!("json summary written to {path}");
    }
    println!("{json}");

    // Self-checks: quantized serving must beat FP, and the batched forward
    // must make B=16 strictly faster than B=1 for the 2-bit model (the
    // acceptance bar of the batch-first API).
    assert!(speedup > 1.0, "quantized serving must outperform FP");
    assert!(
        batch_gain > 1.0,
        "batched serving must outperform B=1: gain {batch_gain:.2}x"
    );
    eprintln!("ok");
}
