//! Bench: the serving coordinator — tokens/sec and per-request latency as a
//! function of batch size, full precision vs 2/2 and 3/3 quantized models.
//! This regenerates the paper's *motivating* claim (§1, abstract): quantized
//! inference serves more concurrent requests per machine at lower latency —
//! that the dynamic batcher's timestep groups execute as true batched GEMMs
//! whose throughput grows with B (one sweep over the weight planes per
//! batch, Fig. 3 right) — and, new, how the W2A2 B=16 workload scales when
//! the batched forward is row-sharded across the `exec` worker pool.
//!
//! Run: `cargo bench --bench server_throughput [-- --quick] [--json PATH]`
//!
//! Besides the batch/thread/decode sweeps, this bench has a **load
//! generator**: hundreds of simulated clients with staggered arrivals and
//! varied request lengths, driven against grouped vs continuous batching
//! (p50/p99 per-request latency + aggregate throughput), a **load-shed
//! burst** exercising admission control (`ERR BUSY`), and — on unix — the
//! same load over real TCP through the event-loop front end.
//!
//! The final stdout line is a machine-readable JSON summary (tokens/sec per
//! model per batch size, the thread-scaling curve, and the load-generator
//! results); `--json PATH` additionally writes it to a file (CI records it
//! as `BENCH_server_throughput.json`) so perf trajectories can be tracked
//! across PRs. Every quantized forward underneath goes through the fused
//! batch-block count primitive of `kernels::backend`.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amq::exec::{Exec, ExecConfig};
use amq::model::lm::{LmConfig, LmStepWorkspace, PrecisionPolicy, RnnKind, RnnLm};
use amq::model::math::argmax;
use amq::model::OutputBatch;
use amq::server::batcher::{BatcherConfig, InferenceServer, Reply, Request, Respond, Work};
use amq::util::Summary;

// The shared counting #[global_allocator] (thread-local counters — worker
// threads never pollute a serial measurement). Same bookkeeping as the
// zero-allocation test gate, so `allocs_per_step` / `bytes_per_step` in the
// JSON mean exactly what `rust/tests/workspace_parity.rs` asserts.
#[path = "../tests/support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::thread_alloc_counts;

struct Sample {
    model: &'static str,
    batch: usize,
    tokens_per_sec: f64,
    batch_ms: f64,
    bytes: usize,
}

struct ThreadSample {
    threads: usize,
    tokens_per_sec: f64,
}

/// One row of the decode-latency comparison: the allocating
/// `step_batch_exec` vs the workspace `step_batch_into_exec`, serial
/// engine, greedy decode.
struct DecodeSample {
    batch: usize,
    alloc_us_per_step: f64,
    into_us_per_step: f64,
    speedup: f64,
    alloc_path_allocs_per_step: f64,
    alloc_path_bytes_per_step: f64,
    allocs_per_step: f64,
    bytes_per_step: f64,
}

fn run_batch(
    model: Arc<RnnLm>,
    batch: usize,
    new_tokens: usize,
    exec: ExecConfig,
) -> (f64, f64) {
    let mut server = InferenceServer::new(
        model,
        BatcherConfig { max_batch: batch, exec, ..Default::default() },
    );
    let mut rxs = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..batch {
        let (tx, rx) = mpsc::channel();
        reqs.push(Request {
            session: i as u64,
            max_new: new_tokens,
            prime: vec![(i * 13 + 1) % 500],
            model: None,
            respond: Respond::Channel(tx),
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let t = Instant::now();
    server.process_batch(reqs);
    let elapsed = t.elapsed().as_secs_f64();
    for rx in rxs {
        match rx.recv().unwrap() {
            Reply::Gen(r) => assert_eq!(r.tokens.len(), new_tokens),
            other => panic!("expected Gen reply, got {other:?}"),
        }
    }
    let tokens = (batch * new_tokens) as f64;
    (tokens / elapsed, elapsed * 1e3)
}

/// One load-generator run: `clients` threads with staggered arrivals and
/// varied request lengths against a live batcher; per-request wall latency
/// (client-observed: queueing + decode) and aggregate throughput.
struct LoadGenSample {
    mode: &'static str,
    clients: usize,
    threads: usize,
    p50_ms: f64,
    p99_ms: f64,
    tokens_per_sec: f64,
}

/// The request length for client `i`: spread over `2 ..= 2*new_tokens+1`
/// so grouped batches are padded to their slowest member while continuous
/// batching backfills freed slots — the effect the p99 gate measures.
fn want_tokens(i: usize, new_tokens: usize) -> usize {
    2 + (i * 7) % (2 * new_tokens)
}

fn run_load(
    model: Arc<RnnLm>,
    mode: &'static str,
    continuous: bool,
    clients: usize,
    new_tokens: usize,
    stagger: Duration,
    threads: usize,
) -> LoadGenSample {
    let server = InferenceServer::new(
        model,
        BatcherConfig {
            max_batch: 8,
            continuous,
            max_slots: 8,
            // The latency comparison must not shed: depth > all clients.
            queue_depth: clients + 1,
            exec: ExecConfig::with_threads(threads),
            ..Default::default()
        },
    );
    let (work_tx, work_rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(work_rx));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let tx = work_tx.clone();
            std::thread::spawn(move || {
                std::thread::sleep(stagger * i as u32);
                let want = want_tokens(i, new_tokens);
                let (rtx, rrx) = mpsc::channel();
                let t = Instant::now();
                tx.send(Work::Gen(Request {
                    session: i as u64,
                    max_new: want,
                    prime: vec![(i * 13 + 1) % 500],
                    model: None,
                    respond: Respond::Channel(rtx),
                    enqueued: Instant::now(),
                }))
                .unwrap();
                match rrx.recv().unwrap() {
                    Reply::Gen(r) => {
                        assert_eq!(r.tokens.len(), want);
                        (t.elapsed().as_secs_f64() * 1e3, want)
                    }
                    other => panic!("latency run must not shed: {other:?}"),
                }
            })
        })
        .collect();
    let mut lat = Summary::new();
    let mut tokens = 0usize;
    for h in handles {
        let (ms, n) = h.join().unwrap();
        lat.add(ms);
        tokens += n;
    }
    let wall = t0.elapsed().as_secs_f64();
    work_tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    LoadGenSample {
        mode,
        clients,
        threads,
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        tokens_per_sec: tokens as f64 / wall,
    }
}

/// Admission-control burst: the whole burst is enqueued before the batcher
/// starts, so the outcome is deterministic — `max_slots` join, `queue_depth`
/// queue, the rest shed with `ERR BUSY`. Returns (served, shed).
fn run_burst(model: Arc<RnnLm>, clients: usize, new_tokens: usize) -> (usize, usize) {
    let server = InferenceServer::new(
        model,
        BatcherConfig {
            max_batch: 2,
            continuous: true,
            max_slots: 2,
            queue_depth: 4,
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (work_tx, work_rx) = mpsc::channel();
    let mut rxs = Vec::new();
    for i in 0..clients {
        let (rtx, rrx) = mpsc::channel();
        work_tx
            .send(Work::Gen(Request {
                session: i as u64,
                max_new: new_tokens,
                prime: vec![(i * 13 + 1) % 500],
                model: None,
                respond: Respond::Channel(rtx),
                enqueued: Instant::now(),
            }))
            .unwrap();
        rxs.push(rrx);
    }
    let batcher = std::thread::spawn(move || server.run(work_rx));
    let (mut served, mut shed) = (0usize, 0usize);
    for rx in rxs {
        match rx.recv().unwrap() {
            Reply::Gen(r) => {
                assert_eq!(r.tokens.len(), new_tokens);
                served += 1;
            }
            Reply::Busy { queued, depth } => {
                assert_eq!((queued, depth), (4, 4));
                shed += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    work_tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    (served, shed)
}

/// The staggered load over real TCP through the event-loop front end:
/// every client is a real socket speaking the wire protocol, multiplexed
/// onto two loop threads.
#[cfg(unix)]
fn run_eventloop_tcp(
    model: Arc<RnnLm>,
    clients: usize,
    new_tokens: usize,
    stagger: Duration,
    threads: usize,
) -> LoadGenSample {
    use std::io::{BufRead, BufReader, Write};

    let server = InferenceServer::new(
        model,
        BatcherConfig {
            max_batch: 8,
            continuous: true,
            max_slots: 8,
            queue_depth: clients + 1,
            exec: ExecConfig::with_threads(threads),
            ..Default::default()
        },
    );
    let (work_tx, work_rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(work_rx));
    let srv = amq::server::eventloop::serve(
        "127.0.0.1:0",
        work_tx.clone(),
        amq::server::eventloop::EventLoopConfig { loops: 2, ..Default::default() },
    )
    .expect("event-loop bind");
    let addr = srv.addr;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            std::thread::spawn(move || {
                std::thread::sleep(stagger * i as u32);
                let want = want_tokens(i, new_tokens);
                let t = Instant::now();
                // Bounded socket ops: a wedged server fails the bench fast
                // instead of hanging 120 client threads forever.
                let timeout = Duration::from_secs(60);
                let mut conn = std::net::TcpStream::connect_timeout(&addr, timeout).unwrap();
                conn.set_read_timeout(Some(timeout)).unwrap();
                conn.set_write_timeout(Some(timeout)).unwrap();
                writeln!(conn, "GEN {i} {want} {}", (i * 13 + 1) % 500).unwrap();
                let mut line = String::new();
                BufReader::new(conn).read_line(&mut line).unwrap();
                assert!(line.starts_with("OK GEN "), "{line}");
                let got = line.trim_end().trim_start_matches("OK GEN ").split(',').count();
                assert_eq!(got, want);
                (t.elapsed().as_secs_f64() * 1e3, want)
            })
        })
        .collect();
    let mut lat = Summary::new();
    let mut tokens = 0usize;
    for h in handles {
        let (ms, n) = h.join().unwrap();
        lat.add(ms);
        tokens += n;
    }
    let wall = t0.elapsed().as_secs_f64();
    srv.shutdown();
    work_tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    LoadGenSample {
        mode: "event-loop",
        clients,
        threads,
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        tokens_per_sec: tokens as f64 / wall,
    }
}

fn json_summary(
    config: &LmConfig,
    new_tokens: usize,
    samples: &[Sample],
    scaling: &[ThreadSample],
    decode: &[DecodeSample],
    load: &[LoadGenSample],
    shed: (usize, usize, usize),
) -> String {
    let mut s = format!(
        "{{\"bench\":\"server_throughput\",\"kernel\":\"{}\",\"cpu_features\":[{}],\"kind\":\"{}\",\"vocab\":{},\"hidden\":{},\"new_tokens\":{},\"results\":[",
        amq::kernels::backend::describe(amq::kernels::backend::active()),
        amq::kernels::backend::cpu_features()
            .iter()
            .map(|f| format!("\"{f}\""))
            .collect::<Vec<_>>()
            .join(","),
        config.kind.name(),
        config.vocab,
        config.hidden,
        new_tokens
    );
    for (i, r) in samples.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"{}\",\"batch\":{},\"threads\":1,\"tokens_per_sec\":{:.1},\"batch_ms\":{:.3},\"weight_bytes\":{}}}",
            r.model, r.batch, r.tokens_per_sec, r.batch_ms, r.bytes
        ));
    }
    s.push_str("],\"thread_scaling\":[");
    for (i, r) in scaling.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"W2A2\",\"batch\":16,\"threads\":{},\"tokens_per_sec\":{:.1}}}",
            r.threads, r.tokens_per_sec
        ));
    }
    s.push_str("],\"decode_latency\":[");
    for (i, r) in decode.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"W2A2\",\"batch\":{},\"threads\":1,\"alloc_us_per_step\":{:.2},\"into_us_per_step\":{:.2},\"into_speedup\":{:.3},\"alloc_path_allocs_per_step\":{:.1},\"alloc_path_bytes_per_step\":{:.0},\"allocs_per_step\":{:.1},\"bytes_per_step\":{:.0}}}",
            r.batch,
            r.alloc_us_per_step,
            r.into_us_per_step,
            r.speedup,
            r.alloc_path_allocs_per_step,
            r.alloc_path_bytes_per_step,
            r.allocs_per_step,
            r.bytes_per_step
        ));
    }
    s.push_str("],\"load_gen\":[");
    for (i, r) in load.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"mode\":\"{}\",\"clients\":{},\"threads\":{},\"p50_ms\":{:.2},\"p99_ms\":{:.2},\"tokens_per_sec\":{:.1}}}",
            r.mode, r.clients, r.threads, r.p50_ms, r.p99_ms, r.tokens_per_sec
        ));
    }
    let (burst_clients, served, shed_n) = shed;
    s.push_str(&format!(
        "],\"load_shed\":{{\"clients\":{burst_clients},\"max_slots\":2,\"queue_depth\":4,\
         \"served\":{served},\"shed\":{shed_n}}}}}"
    ));
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let config = LmConfig {
        kind: RnnKind::Lstm,
        vocab: if quick { 500 } else { 2000 },
        hidden: if quick { 128 } else { 256 },
        layers: 1,
    };
    let new_tokens = if quick { 8 } else { 16 };
    println!(
        "Serving throughput, LSTM vocab={} hidden={} ({} new tokens/request):",
        config.vocab, config.hidden, new_tokens
    );
    println!(
        "{:<10} {:>10} {:>14} {:>12} {:>10}",
        "model", "batch", "tokens/s", "batch-ms", "bytes"
    );
    let variants: Vec<(&'static str, PrecisionPolicy)> = vec![
        ("FP", PrecisionPolicy::full()),
        ("W2A2", PrecisionPolicy::quantized(2, 2)),
        ("W3A3", PrecisionPolicy::quantized(3, 3)),
    ];
    let batches: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let mut samples: Vec<Sample> = Vec::new();
    let mut w2a2: Option<Arc<RnnLm>> = None;
    for (name, policy) in variants {
        let model = Arc::new(RnnLm::random(config, 99, policy));
        if name == "W2A2" {
            w2a2 = Some(model.clone());
        }
        let bytes = model.bytes();
        for &b in batches {
            // The batch sweep itself runs serial (threads = 1) so the B
            // scaling is measured in isolation from the worker pool.
            let (tps, ms) = run_batch(model.clone(), b, new_tokens, ExecConfig::serial());
            println!("{name:<10} {b:>10} {tps:>14.0} {ms:>12.2} {bytes:>10}");
            samples.push(Sample { model: name, batch: b, tokens_per_sec: tps, batch_ms: ms, bytes });
        }
    }

    let tps = |model: &str, batch: usize| {
        samples
            .iter()
            .find(|s| s.model == model && s.batch == batch)
            .map(|s| s.tokens_per_sec)
            .unwrap_or(0.0)
    };
    let max_b = *batches.last().unwrap();
    let speedup = tps("W2A2", max_b) / tps("FP", max_b);
    println!("\nW2A2 vs FP serving speedup at batch {max_b}: {speedup:.2}x");
    let batch_gain = tps("W2A2", 16) / tps("W2A2", 1);
    println!("W2A2 batching gain, B=16 vs B=1: {batch_gain:.2}x");

    // Thread-scaling: the W2A2 B=16 workload on worker pools of growing
    // size (the execution-engine acceptance curve). Each run generates the
    // bit-identical tokens — only wall time changes.
    let w2a2 = w2a2.expect("W2A2 model benchmarked above");
    println!("\nW2A2 thread scaling at B=16 (row-sharded batched forward):");
    println!("{:<10} {:>14} {:>12}", "threads", "tokens/s", "vs 1 thread");
    let mut scaling: Vec<ThreadSample> = Vec::new();
    for &t in &[1usize, 2, 4] {
        // Best of 3 runs to damp scheduler noise.
        let mut best = 0.0f64;
        for _ in 0..3 {
            let (tps, _) =
                run_batch(w2a2.clone(), 16, new_tokens, ExecConfig::with_threads(t));
            best = best.max(tps);
        }
        let base = scaling.first().map(|s| s.tokens_per_sec).unwrap_or(best);
        println!("{t:<10} {best:>14.0} {:>11.2}x", best / base);
        scaling.push(ThreadSample { threads: t, tokens_per_sec: best });
    }
    // Best over all pool sizes vs serial (same gate as binary_gemv: a
    // 2-core machine may lose at 4 threads to oversubscription while 2
    // threads genuinely wins).
    let thread_gain = scaling[1..]
        .iter()
        .map(|s| s.tokens_per_sec / scaling[0].tokens_per_sec)
        .fold(f64::NAN, f64::max);
    let gain4 = scaling.last().unwrap().tokens_per_sec / scaling[0].tokens_per_sec;
    println!("W2A2 threading gain at B=16: 4 threads {gain4:.2}x, best {thread_gain:.2}x");

    // Steady-state decode latency: one greedy-decode timestep on the serial
    // engine (B = 1 is the latency-critical serving shape), the allocating
    // step_batch_exec vs the workspace step_batch_into_exec, with heap
    // allocations per timestep counted on both paths. The into path must be
    // allocation-free once warm — the zero-allocation contract, gated here
    // as well as in rust/tests/workspace_parity.rs.
    let exec = Exec::serial();
    let steps = if quick { 64 } else { 192 };
    let reps = 5;
    let vocab = config.vocab;
    let mut decode: Vec<DecodeSample> = Vec::new();
    println!("\nW2A2 steady-state decode (serial engine, {steps} timesteps/run, best of {reps}):");
    println!(
        "{:<7} {:>15} {:>15} {:>9} {:>13} {:>13}",
        "batch", "alloc us/step", "into us/step", "speedup", "allocs/step", "bytes/step"
    );
    for &b in &[1usize, 16] {
        let seed_tokens: Vec<usize> = (0..b).map(|i| (i * 13 + 1) % vocab).collect();

        // Allocating path: fresh output + workspaces inside every step.
        let mut state = w2a2.zero_state_batch(b);
        let mut toks = seed_tokens.clone();
        for _ in 0..4 {
            let lg = w2a2.step_batch_exec(&toks, &mut state, &exec);
            for (i, t) in toks.iter_mut().enumerate() {
                *t = argmax(lg.row(i));
            }
        }
        let (a0, by0) = thread_alloc_counts();
        let mut alloc_us = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            for _ in 0..steps {
                let lg = w2a2.step_batch_exec(&toks, &mut state, &exec);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(lg.row(i));
                }
            }
            alloc_us = alloc_us.min(t0.elapsed().as_secs_f64() * 1e6 / steps as f64);
        }
        let (a1, by1) = thread_alloc_counts();
        let alloc_path_allocs = (a1 - a0) as f64 / (reps * steps) as f64;
        let alloc_path_bytes = (by1 - by0) as f64 / (reps * steps) as f64;

        // Workspace path: state, logits, and workspace reused across steps.
        let mut state = w2a2.zero_state_batch(b);
        let mut ws = LmStepWorkspace::new();
        let mut logits = OutputBatch::zeros(0, 0);
        let mut toks = seed_tokens.clone();
        for _ in 0..4 {
            w2a2.step_batch_into_exec(&toks, &mut state, &mut logits, &exec, &mut ws);
            for (i, t) in toks.iter_mut().enumerate() {
                *t = argmax(logits.row(i));
            }
        }
        let (a0, by0) = thread_alloc_counts();
        let mut into_us = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            for _ in 0..steps {
                w2a2.step_batch_into_exec(&toks, &mut state, &mut logits, &exec, &mut ws);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(logits.row(i));
                }
            }
            into_us = into_us.min(t0.elapsed().as_secs_f64() * 1e6 / steps as f64);
        }
        let (a1, by1) = thread_alloc_counts();
        assert_eq!(a1 - a0, 0, "warmed-up step_batch_into_exec timestep allocated (B={b})");
        let allocs = (a1 - a0) as f64 / (reps * steps) as f64;
        let bytes_ps = (by1 - by0) as f64 / (reps * steps) as f64;

        let speedup = alloc_us / into_us;
        println!(
            "{b:<7} {alloc_us:>15.2} {into_us:>15.2} {speedup:>8.2}x {allocs:>13.1} {bytes_ps:>13.0}"
        );
        decode.push(DecodeSample {
            batch: b,
            alloc_us_per_step: alloc_us,
            into_us_per_step: into_us,
            speedup,
            alloc_path_allocs_per_step: alloc_path_allocs,
            alloc_path_bytes_per_step: alloc_path_bytes,
            allocs_per_step: allocs,
            bytes_per_step: bytes_ps,
        });
    }
    let b1 = decode.iter().find(|d| d.batch == 1).expect("B=1 decode sample");
    println!(
        "W2A2 B=1 decode: into path {:.2}x vs allocating path \
         ({:.1} allocs/step eliminated)",
        b1.speedup, b1.alloc_path_allocs_per_step
    );

    // -----------------------------------------------------------------
    // Load generator: staggered arrivals, varied request lengths, grouped
    // vs continuous batching on the same model and thread count. Client
    // latency is measured end to end (queueing + decode).
    // -----------------------------------------------------------------
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let lg_clients = if quick { 64 } else { 256 };
    let lg_threads = cores.min(2);
    let stagger = Duration::from_micros(250);
    let run_mode = |mode: &'static str, continuous: bool| {
        // Best-of-2 (by p99) outside quick mode to damp scheduler noise.
        let reps = if quick { 1 } else { 2 };
        let mut best: Option<LoadGenSample> = None;
        for _ in 0..reps {
            let s = run_load(
                w2a2.clone(),
                mode,
                continuous,
                lg_clients,
                new_tokens,
                stagger,
                lg_threads,
            );
            if best.is_none() || s.p99_ms < best.as_ref().unwrap().p99_ms {
                best = Some(s);
            }
        }
        best.unwrap()
    };
    println!(
        "\nLoad generator: {lg_clients} clients, {}µs stagger, lengths 2..{}, {lg_threads} exec threads:",
        stagger.as_micros(),
        2 * new_tokens + 1
    );
    println!("{:<12} {:>10} {:>10} {:>14}", "mode", "p50-ms", "p99-ms", "tokens/s");
    let grouped = run_mode("grouped", false);
    let continuous = run_mode("continuous", true);
    for s in [&grouped, &continuous] {
        println!(
            "{:<12} {:>10.2} {:>10.2} {:>14.0}",
            s.mode, s.p50_ms, s.p99_ms, s.tokens_per_sec
        );
    }
    let (grouped_p99, continuous_p99) = (grouped.p99_ms, continuous.p99_ms);
    println!(
        "continuous vs grouped p99: {:.2}x ({:.2} ms vs {:.2} ms)",
        grouped_p99 / continuous_p99,
        continuous_p99,
        grouped_p99
    );

    // Admission-control burst: deterministic shed accounting.
    let burst_clients = 32;
    let (served, shed_n) = run_burst(w2a2.clone(), burst_clients, new_tokens);
    println!(
        "load shed: burst of {burst_clients} at max_slots=2 queue_depth=4 → served {served}, shed {shed_n} (ERR BUSY)"
    );

    let mut load = vec![grouped, continuous];
    #[cfg(unix)]
    {
        let ev_clients = if quick { 40 } else { 120 };
        let ev = run_eventloop_tcp(w2a2.clone(), ev_clients, new_tokens, stagger, lg_threads);
        println!(
            "event-loop TCP: {ev_clients} sockets → p50 {:.2} ms, p99 {:.2} ms, {:.0} tokens/s",
            ev.p50_ms, ev.p99_ms, ev.tokens_per_sec
        );
        load.push(ev);
    }

    let json = json_summary(
        &config,
        new_tokens,
        &samples,
        &scaling,
        &decode,
        &load,
        (burst_clients, served, shed_n),
    );
    if let Some(path) = json_path {
        std::fs::write(&path, &json).expect("write json summary");
        eprintln!("json summary written to {path}");
    }
    println!("{json}");

    // Self-checks: quantized serving must beat FP, the batched forward must
    // make B=16 strictly faster than B=1 for the 2-bit model, the
    // zero-allocation decode path must beat the allocating path at the
    // B=1 latency shape, and on a multi-core machine the worker pool must
    // not make serving slower.
    assert!(speedup > 1.0, "quantized serving must outperform FP");
    assert!(
        b1.speedup > 1.0,
        "workspace decode path slower than allocating path at B=1: {:.2}x",
        b1.speedup
    );
    assert!(
        batch_gain > 1.0,
        "batched serving must outperform B=1: gain {batch_gain:.2}x"
    );
    if cores >= 2 {
        assert!(
            thread_gain > 1.0,
            "threaded serving slower than serial: {thread_gain:.2}x on {cores} cores"
        );
    } else {
        eprintln!("note: single-core machine — skipping the thread-scaling assertion");
    }
    // Admission control: every burst client was answered, the overflow was
    // shed, and the accounting is the deterministic slots+queue split.
    assert_eq!(served + shed_n, burst_clients, "every burst client must get an answer");
    assert_eq!(served, 6, "pre-queued burst serves exactly max_slots + queue_depth");
    assert!(shed_n > 0, "burst must trigger load shedding");
    // The tentpole claim: with staggered arrivals and varied lengths,
    // continuous batching beats grouped batching at the tail — freed slots
    // backfill instead of idling until the slowest batch member finishes.
    if cores >= 2 {
        assert!(
            continuous_p99 < grouped_p99,
            "continuous batching must beat grouped on p99 under staggered load: \
             {continuous_p99:.2} ms vs {grouped_p99:.2} ms"
        );
    } else {
        eprintln!("note: single-core machine — skipping the continuous-p99 assertion");
    }
    eprintln!("ok");
}
