//! Bench: the serving coordinator — tokens/sec and per-request latency as a
//! function of batch size, full precision vs 2/2 and 3/3 quantized models.
//! This regenerates the paper's *motivating* claim (§1, abstract): quantized
//! inference serves more concurrent requests per machine at lower latency —
//! that the dynamic batcher's timestep groups execute as true batched GEMMs
//! whose throughput grows with B (one sweep over the weight planes per
//! batch, Fig. 3 right) — and, new, how the W2A2 B=16 workload scales when
//! the batched forward is row-sharded across the `exec` worker pool.
//!
//! Run: `cargo bench --bench server_throughput [-- --quick] [--json PATH]`
//!
//! The final stdout line is a machine-readable JSON summary (tokens/sec per
//! model per batch size, plus the thread-scaling curve); `--json PATH`
//! additionally writes it to a file (CI records it as
//! `BENCH_server_throughput.json`) so perf trajectories can be tracked
//! across PRs. Every quantized forward underneath goes through the fused
//! batch-block count primitive of `kernels::backend`.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::exec::ExecConfig;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Request};

struct Sample {
    model: &'static str,
    batch: usize,
    tokens_per_sec: f64,
    batch_ms: f64,
    bytes: usize,
}

struct ThreadSample {
    threads: usize,
    tokens_per_sec: f64,
}

fn run_batch(
    model: Arc<RnnLm>,
    batch: usize,
    new_tokens: usize,
    exec: ExecConfig,
) -> (f64, f64) {
    let mut server = InferenceServer::new(
        model,
        BatcherConfig { max_batch: batch, exec, ..Default::default() },
    );
    let mut rxs = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..batch {
        let (tx, rx) = mpsc::channel();
        reqs.push(Request {
            session: i as u64,
            max_new: new_tokens,
            prime: vec![(i * 13 + 1) % 500],
            respond: tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let t = Instant::now();
    server.process_batch(reqs);
    let elapsed = t.elapsed().as_secs_f64();
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), new_tokens);
    }
    let tokens = (batch * new_tokens) as f64;
    (tokens / elapsed, elapsed * 1e3)
}

fn json_summary(
    config: &LmConfig,
    new_tokens: usize,
    samples: &[Sample],
    scaling: &[ThreadSample],
) -> String {
    let mut s = format!(
        "{{\"bench\":\"server_throughput\",\"kernel\":\"{}\",\"cpu_features\":[{}],\"kind\":\"{}\",\"vocab\":{},\"hidden\":{},\"new_tokens\":{},\"results\":[",
        amq::kernels::backend::active(),
        amq::kernels::backend::cpu_features()
            .iter()
            .map(|f| format!("\"{f}\""))
            .collect::<Vec<_>>()
            .join(","),
        config.kind.name(),
        config.vocab,
        config.hidden,
        new_tokens
    );
    for (i, r) in samples.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"{}\",\"batch\":{},\"threads\":1,\"tokens_per_sec\":{:.1},\"batch_ms\":{:.3},\"weight_bytes\":{}}}",
            r.model, r.batch, r.tokens_per_sec, r.batch_ms, r.bytes
        ));
    }
    s.push_str("],\"thread_scaling\":[");
    for (i, r) in scaling.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"model\":\"W2A2\",\"batch\":16,\"threads\":{},\"tokens_per_sec\":{:.1}}}",
            r.threads, r.tokens_per_sec
        ));
    }
    s.push_str("]}");
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let config = LmConfig {
        kind: RnnKind::Lstm,
        vocab: if quick { 500 } else { 2000 },
        hidden: if quick { 128 } else { 256 },
        layers: 1,
    };
    let new_tokens = if quick { 8 } else { 16 };
    println!(
        "Serving throughput, LSTM vocab={} hidden={} ({} new tokens/request):",
        config.vocab, config.hidden, new_tokens
    );
    println!(
        "{:<10} {:>10} {:>14} {:>12} {:>10}",
        "model", "batch", "tokens/s", "batch-ms", "bytes"
    );
    let variants: Vec<(&'static str, PrecisionPolicy)> = vec![
        ("FP", PrecisionPolicy::full()),
        ("W2A2", PrecisionPolicy::quantized(2, 2)),
        ("W3A3", PrecisionPolicy::quantized(3, 3)),
    ];
    let batches: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let mut samples: Vec<Sample> = Vec::new();
    let mut w2a2: Option<Arc<RnnLm>> = None;
    for (name, policy) in variants {
        let model = Arc::new(RnnLm::random(config, 99, policy));
        if name == "W2A2" {
            w2a2 = Some(model.clone());
        }
        let bytes = model.bytes();
        for &b in batches {
            // The batch sweep itself runs serial (threads = 1) so the B
            // scaling is measured in isolation from the worker pool.
            let (tps, ms) = run_batch(model.clone(), b, new_tokens, ExecConfig::serial());
            println!("{name:<10} {b:>10} {tps:>14.0} {ms:>12.2} {bytes:>10}");
            samples.push(Sample { model: name, batch: b, tokens_per_sec: tps, batch_ms: ms, bytes });
        }
    }

    let tps = |model: &str, batch: usize| {
        samples
            .iter()
            .find(|s| s.model == model && s.batch == batch)
            .map(|s| s.tokens_per_sec)
            .unwrap_or(0.0)
    };
    let max_b = *batches.last().unwrap();
    let speedup = tps("W2A2", max_b) / tps("FP", max_b);
    println!("\nW2A2 vs FP serving speedup at batch {max_b}: {speedup:.2}x");
    let batch_gain = tps("W2A2", 16) / tps("W2A2", 1);
    println!("W2A2 batching gain, B=16 vs B=1: {batch_gain:.2}x");

    // Thread-scaling: the W2A2 B=16 workload on worker pools of growing
    // size (the execution-engine acceptance curve). Each run generates the
    // bit-identical tokens — only wall time changes.
    let w2a2 = w2a2.expect("W2A2 model benchmarked above");
    println!("\nW2A2 thread scaling at B=16 (row-sharded batched forward):");
    println!("{:<10} {:>14} {:>12}", "threads", "tokens/s", "vs 1 thread");
    let mut scaling: Vec<ThreadSample> = Vec::new();
    for &t in &[1usize, 2, 4] {
        // Best of 3 runs to damp scheduler noise.
        let mut best = 0.0f64;
        for _ in 0..3 {
            let (tps, _) =
                run_batch(w2a2.clone(), 16, new_tokens, ExecConfig::with_threads(t));
            best = best.max(tps);
        }
        let base = scaling.first().map(|s| s.tokens_per_sec).unwrap_or(best);
        println!("{t:<10} {best:>14.0} {:>11.2}x", best / base);
        scaling.push(ThreadSample { threads: t, tokens_per_sec: best });
    }
    // Best over all pool sizes vs serial (same gate as binary_gemv: a
    // 2-core machine may lose at 4 threads to oversubscription while 2
    // threads genuinely wins).
    let thread_gain = scaling[1..]
        .iter()
        .map(|s| s.tokens_per_sec / scaling[0].tokens_per_sec)
        .fold(f64::NAN, f64::max);
    let gain4 = scaling.last().unwrap().tokens_per_sec / scaling[0].tokens_per_sec;
    println!("W2A2 threading gain at B=16: 4 threads {gain4:.2}x, best {thread_gain:.2}x");

    let json = json_summary(&config, new_tokens, &samples, &scaling);
    if let Some(path) = json_path {
        std::fs::write(&path, &json).expect("write json summary");
        eprintln!("json summary written to {path}");
    }
    println!("{json}");

    // Self-checks: quantized serving must beat FP, the batched forward must
    // make B=16 strictly faster than B=1 for the 2-bit model, and on a
    // multi-core machine the worker pool must not make serving slower.
    assert!(speedup > 1.0, "quantized serving must outperform FP");
    assert!(
        batch_gain > 1.0,
        "batched serving must outperform B=1: gain {batch_gain:.2}x"
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 2 {
        assert!(
            thread_gain > 1.0,
            "threaded serving slower than serial: {thread_gain:.2}x on {cores} cores"
        );
    } else {
        eprintln!("note: single-core machine — skipping the thread-scaling assertion");
    }
    eprintln!("ok");
}
