//! Bench: the serving coordinator — tokens/sec and per-request latency as a
//! function of batch size, full precision vs 2/2 and 3/3 quantized models.
//! This regenerates the paper's *motivating* claim (§1, abstract): quantized
//! inference serves more concurrent requests per machine at lower latency.
//!
//! Run: `cargo bench --bench server_throughput`

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Request};

fn run_batch(model: Arc<RnnLm>, batch: usize, new_tokens: usize) -> (f64, f64) {
    let mut server = InferenceServer::new(
        model,
        BatcherConfig { max_batch: batch, ..Default::default() },
    );
    let mut rxs = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..batch {
        let (tx, rx) = mpsc::channel();
        reqs.push(Request {
            session: i as u64,
            max_new: new_tokens,
            prime: vec![(i * 13 + 1) % 500],
            respond: tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let t = Instant::now();
    server.process_batch(reqs);
    let elapsed = t.elapsed().as_secs_f64();
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), new_tokens);
    }
    let tokens = (batch * new_tokens) as f64;
    (tokens / elapsed, elapsed * 1e3)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let config = LmConfig {
        kind: RnnKind::Lstm,
        vocab: if quick { 500 } else { 2000 },
        hidden: if quick { 128 } else { 256 },
        layers: 1,
    };
    let new_tokens = if quick { 8 } else { 16 };
    println!(
        "Serving throughput, LSTM vocab={} hidden={} ({} new tokens/request):",
        config.vocab, config.hidden, new_tokens
    );
    println!(
        "{:<10} {:>10} {:>14} {:>12} {:>10}",
        "model", "batch", "tokens/s", "batch-ms", "bytes"
    );
    let variants: Vec<(&str, PrecisionPolicy)> = vec![
        ("FP", PrecisionPolicy::full()),
        ("W2A2", PrecisionPolicy::quantized(2, 2)),
        ("W3A3", PrecisionPolicy::quantized(3, 3)),
    ];
    let batches: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let mut fp_tps_at_max = 0.0;
    let mut q2_tps_at_max = 0.0;
    for (name, policy) in variants {
        let model = Arc::new(RnnLm::random(config, 99, policy));
        let bytes = model.bytes();
        for &b in batches {
            let (tps, ms) = run_batch(model.clone(), b, new_tokens);
            println!("{name:<10} {b:>10} {tps:>14.0} {ms:>12.2} {bytes:>10}");
            if b == *batches.last().unwrap() {
                if name == "FP" {
                    fp_tps_at_max = tps;
                }
                if name == "W2A2" {
                    q2_tps_at_max = tps;
                }
            }
        }
    }
    let speedup = q2_tps_at_max / fp_tps_at_max;
    println!("\nW2A2 vs FP serving speedup at max batch: {speedup:.2}x");
    assert!(speedup > 1.0, "quantized serving must outperform FP");
    eprintln!("ok");
}
