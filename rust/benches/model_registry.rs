//! Bench: the multi-tenant model registry and the `.amqz` packed format.
//!
//! Two measurements back the tentpole claims:
//!
//! 1. **Cold load** — bringing a model up from a published `.amqz` (one
//!    bulk read into an arena, no parse, no requantize) vs rebuilding it
//!    from weights through alternating minimization. The format exists to
//!    make this ≥ 5×; the gate asserts it.
//! 2. **Hot swap** — three published models behind one continuous batcher
//!    with a memory budget that fits only two, hammered by the staggered
//!    load generator with requests cycling `MODEL` names. Reports client
//!    p50/p99 and the LRU eviction count from `STATS`.
//!
//! Run: `cargo bench --bench model_registry [-- --quick] [--json PATH]`
//!
//! The final stdout line is a machine-readable JSON summary; `--json PATH`
//! additionally writes it to a file (CI records it as
//! `BENCH_model_registry.json`).

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use amq::data::amqz;
use amq::exec::{Exec, ExecConfig};
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Reply, Request, Respond, Work};
use amq::server::ModelRegistry;
use amq::util::Summary;

const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];

fn temp_amqz(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("amqz_bench_{}_{tag}.amqz", std::process::id()))
}

fn best_of_3(f: &dyn Fn() -> usize) -> f64 {
    (0..3)
        .map(|_| {
            let t = Instant::now();
            std::hint::black_box(f());
            t.elapsed().as_secs_f64() * 1e3
        })
        .fold(f64::INFINITY, f64::min)
}

fn stats_json(tx: &mpsc::Sender<Work>) -> String {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Work::Stats { text: false, respond: Respond::Channel(rtx) }).unwrap();
    match rrx.recv().unwrap() {
        Reply::Stats(s) => s,
        other => panic!("unexpected reply {other:?}"),
    }
}

fn json_u64(s: &str, key: &str) -> u64 {
    s.split(key)
        .nth(1)
        .and_then(|t| t.chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().ok())
        .unwrap_or_else(|| panic!("missing {key} in {s}"))
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1)).cloned();

    let config = LmConfig {
        kind: RnnKind::Gru,
        vocab: if quick { 600 } else { 1500 },
        hidden: if quick { 64 } else { 128 },
        layers: 1,
    };
    let policy = PrecisionPolicy::quantized(2, 2);

    // ---------------------------------------------------------- publish
    // Pay the quantization cost once per model, write the packed planes.
    let mut paths = Vec::new();
    let mut model_bytes = 0usize;
    let mut file_bytes = 0u64;
    let mut publish_ms = 0.0f64;
    for (i, name) in NAMES.iter().enumerate() {
        let t = Instant::now();
        let model = RnnLm::random(config, 100 + i as u64, policy);
        let path = temp_amqz(name);
        amqz::save(&path, &model.to_packed().expect("quantized model packs")).expect("publish");
        publish_ms = t.elapsed().as_secs_f64() * 1e3;
        model_bytes = model.bytes();
        file_bytes = std::fs::metadata(&path).expect("published file").len();
        paths.push(path);
    }
    println!(
        "Published {} GRU models (vocab={} hidden={} W2A2): {} bytes on disk, {} in memory, {:.1} ms each",
        NAMES.len(),
        config.vocab,
        config.hidden,
        file_bytes,
        model_bytes,
        publish_ms
    );

    // --------------------------------------------------------- cold load
    // The same model up two ways, best of 3 each: alternating-minimization
    // requantize from weights vs one bulk `.amqz` read.
    let requantize_ms = best_of_3(&|| RnnLm::random(config, 100, policy).bytes());
    let load_ms = best_of_3(&|| amqz::load_model(&paths[0]).expect("cold load").bytes());
    let cold_speedup = requantize_ms / load_ms;
    println!("\nCold start (best of 3):");
    println!("{:<24} {:>12}", "path", "ms");
    println!("{:<24} {:>12.2}", "requantize from weights", requantize_ms);
    println!("{:<24} {:>12.2}", ".amqz bulk load", load_ms);
    println!("cold-load speedup: {cold_speedup:.1}x");

    // ---------------------------------------------------------- hot swap
    // Budget fits two of the three models; the staggered load generator
    // cycles MODEL names so the registry must keep evicting and reloading
    // lanes mid-serve while every reply stays correct.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let clients = if quick { 48 } else { 144 };
    let threads = cores.min(2);
    let stagger = Duration::from_micros(250);
    let budget = model_bytes * 5 / 2;

    let mut registry = ModelRegistry::new(budget);
    for (name, path) in NAMES.iter().zip(&paths) {
        registry.register_path(name, path.clone()).expect("register");
    }
    registry.set_default(NAMES[0]).expect("default");
    let server = InferenceServer::with_registry(
        registry,
        BatcherConfig {
            max_batch: 4,
            continuous: true,
            max_slots: 4,
            queue_depth: clients + 1,
            exec: ExecConfig::with_threads(threads),
            ..Default::default()
        },
        Exec::new(ExecConfig::with_threads(threads)),
    );
    let (work_tx, work_rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(work_rx));

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let tx = work_tx.clone();
            std::thread::spawn(move || {
                std::thread::sleep(stagger * i as u32);
                let want = 2 + (i * 7) % 24;
                let (rtx, rrx) = mpsc::channel();
                let t = Instant::now();
                tx.send(Work::Gen(Request {
                    session: i as u64,
                    max_new: want,
                    prime: vec![(i * 13 + 1) % 600],
                    model: Some(NAMES[i % NAMES.len()].to_string()),
                    respond: Respond::Channel(rtx),
                    enqueued: Instant::now(),
                }))
                .unwrap();
                match rrx.recv().unwrap() {
                    Reply::Gen(r) => {
                        assert_eq!(r.tokens.len(), want);
                        (t.elapsed().as_secs_f64() * 1e3, want)
                    }
                    other => panic!("hot-swap load must not fail: {other:?}"),
                }
            })
        })
        .collect();
    let mut lat = Summary::new();
    let mut tokens = 0usize;
    for h in handles {
        let (ms, n) = h.join().unwrap();
        lat.add(ms);
        tokens += n;
    }
    let wall = t0.elapsed().as_secs_f64();

    // A quiescent round-robin pass: with every lane idle the LRU loop can
    // always make room, so cycling three models under a two-model budget
    // must evict deterministically even if the concurrent phase ran wide.
    for (i, name) in NAMES.iter().enumerate() {
        let (rtx, rrx) = mpsc::channel();
        work_tx
            .send(Work::Gen(Request {
                session: 10_000 + i as u64,
                max_new: 4,
                prime: vec![1 + i],
                model: Some(name.to_string()),
                respond: Respond::Channel(rtx),
                enqueued: Instant::now(),
            }))
            .unwrap();
        match rrx.recv().unwrap() {
            Reply::Gen(_) => {}
            other => panic!("round-robin pass must serve: {other:?}"),
        }
    }

    let stats = stats_json(&work_tx);
    work_tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    for p in &paths {
        std::fs::remove_file(p).ok();
    }

    let evictions = json_u64(&stats, "\"model_evictions\":");
    let (p50, p99) = (lat.percentile(50.0), lat.percentile(99.0));
    let tps = tokens as f64 / wall;
    println!(
        "\nHot swap: {clients} clients cycling {} models, budget {budget} bytes ({} exec threads):",
        NAMES.len(),
        threads
    );
    println!("{:<12} {:>10} {:>10} {:>14} {:>12}", "", "p50-ms", "p99-ms", "tokens/s", "evictions");
    println!("{:<12} {p50:>10.2} {p99:>10.2} {tps:>14.0} {evictions:>12}", "hot-swap");

    let json = format!(
        "{{\"bench\":\"model_registry\",\"kernel\":\"{}\",\"kind\":\"{}\",\"vocab\":{},\"hidden\":{},\
         \"models\":{},\"publish\":{{\"file_bytes\":{file_bytes},\"model_bytes\":{model_bytes},\"publish_ms\":{publish_ms:.2}}},\
         \"cold\":{{\"requantize_ms\":{requantize_ms:.2},\"load_ms\":{load_ms:.2},\"speedup\":{cold_speedup:.2}}},\
         \"hot_swap\":{{\"clients\":{clients},\"threads\":{threads},\"budget_bytes\":{budget},\
         \"p50_ms\":{p50:.2},\"p99_ms\":{p99:.2},\"tokens_per_sec\":{tps:.1},\"model_evictions\":{evictions}}}}}",
        amq::kernels::backend::active(),
        config.kind.name(),
        config.vocab,
        config.hidden,
        NAMES.len(),
    );
    if let Some(path) = json_path {
        std::fs::write(&path, &json).expect("write json summary");
        eprintln!("json summary written to {path}");
    }
    println!("{json}");

    // Gates: the format must deliver its reason to exist, and the registry
    // must actually have swapped under the two-model budget.
    assert!(
        cold_speedup >= 5.0,
        "cold load must be >= 5x faster than requantize: {load_ms:.2} ms vs {requantize_ms:.2} ms"
    );
    assert!(evictions >= 1, "cycling 3 models under a 2-model budget must evict: {stats}");
    eprintln!("ok");
}
