//! Deterministic Zipf–Mandelbrot bigram-chain corpus generator.
//!
//! Natural-language token streams have (a) a heavy-tailed unigram
//! distribution and (b) strong local (bigram) structure. Both properties
//! are what the paper's LM experiments actually exercise: (a) shapes the
//! softmax/embedding weight statistics that quantization must approximate,
//! (b) gives the model something learnable so PPW improves with training.
//!
//! Generator: unigram probabilities `p(i) ∝ (i + q)^{-s}` (Zipf–Mandelbrot);
//! each token `c` owns a small deterministic successor set `S(c)`; the next
//! token is drawn from `S(c)` with probability `λ` and from the unigram
//! distribution otherwise.

use crate::util::Rng;

/// Specification of a synthetic dataset (paper-matching presets below).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub vocab: usize,
    pub train_tokens: usize,
    pub valid_tokens: usize,
    pub test_tokens: usize,
    pub seed: u64,
    /// Zipf exponent `s` (≈1 for natural text).
    pub zipf_s: f64,
    /// Mandelbrot shift `q`.
    pub zipf_q: f64,
    /// Bigram mixture weight λ.
    pub bigram_lambda: f64,
    /// Successor-set size per token.
    pub successors: usize,
}

impl DatasetSpec {
    /// PTB-sized: 929K/73K/82K tokens, 10K vocab (Marcus et al. 1993 split).
    pub fn ptb_like() -> Self {
        DatasetSpec {
            name: "ptb-like".into(),
            vocab: 10_000,
            train_tokens: 929_000,
            valid_tokens: 73_000,
            test_tokens: 82_000,
            seed: 1993,
            zipf_s: 1.05,
            zipf_q: 2.7,
            bigram_lambda: 0.55,
            successors: 4,
        }
    }

    /// WikiText-2-sized: 2088K/217K/245K tokens, 33K vocab.
    pub fn wt2_like() -> Self {
        DatasetSpec {
            name: "wt2-like".into(),
            vocab: 33_000,
            train_tokens: 2_088_000,
            valid_tokens: 217_000,
            test_tokens: 245_000,
            seed: 2017,
            zipf_s: 1.05,
            zipf_q: 2.7,
            bigram_lambda: 0.55,
            successors: 4,
        }
    }

    /// Text8-sized: 15.3M/848K/855K tokens, 42K vocab.
    pub fn text8_like() -> Self {
        DatasetSpec {
            name: "text8-like".into(),
            vocab: 42_000,
            train_tokens: 15_300_000,
            valid_tokens: 848_000,
            test_tokens: 855_000,
            seed: 2014,
            zipf_s: 1.05,
            zipf_q: 2.7,
            bigram_lambda: 0.55,
            successors: 4,
        }
    }

    /// Force an exact vocabulary size (e.g. to match a fixed artifact
    /// geometry; the generator then emits tokens in `[0, vocab)`).
    pub fn with_vocab(mut self, vocab: usize) -> Self {
        assert!(vocab >= 2);
        self.vocab = vocab;
        self
    }

    /// Scale token counts (and optionally vocab) by `1/div` for CPU-budgeted
    /// runs; documented per run in EXPERIMENTS.md.
    pub fn scaled(mut self, div: usize, vocab_div: usize) -> Self {
        assert!(div >= 1 && vocab_div >= 1);
        self.train_tokens = (self.train_tokens / div).max(1000);
        self.valid_tokens = (self.valid_tokens / div).max(500);
        self.test_tokens = (self.test_tokens / div).max(500);
        self.vocab = (self.vocab / vocab_div).max(100);
        self.name = format!("{}/{}x{}", self.name, div, vocab_div);
        self
    }
}

/// A generated corpus with the standard three splits.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub spec: DatasetSpec,
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
    pub test: Vec<usize>,
}

/// Sampler over the Zipf–Mandelbrot distribution by inverse-CDF binary
/// search (exact, O(log V) per draw).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(vocab: usize, s: f64, q: f64) -> Self {
        let mut cdf = Vec::with_capacity(vocab);
        let mut acc = 0.0f64;
        for i in 0..vocab {
            acc += (i as f64 + 1.0 + q).powf(-s);
            cdf.push(acc);
        }
        let norm = acc;
        for c in cdf.iter_mut() {
            *c /= norm;
        }
        ZipfSampler { cdf }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn prob(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

impl Corpus {
    /// Generate all three splits deterministically from the spec seed.
    pub fn generate(spec: DatasetSpec) -> Self {
        let sampler = ZipfSampler::new(spec.vocab, spec.zipf_s, spec.zipf_q);
        let mut rng = Rng::new(spec.seed);
        // Deterministic successor sets: S(c) derived from a cheap hash so
        // train/valid/test share the same transition structure.
        let successor = |c: usize, j: usize| -> usize {
            let mut h = (c as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(j as u64 + 1);
            h ^= h >> 33;
            h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            (h % spec.vocab as u64) as usize
        };
        let mut gen_split = |len: usize| -> Vec<usize> {
            let mut out = Vec::with_capacity(len);
            let mut cur = sampler.sample(&mut rng);
            out.push(cur);
            for _ in 1..len {
                cur = if rng.f64() < spec.bigram_lambda {
                    successor(cur, rng.below(spec.successors))
                } else {
                    sampler.sample(&mut rng)
                };
                out.push(cur);
            }
            out
        };
        let train = gen_split(spec.train_tokens);
        let valid = gen_split(spec.valid_tokens);
        let test = gen_split(spec.test_tokens);
        Corpus { spec, train, valid, test }
    }

    /// Entropy-rate upper bound (unigram entropy, nats → perplexity): the
    /// PPW a unigram-optimal model would reach; a trained bigram model goes
    /// lower. Useful as a sanity anchor for trained-PPW numbers.
    pub fn unigram_perplexity(&self) -> f64 {
        let mut counts = vec![0usize; self.spec.vocab];
        for &t in &self.train {
            counts[t] += 1;
        }
        let n = self.train.len() as f64;
        let mut h = 0.0f64;
        for &c in &counts {
            if c > 0 {
                let p = c as f64 / n;
                h -= p * p.ln();
            }
        }
        h.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> DatasetSpec {
        DatasetSpec {
            name: "test".into(),
            vocab: 200,
            train_tokens: 20_000,
            valid_tokens: 2_000,
            test_tokens: 2_000,
            seed: 7,
            zipf_s: 1.05,
            zipf_q: 2.7,
            bigram_lambda: 0.55,
            successors: 4,
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(small_spec());
        let b = Corpus::generate(small_spec());
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
    }

    #[test]
    fn tokens_in_vocab_and_sizes_match() {
        let c = Corpus::generate(small_spec());
        assert_eq!(c.train.len(), 20_000);
        assert_eq!(c.valid.len(), 2_000);
        assert!(c.train.iter().all(|&t| t < 200));
    }

    #[test]
    fn zipf_head_is_heavy() {
        let s = ZipfSampler::new(1000, 1.05, 2.7);
        // Top-10 tokens should carry a large probability share.
        let head: f64 = (0..10).map(|i| s.prob(i)).sum();
        assert!(head > 0.15, "head mass {head}");
        // And the CDF must be a proper distribution.
        assert!((s.cdf.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zipf_sampling_matches_probabilities() {
        let s = ZipfSampler::new(50, 1.05, 2.7);
        let mut rng = Rng::new(9);
        let mut counts = vec![0usize; 50];
        let n = 200_000;
        for _ in 0..n {
            counts[s.sample(&mut rng)] += 1;
        }
        for i in 0..5 {
            let emp = counts[i] as f64 / n as f64;
            let p = s.prob(i);
            assert!((emp - p).abs() < 0.02, "token {i}: emp {emp} vs p {p}");
        }
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // The conditional entropy given the previous token must be clearly
        // below the unigram entropy — otherwise there is nothing to learn.
        let c = Corpus::generate(small_spec());
        let v = c.spec.vocab;
        let mut uni = vec![0f64; v];
        let mut big = std::collections::HashMap::<(usize, usize), f64>::new();
        for w in c.train.windows(2) {
            uni[w[0]] += 1.0;
            *big.entry((w[0], w[1])).or_insert(0.0) += 1.0;
        }
        let n = (c.train.len() - 1) as f64;
        let h_uni: f64 = {
            let mut counts = vec![0f64; v];
            for &t in &c.train {
                counts[t] += 1.0;
            }
            -counts
                .iter()
                .filter(|&&x| x > 0.0)
                .map(|&x| (x / n) * (x / n).ln())
                .sum::<f64>()
        };
        let h_big: f64 = -big
            .iter()
            .map(|(&(a, _), &cnt)| (cnt / n) * (cnt / uni[a]).ln())
            .sum::<f64>();
        assert!(
            h_big < 0.8 * h_uni,
            "bigram entropy {h_big} not far below unigram {h_uni}"
        );
    }

    #[test]
    fn presets_match_paper_sizes() {
        let p = DatasetSpec::ptb_like();
        assert_eq!((p.vocab, p.train_tokens), (10_000, 929_000));
        let w = DatasetSpec::wt2_like();
        assert_eq!((w.vocab, w.train_tokens), (33_000, 2_088_000));
        let t = DatasetSpec::text8_like();
        assert_eq!((t.vocab, t.train_tokens), (42_000, 15_300_000));
    }

    #[test]
    fn scaling_reduces_sizes() {
        let s = DatasetSpec::ptb_like().scaled(10, 5);
        assert_eq!(s.train_tokens, 92_900);
        assert_eq!(s.vocab, 2_000);
    }
}
