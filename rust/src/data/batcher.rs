//! Contiguous LM batching, exactly the Zaremba et al. recipe the paper
//! follows: split the token stream into `batch` parallel streams, then walk
//! windows of `bptt` tokens (paper: unroll 30, batch 20/100).

/// Iterator state over (inputs, targets) windows.
pub struct LmBatcher {
    data: Vec<usize>, // batch streams laid out as batch × stream_len
    batch: usize,
    stream_len: usize,
    bptt: usize,
    cursor: usize,
}

impl LmBatcher {
    pub fn new(tokens: &[usize], batch: usize, bptt: usize) -> Self {
        assert!(batch >= 1 && bptt >= 1);
        let stream_len = tokens.len() / batch;
        assert!(
            stream_len >= 2,
            "corpus too small: {} tokens for batch {batch}",
            tokens.len()
        );
        // Row-major batch × stream_len (truncates the tail like the reference impl).
        let mut data = vec![0usize; batch * stream_len];
        for b in 0..batch {
            data[b * stream_len..(b + 1) * stream_len]
                .copy_from_slice(&tokens[b * stream_len..(b + 1) * stream_len]);
        }
        LmBatcher { data, batch, stream_len, bptt, cursor: 0 }
    }

    /// Number of (x, y) windows per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        (self.stream_len - 1).div_ceil(self.bptt)
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Next window: `x, y` each `batch × len` (row-major), `y` shifted by
    /// one. Returns `None` at epoch end (call [`Self::reset`]).
    #[allow(clippy::type_complexity)]
    pub fn next(&mut self) -> Option<(Vec<usize>, Vec<usize>, usize)> {
        if self.cursor + 1 >= self.stream_len {
            return None;
        }
        let len = self.bptt.min(self.stream_len - 1 - self.cursor);
        let mut x = vec![0usize; self.batch * len];
        let mut y = vec![0usize; self.batch * len];
        for b in 0..self.batch {
            let s = &self.data[b * self.stream_len..(b + 1) * self.stream_len];
            x[b * len..(b + 1) * len].copy_from_slice(&s[self.cursor..self.cursor + len]);
            y[b * len..(b + 1) * len].copy_from_slice(&s[self.cursor + 1..self.cursor + 1 + len]);
        }
        self.cursor += len;
        Some((x, y, len))
    }

    pub fn reset(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_stream_with_shift() {
        let tokens: Vec<usize> = (0..23).collect();
        let mut b = LmBatcher::new(&tokens, 2, 4);
        // stream_len = 11; streams: [0..11), [11..22).
        let (x, y, len) = b.next().unwrap();
        assert_eq!(len, 4);
        assert_eq!(&x[0..4], &[0, 1, 2, 3]);
        assert_eq!(&y[0..4], &[1, 2, 3, 4]);
        assert_eq!(&x[4..8], &[11, 12, 13, 14]);
        assert_eq!(&y[4..8], &[12, 13, 14, 15]);
        let mut windows = 1;
        while b.next().is_some() {
            windows += 1;
        }
        assert_eq!(windows, b.batches_per_epoch());
    }

    #[test]
    fn reset_replays_identically() {
        let tokens: Vec<usize> = (0..100).map(|i| i % 7).collect();
        let mut b = LmBatcher::new(&tokens, 4, 5);
        let first: Vec<_> = std::iter::from_fn(|| b.next()).collect();
        b.reset();
        let second: Vec<_> = std::iter::from_fn(|| b.next()).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn last_window_may_be_short() {
        let tokens: Vec<usize> = (0..21).collect();
        let mut b = LmBatcher::new(&tokens, 1, 6);
        let mut lens = Vec::new();
        while let Some((_, _, l)) = b.next() {
            lens.push(l);
        }
        assert_eq!(lens, vec![6, 6, 6, 2]);
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn too_small_panics() {
        LmBatcher::new(&[1, 2, 3], 4, 2);
    }
}
