//! Procedural image datasets standing in for MNIST and CIFAR-10
//! (DESIGN.md §4): deterministic, class-structured, learnable.
//!
//! * MNIST-like: 28×28 grayscale "digits" rendered from per-class stroke
//!   templates (segments + arcs) with per-sample jitter, rotation and noise.
//! * CIFAR-like: 32×32×3 textured classes — class-specific oriented
//!   gratings + color bias + noise (classes differ in orientation,
//!   frequency, and hue, so a small conv net separates them while a linear
//!   model struggles).

use crate::util::Rng;

/// A labelled image set, images row-major `n × (c·h·w)` in `[0, 1]`.
pub struct ImageSet {
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
}

impl ImageSet {
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.channels * self.height * self.width;
        &self.images[i * sz..(i + 1) * sz]
    }

    pub fn pixels(&self) -> usize {
        self.channels * self.height * self.width
    }
}

/// Per-class stroke templates for the 10 digit-like classes: a list of
/// segments `(x0,y0,x1,y1)` in unit coordinates.
fn digit_strokes(class: usize) -> Vec<(f32, f32, f32, f32)> {
    match class {
        0 => vec![(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.7, 0.8), (0.7, 0.8, 0.3, 0.8), (0.3, 0.8, 0.3, 0.2)],
        1 => vec![(0.5, 0.15, 0.5, 0.85), (0.38, 0.3, 0.5, 0.15)],
        2 => vec![(0.3, 0.25, 0.7, 0.25), (0.7, 0.25, 0.7, 0.5), (0.7, 0.5, 0.3, 0.8), (0.3, 0.8, 0.7, 0.8)],
        3 => vec![(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.7, 0.5), (0.4, 0.5, 0.7, 0.5), (0.7, 0.5, 0.7, 0.8), (0.3, 0.8, 0.7, 0.8)],
        4 => vec![(0.35, 0.2, 0.35, 0.55), (0.35, 0.55, 0.7, 0.55), (0.65, 0.2, 0.65, 0.85)],
        5 => vec![(0.7, 0.2, 0.3, 0.2), (0.3, 0.2, 0.3, 0.5), (0.3, 0.5, 0.7, 0.5), (0.7, 0.5, 0.7, 0.8), (0.7, 0.8, 0.3, 0.8)],
        6 => vec![(0.65, 0.2, 0.35, 0.35), (0.35, 0.35, 0.35, 0.8), (0.35, 0.8, 0.7, 0.8), (0.7, 0.8, 0.7, 0.55), (0.7, 0.55, 0.35, 0.55)],
        7 => vec![(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.45, 0.85)],
        8 => vec![(0.35, 0.2, 0.65, 0.2), (0.65, 0.2, 0.65, 0.8), (0.65, 0.8, 0.35, 0.8), (0.35, 0.8, 0.35, 0.2), (0.35, 0.5, 0.65, 0.5)],
        _ => vec![(0.35, 0.2, 0.65, 0.2), (0.65, 0.2, 0.65, 0.85), (0.35, 0.2, 0.35, 0.5), (0.35, 0.5, 0.65, 0.5)],
    }
}

/// Draw an anti-aliased segment with thickness into a h×w canvas.
fn draw_segment(img: &mut [f32], h: usize, w: usize, seg: (f32, f32, f32, f32), thick: f32) {
    let (x0, y0, x1, y1) = seg;
    let (ax, ay) = (x0 * w as f32, y0 * h as f32);
    let (bx, by) = (x1 * w as f32, y1 * h as f32);
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = (dx * dx + dy * dy).max(1e-6);
    for py in 0..h {
        for px in 0..w {
            let (fx, fy) = (px as f32 + 0.5, py as f32 + 0.5);
            let t = (((fx - ax) * dx + (fy - ay) * dy) / len2).clamp(0.0, 1.0);
            let (cx, cy) = (ax + t * dx, ay + t * dy);
            let d = ((fx - cx).powi(2) + (fy - cy).powi(2)).sqrt();
            let v = (1.0 - (d - thick).max(0.0)).clamp(0.0, 1.0);
            let idx = py * w + px;
            img[idx] = img[idx].max(v);
        }
    }
}

/// Generate an MNIST-like set: `n` samples of 28×28 grayscale, 10 classes.
pub fn mnist_like(n: usize, seed: u64) -> ImageSet {
    let (h, w) = (28usize, 28usize);
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * h * w];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let class = rng.below(10);
        labels[i] = class;
        let img = &mut images[i * h * w..(i + 1) * h * w];
        // Per-sample jitter: shift, scale, rotation.
        let (sx, sy) = (rng.range_f32(-0.08, 0.08), rng.range_f32(-0.08, 0.08));
        let scale = rng.range_f32(0.85, 1.15);
        let rot = rng.range_f32(-0.25, 0.25);
        let (cr, sr) = (rot.cos(), rot.sin());
        let xf = |x: f32, y: f32| -> (f32, f32) {
            let (xc, yc) = (x - 0.5, y - 0.5);
            let (xr, yr) = (cr * xc - sr * yc, sr * xc + cr * yc);
            (0.5 + scale * xr + sx, 0.5 + scale * yr + sy)
        };
        for seg in digit_strokes(class) {
            let (x0, y0) = xf(seg.0, seg.1);
            let (x1, y1) = xf(seg.2, seg.3);
            draw_segment(img, h, w, (x0, y0, x1, y1), rng.range_f32(0.9, 1.5));
        }
        // Background noise.
        for v in img.iter_mut() {
            *v = (*v + rng.range_f32(0.0, 0.08)).min(1.0);
        }
    }
    ImageSet { images, labels, n, channels: 1, height: h, width: w }
}

/// Generate a CIFAR-like set: `n` samples of 3×32×32, 10 classes.
pub fn cifar_like(n: usize, seed: u64) -> ImageSet {
    let (c, h, w) = (3usize, 32usize, 32usize);
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * c * h * w];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let class = rng.below(10);
        labels[i] = class;
        // Class signature: orientation, frequency, hue.
        let theta = class as f32 * std::f32::consts::PI / 10.0;
        let freq = 0.25 + 0.09 * (class % 5) as f32;
        let hue = [
            (1.0, 0.3, 0.3), (0.3, 1.0, 0.3), (0.3, 0.3, 1.0), (1.0, 1.0, 0.3),
            (1.0, 0.3, 1.0), (0.3, 1.0, 1.0), (1.0, 0.6, 0.2), (0.6, 0.2, 1.0),
            (0.2, 1.0, 0.6), (0.7, 0.7, 0.7),
        ][class];
        let phase = rng.range_f32(0.0, std::f32::consts::TAU);
        let img = &mut images[i * c * h * w..(i + 1) * c * h * w];
        let (ct, st) = (theta.cos(), theta.sin());
        for py in 0..h {
            for px in 0..w {
                let u = ct * px as f32 + st * py as f32;
                let g = 0.5 + 0.5 * (freq * u + phase).sin();
                let noise = rng.range_f32(-0.1, 0.1);
                let base = [hue.0, hue.1, hue.2];
                for (ch, &b) in base.iter().enumerate() {
                    img[ch * h * w + py * w + px] = (g * b + noise).clamp(0.0, 1.0);
                }
            }
        }
    }
    ImageSet { images, labels, n, channels: c, height: h, width: w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_range() {
        let s = mnist_like(20, 1);
        assert_eq!(s.n, 20);
        assert_eq!(s.pixels(), 28 * 28);
        assert!(s.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(s.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn deterministic() {
        let a = mnist_like(5, 42);
        let b = mnist_like(5, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class pixel correlation must exceed inter-class: the
        // classes carry signal. Use class means as prototypes.
        let s = mnist_like(400, 3);
        let px = s.pixels();
        let mut means = vec![vec![0.0f32; px]; 10];
        let mut counts = [0usize; 10];
        for i in 0..s.n {
            let l = s.labels[i];
            counts[l] += 1;
            for (m, &v) in means[l].iter_mut().zip(s.image(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        // Nearest-prototype classification should beat chance by a lot.
        let mut correct = 0;
        for i in 0..s.n {
            let img = s.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(img).map(|(m, v)| (m - v) * (m - v)).sum();
                    let db: f32 = means[b].iter().zip(img).map(|(m, v)| (m - v) * (m - v)).sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best == s.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / s.n as f64;
        assert!(acc > 0.6, "prototype accuracy {acc}");
    }

    #[test]
    fn cifar_like_shapes() {
        let s = cifar_like(10, 2);
        assert_eq!(s.pixels(), 3 * 32 * 32);
        assert!(s.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
