//! Data substrates.
//!
//! The paper evaluates on PTB, WikiText-2, Text8 (language modeling) and
//! MNIST / CIFAR-10 (images). None of those corpora are available in this
//! offline environment, so per the substitution policy in DESIGN.md §4 we
//! build deterministic synthetic equivalents that exercise the same code
//! paths and preserve the statistics the experiments depend on:
//!
//! * [`synthetic`] — Zipf–Mandelbrot bigram-chain corpora (`ptb-like`,
//!   `wt2-like`, `text8-like` presets with the papers' vocab sizes).
//! * [`images`] — procedural 28×28 digit-like and 32×32 textured-class
//!   image sets for the Appendix-B tables.
//! * [`batcher`] — the standard contiguous LM batching (batch streams ×
//!   BPTT windows), matching the paper's unroll of 30.
//! * [`checkpoint`] — a minimal named-tensor binary format shared with the
//!   Layer-2 Python side (`python/compile/tensorio.py`).

pub mod amqz;
pub mod batcher;
pub mod checkpoint;
pub mod images;
pub mod synthetic;

pub use synthetic::{Corpus, DatasetSpec};
