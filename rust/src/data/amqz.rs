//! `.amqz` — the zero-copy packed-model format.
//!
//! `amq publish` pays the quantization cost **once**, writing the packed
//! `u64` planes and `f32` alphas in exactly the `[row][plane][word]`
//! serving layout of [`PreparedGemm`]. The loader then brings a model up
//! with a **single bulk read into a `u64` arena** — no parsing loop over
//! weights, no requantization — so cold start moves O(file size) bytes
//! and nothing else. `rust/tests/amqz_roundtrip.rs` pins the loaded model
//! bit-identical to the parse-and-requantize path and gates the cold-load
//! speedup.
//!
//! Layout (all integers little-endian, every section 8-byte aligned):
//! ```text
//! magic "AMQZ" | u32 version=1
//! u8 kind (0=lstm, 1=gru) | u8 w_bits | u8 a_bits | u8 method (0=alternating)
//! u32 layers | u64 vocab | u64 hidden
//! matrix  embedding                      (vocab × hidden)
//! per layer: matrix wx | matrix wh | f32vec bias
//! matrix  softmax                        (vocab × hidden)
//! f32vec  softmax_bias                   (vocab)
//!
//! matrix: u64 rows | u64 cols | u64 k
//!         f32 alphas[rows·k] | pad to 8
//!         u64 words[rows·k·cols.div_ceil(64)]     ([row][plane][word])
//! f32vec: u64 len | f32 data[len] | pad to 8
//! ```
//!
//! The arena is a `Vec<u64>`, so every `u64` field is read by aligned
//! indexing (`u64::from_le`, a no-op on little-endian hosts) and the
//! plane words are copied out of the arena as whole slices. `f32`s are
//! extracted from the words by bit-twiddling. Shape and tail-bit
//! invariants are validated as sections are walked, so truncated or
//! corrupt files fail with an error instead of panicking.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::kernels::binary::PreparedGemm;
use crate::model::lm::{LmConfig, PackedLayer, PackedLmParts, RnnKind};
use crate::model::RnnLm;
use crate::quant::RowQuantized;

const MAGIC: u32 = u32::from_le_bytes(*b"AMQZ");
const VERSION: u32 = 1;
/// Method tag in the header: alternating minimization (the only quantizer
/// the serving GEMM needs to know about — all methods share the plane
/// format, so new tags only gate provenance, not decoding).
const METHOD_ALTERNATING: u8 = 0;

// ---------------------------------------------------------------- writing

fn write_matrix(
    w: &mut impl Write,
    rows: usize,
    cols: usize,
    k: usize,
    alphas: &[f32],
    words: &[u64],
) -> Result<()> {
    debug_assert_eq!(alphas.len(), rows * k);
    debug_assert_eq!(words.len(), rows * k * cols.div_ceil(64));
    for dim in [rows, cols, k] {
        w.write_all(&(dim as u64).to_le_bytes())?;
    }
    write_f32s_padded(w, alphas)?;
    for word in words {
        w.write_all(&word.to_le_bytes())?;
    }
    Ok(())
}

fn write_f32s_padded(w: &mut impl Write, data: &[f32]) -> Result<()> {
    for x in data {
        w.write_all(&x.to_bits().to_le_bytes())?;
    }
    if data.len() % 2 == 1 {
        w.write_all(&[0u8; 4])?;
    }
    Ok(())
}

fn write_vec(w: &mut impl Write, data: &[f32]) -> Result<()> {
    w.write_all(&(data.len() as u64).to_le_bytes())?;
    write_f32s_padded(w, data)
}

/// Write a published model. The packed planes and alphas go out verbatim
/// from the serving layout, so [`load`] can adopt them without rebuilding.
pub fn save(path: &Path, parts: &PackedLmParts) -> Result<()> {
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&VERSION.to_le_bytes())?;
    let kind = match parts.config.kind {
        RnnKind::Lstm => 0u8,
        RnnKind::Gru => 1u8,
    };
    ensure!(
        parts.w_bits >= 1 && parts.w_bits <= 255 && parts.a_bits >= 1 && parts.a_bits <= 255,
        "bit widths must fit a byte"
    );
    w.write_all(&[kind, parts.w_bits as u8, parts.a_bits as u8, METHOD_ALTERNATING])?;
    w.write_all(&(parts.config.layers as u32).to_le_bytes())?;
    w.write_all(&(parts.config.vocab as u64).to_le_bytes())?;
    w.write_all(&(parts.config.hidden as u64).to_le_bytes())?;
    let e = &parts.embedding;
    let mut ewords = Vec::with_capacity(e.rows * e.k * e.cols.div_ceil(64));
    for plane in &e.planes {
        ewords.extend_from_slice(plane.words());
    }
    write_matrix(&mut w, e.rows, e.cols, e.k, &e.alphas, &ewords)?;
    for layer in &parts.layers {
        for m in [&layer.wx, &layer.wh] {
            write_matrix(&mut w, m.rows, m.cols, m.k, m.alphas(), m.plane_words())?;
        }
        write_vec(&mut w, &layer.bias)?;
    }
    let s = &parts.softmax;
    write_matrix(&mut w, s.rows, s.cols, s.k, s.alphas(), s.plane_words())?;
    write_vec(&mut w, &parts.softmax_bias)?;
    w.flush().with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------------- loading

/// Byte-offset cursor over the loaded `u64` arena. All multi-byte reads
/// happen at their natural alignment (the writer pads every section to 8
/// bytes), so values come out by word indexing, never byte reassembly.
struct Cursor<'a> {
    arena: &'a [u64],
    /// File length in bytes (the arena's last word may be partial).
    len: usize,
    off: usize,
}

impl Cursor<'_> {
    /// Reserve `n` bytes: bounds-check, advance, return the old offset.
    fn take(&mut self, n: usize) -> Result<usize> {
        let end = self.off.checked_add(n).context("section size overflows")?;
        ensure!(end <= self.len, "file truncated (need {end} bytes, have {})", self.len);
        let at = self.off;
        self.off = end;
        Ok(at)
    }

    fn u32(&mut self) -> Result<u32> {
        let at = self.take(4)?;
        debug_assert_eq!(at % 4, 0);
        let word = u64::from_le(self.arena[at / 8]);
        Ok(if at % 8 == 0 { word as u32 } else { (word >> 32) as u32 })
    }

    fn u64(&mut self) -> Result<u64> {
        let at = self.take(8)?;
        debug_assert_eq!(at % 8, 0);
        Ok(u64::from_le(self.arena[at / 8]))
    }

    /// A `u64` field that must fit `usize` (rows, cols, lengths).
    fn dim(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("dimension overflows usize")
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let nbytes = n.checked_mul(4).context("f32 section size overflows")?;
        let at = self.take(nbytes)?;
        debug_assert_eq!(at % 8, 0);
        if n % 2 == 1 {
            self.take(4)?; // writer's alignment pad
        }
        let base = at / 8;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let word = u64::from_le(self.arena[base + i / 2]);
            let bits = if i % 2 == 0 { word as u32 } else { (word >> 32) as u32 };
            out.push(f32::from_bits(bits));
        }
        Ok(out)
    }

    /// The bulk move: `n` plane words lifted out of the arena as one slice.
    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let nbytes = n.checked_mul(8).context("plane section size overflows")?;
        let at = self.take(nbytes)?;
        debug_assert_eq!(at % 8, 0);
        let base = at / 8;
        Ok(self.arena[base..base + n].iter().map(|&w| u64::from_le(w)).collect())
    }

    /// Matrix section as raw parts: `(rows, cols, k, alphas, words)`.
    fn matrix(&mut self) -> Result<(usize, usize, usize, Vec<f32>, Vec<u64>)> {
        let (rows, cols, k) = (self.dim()?, self.dim()?, self.dim()?);
        ensure!(rows >= 1 && cols >= 1 && k >= 1, "degenerate matrix shape {rows}x{cols} k={k}");
        let planes = rows.checked_mul(k).context("matrix shape overflows")?;
        let words = planes.checked_mul(cols.div_ceil(64)).context("matrix shape overflows")?;
        let alphas = self.f32s(planes)?;
        let data = self.u64s(words)?;
        Ok((rows, cols, k, alphas, data))
    }

    fn vec(&mut self) -> Result<Vec<f32>> {
        let n = self.dim()?;
        self.f32s(n)
    }
}

/// Load a published model's packed parts: one metadata read, one bulk
/// `read_exact` into a `u64` arena, then section walks that only copy
/// plane/alpha buffers out — no parse, no requantize.
pub fn load(path: &Path) -> Result<PackedLmParts> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let len = f.metadata()?.len();
    let len = usize::try_from(len).context("file too large for this host")?;
    ensure!(len >= 32, "not an .amqz file (shorter than the header)");
    let mut arena = vec![0u64; len.div_ceil(8)];
    // SAFETY: u8 has no alignment or validity requirements, and the byte
    // view covers exactly the `len` bytes inside the arena's allocation.
    let bytes = unsafe { std::slice::from_raw_parts_mut(arena.as_mut_ptr().cast::<u8>(), len) };
    f.read_exact(bytes).with_context(|| format!("reading {}", path.display()))?;
    drop(f);

    let mut c = Cursor { arena: &arena, len, off: 0 };
    let magic = c.u32()?;
    ensure!(magic == MAGIC, "not an .amqz file (bad magic)");
    let version = c.u32()?;
    ensure!(version == VERSION, "unsupported .amqz version {version} (expected {VERSION})");
    let [kind, w_bits, a_bits, method] = c.u32()?.to_le_bytes();
    let kind = match kind {
        0 => RnnKind::Lstm,
        1 => RnnKind::Gru,
        other => bail!("unknown model kind tag {other}"),
    };
    ensure!(method == METHOD_ALTERNATING, "unsupported quantization method tag {method}");
    let (w_bits, a_bits) = (w_bits as usize, a_bits as usize);
    ensure!(w_bits >= 1 && a_bits >= 1, "bit widths must be at least 1");
    let layers = c.u32()? as usize;
    let vocab = usize::try_from(c.u64()?).context("vocab overflows usize")?;
    let hidden = usize::try_from(c.u64()?).context("hidden overflows usize")?;
    ensure!(layers >= 1 && vocab >= 1 && hidden >= 1, "degenerate model shape");
    let config = LmConfig { kind, vocab, hidden, layers };

    let (rows, cols, k, alphas, words) = c.matrix()?;
    let embedding = RowQuantized::from_raw_parts(rows, cols, k, alphas, &words)
        .map_err(|e| anyhow::anyhow!("embedding: {e}"))?;
    let mut packed_layers = Vec::with_capacity(layers);
    for l in 0..layers {
        let (rows, cols, k, alphas, words) = c.matrix()?;
        let wx = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
            .map_err(|e| anyhow::anyhow!("layer {l} wx: {e}"))?;
        let (rows, cols, k, alphas, words) = c.matrix()?;
        let wh = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
            .map_err(|e| anyhow::anyhow!("layer {l} wh: {e}"))?;
        let bias = c.vec()?;
        packed_layers.push(PackedLayer { wx, wh, bias });
    }
    let (rows, cols, k, alphas, words) = c.matrix()?;
    let softmax = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
        .map_err(|e| anyhow::anyhow!("softmax: {e}"))?;
    let softmax_bias = c.vec()?;
    ensure!(c.off == len, "{} trailing bytes after the model payload", len - c.off);
    Ok(PackedLmParts {
        config,
        w_bits,
        a_bits,
        embedding,
        layers: packed_layers,
        softmax,
        softmax_bias,
    })
}

/// [`load`] + [`RnnLm::from_packed`]: file → serving model in one call.
pub fn load_model(path: &Path) -> Result<RnnLm> {
    RnnLm::from_packed(load(path)?)
        .with_context(|| format!("assembling model from {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lm::PrecisionPolicy;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("amqz_unit_{}_{name}.amqz", std::process::id()))
    }

    fn tiny_model(kind: RnnKind) -> RnnLm {
        let config = LmConfig { kind, vocab: 50, hidden: 24, layers: 1 };
        RnnLm::random(config, 7, PrecisionPolicy::quantized(2, 2))
    }

    #[test]
    fn roundtrip_preserves_every_buffer() {
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            let model = tiny_model(kind);
            let parts = model.to_packed().unwrap();
            let path = tmp(kind.name());
            save(&path, &parts).unwrap();
            let loaded = load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(loaded.w_bits, parts.w_bits);
            assert_eq!(loaded.a_bits, parts.a_bits);
            assert_eq!(loaded.embedding.alphas, parts.embedding.alphas);
            assert_eq!(loaded.embedding.planes, parts.embedding.planes);
            assert_eq!(loaded.softmax.plane_words(), parts.softmax.plane_words());
            assert_eq!(loaded.softmax.alphas(), parts.softmax.alphas());
            assert_eq!(loaded.softmax_bias, parts.softmax_bias);
            for (a, b) in loaded.layers.iter().zip(&parts.layers) {
                assert_eq!(a.wx.plane_words(), b.wx.plane_words());
                assert_eq!(a.wh.plane_words(), b.wh.plane_words());
                assert_eq!(a.bias, b.bias);
            }
        }
    }

    #[test]
    fn corrupt_and_truncated_files_error_without_panicking() {
        let model = tiny_model(RnnKind::Lstm);
        let path = tmp("corrupt");
        save(&path, &model.to_packed().unwrap()).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(load(&path).unwrap_err().to_string().contains("bad magic"));

        // Truncation at every interesting boundary.
        for cut in [7, 31, 40, good.len() / 2, good.len() - 4] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at {cut} must error");
        }

        // Trailing junk.
        let mut long = good.clone();
        long.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &long).unwrap();
        assert!(load(&path).unwrap_err().to_string().contains("trailing"));

        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dense_models_refuse_to_publish() {
        let config = LmConfig { kind: RnnKind::Lstm, vocab: 20, hidden: 8, layers: 1 };
        let dense = RnnLm::random(config, 3, PrecisionPolicy::full());
        assert!(dense.to_packed().is_err());
    }
}
