//! `.amqz` — the zero-copy packed-model format, crash-safe since v2.
//!
//! `amq publish` pays the quantization cost **once**, writing the packed
//! `u64` planes and `f32` alphas in exactly the `[row][plane][word]`
//! serving layout of [`PreparedGemm`]. The loader then brings a model up
//! with a **single bulk read into a `u64` arena** — no parsing loop over
//! weights, no requantization — so cold start moves O(file size) bytes
//! and nothing else. `rust/tests/amqz_roundtrip.rs` pins the loaded model
//! bit-identical to the quantize path and gates the cold-load speedup.
//!
//! Layout (all integers little-endian, every section 8-byte aligned):
//! ```text
//! magic "AMQZ" | u32 version=2
//! u8 kind (0=lstm, 1=gru) | u8 w_bits | u8 a_bits | u8 method (0=alternating)
//! u32 layers | u64 vocab | u64 hidden
//! matrix  embedding                      (vocab × hidden)
//! per layer: matrix wx | matrix wh | f32vec bias
//! matrix  softmax                        (vocab × hidden)
//! f32vec  softmax_bias                   (vocab)
//! trailer (v2): u32 crc32c[section]      (one per section, walk order)
//!               pad to 8
//!               magic "AMQC" | u32 section_count
//!               u32 file_crc32c | u32 0  (crc of every byte before it)
//!
//! matrix: u64 rows | u64 cols | u64 k
//!         f32 alphas[rows·k] | pad to 8
//!         u64 words[rows·k·cols.div_ceil(64)]     ([row][plane][word])
//! f32vec: u64 len | f32 data[len] | pad to 8
//! ```
//!
//! **Durability.** [`save`] is atomic: the whole file is encoded in
//! memory, written to a same-directory temp file, fsynced, renamed over
//! the destination, and the directory entry is fsynced — a crash at any
//! point leaves either the previous file or the complete new one on disk,
//! never a hybrid. The v2 trailer is parseable from the **end** of the
//! file, so a torn write (truncation, bit rot past the rename) is caught
//! before any section is trusted: the loader verifies the whole-file
//! CRC32C and every per-section CRC32C and refuses with a typed
//! [`CorruptModel`] naming the damaged section — the registry surfaces it
//! as `ERR MODEL_CORRUPT <name> <section>`. v1 files (no trailer) still
//! load, with an `unverified` warning on stderr.
//!
//! The arena is a `Vec<u64>`, so every `u64` field is read by aligned
//! indexing (`u64::from_le`, a no-op on little-endian hosts) and the
//! plane words are copied out of the arena as whole slices. `f32`s are
//! extracted from the words by bit-twiddling. Shape and tail-bit
//! invariants are validated as sections are walked — including agreement
//! with the header config — so truncated or corrupt files fail with an
//! error instead of panicking.

use std::fmt;
use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::kernels::binary::PreparedGemm;
use crate::model::lm::{LmConfig, PackedLayer, PackedLmParts, RnnKind};
use crate::model::RnnLm;
use crate::quant::RowQuantized;
use crate::server::faults::FaultPlan;
use crate::util::crc::crc32c;

const MAGIC: u32 = u32::from_le_bytes(*b"AMQZ");
/// Current format version: v2 adds the checksum trailer. v1 (no trailer,
/// identical body layout) is still readable.
const VERSION: u32 = 2;
const VERSION_UNVERIFIED: u32 = 1;
/// Magic of the v2 checksum trailer, sitting 16 bytes before end-of-file.
const TRAILER_MAGIC: u32 = u32::from_le_bytes(*b"AMQC");
/// Method tag in the header: alternating minimization (the only quantizer
/// the serving GEMM needs to know about — all methods share the plane
/// format, so new tags only gate provenance, not decoding).
const METHOD_ALTERNATING: u8 = 0;

/// A checksum-verified load failure: the file is structurally present but
/// its bytes do not match what was published. `section` names the first
/// damaged section (`"file"`/`"trailer"` when the damage is outside the
/// body walk). The registry downcasts this to answer
/// `ERR MODEL_CORRUPT <name> <section>` instead of a generic load error.
#[derive(Debug, Clone)]
pub struct CorruptModel {
    pub section: String,
    pub detail: String,
}

impl fmt::Display for CorruptModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "section {}: {}", self.section, self.detail)
    }
}

impl std::error::Error for CorruptModel {}

fn corrupt(section: &str, detail: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(CorruptModel { section: section.to_string(), detail: detail.into() })
}

// ---------------------------------------------------------------- writing

fn write_matrix(
    w: &mut Vec<u8>,
    rows: usize,
    cols: usize,
    k: usize,
    alphas: &[f32],
    words: &[u64],
) {
    debug_assert_eq!(alphas.len(), rows * k);
    debug_assert_eq!(words.len(), rows * k * cols.div_ceil(64));
    for dim in [rows, cols, k] {
        w.extend_from_slice(&(dim as u64).to_le_bytes());
    }
    write_f32s_padded(w, alphas);
    for word in words {
        w.extend_from_slice(&word.to_le_bytes());
    }
}

fn write_f32s_padded(w: &mut Vec<u8>, data: &[f32]) {
    for x in data {
        w.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    if data.len() % 2 == 1 {
        w.extend_from_slice(&[0u8; 4]);
    }
}

fn write_vec(w: &mut Vec<u8>, data: &[f32]) {
    w.extend_from_slice(&(data.len() as u64).to_le_bytes());
    write_f32s_padded(w, data);
}

/// Encode the complete v2 file — header, sections, checksum trailer — as
/// one in-memory buffer (the unit the atomic publish writes and the fault
/// seams mutate).
fn encode(parts: &PackedLmParts) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    let kind = match parts.config.kind {
        RnnKind::Lstm => 0u8,
        RnnKind::Gru => 1u8,
    };
    ensure!(
        parts.w_bits >= 1 && parts.w_bits <= 255 && parts.a_bits >= 1 && parts.a_bits <= 255,
        "bit widths must fit a byte"
    );
    buf.extend_from_slice(&[kind, parts.w_bits as u8, parts.a_bits as u8, METHOD_ALTERNATING]);
    buf.extend_from_slice(&(parts.config.layers as u32).to_le_bytes());
    buf.extend_from_slice(&(parts.config.vocab as u64).to_le_bytes());
    buf.extend_from_slice(&(parts.config.hidden as u64).to_le_bytes());

    let mut crcs: Vec<u32> = Vec::new();
    let mut start = buf.len();
    let mut close_section = |buf: &[u8], start: &mut usize, crcs: &mut Vec<u32>| {
        crcs.push(crc32c(&buf[*start..]));
        *start = buf.len();
    };

    let e = &parts.embedding;
    let mut ewords = Vec::with_capacity(e.rows * e.k * e.cols.div_ceil(64));
    for plane in &e.planes {
        ewords.extend_from_slice(plane.words());
    }
    write_matrix(&mut buf, e.rows, e.cols, e.k, &e.alphas, &ewords);
    close_section(&buf, &mut start, &mut crcs);
    for layer in &parts.layers {
        for m in [&layer.wx, &layer.wh] {
            write_matrix(&mut buf, m.rows, m.cols, m.k, m.alphas(), m.plane_words());
            close_section(&buf, &mut start, &mut crcs);
        }
        write_vec(&mut buf, &layer.bias);
        close_section(&buf, &mut start, &mut crcs);
    }
    let s = &parts.softmax;
    write_matrix(&mut buf, s.rows, s.cols, s.k, s.alphas(), s.plane_words());
    close_section(&buf, &mut start, &mut crcs);
    write_vec(&mut buf, &parts.softmax_bias);
    close_section(&buf, &mut start, &mut crcs);

    for crc in &crcs {
        buf.extend_from_slice(&crc.to_le_bytes());
    }
    if crcs.len() % 2 == 1 {
        buf.extend_from_slice(&[0u8; 4]);
    }
    buf.extend_from_slice(&TRAILER_MAGIC.to_le_bytes());
    buf.extend_from_slice(&(crcs.len() as u32).to_le_bytes());
    let file_crc = crc32c(&buf);
    buf.extend_from_slice(&file_crc.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]);
    Ok(buf)
}

/// Write a published model atomically: encode in memory, write a
/// same-directory temp file, fsync, rename over `path`, fsync the
/// directory. A `kill -9` at any instant leaves either the old artifact
/// or the complete new one — the destination path never names a partial
/// file. The packed planes and alphas go out verbatim from the serving
/// layout, so [`load`] can adopt them without rebuilding.
pub fn save(path: &Path, parts: &PackedLmParts) -> Result<()> {
    save_with_faults(path, parts, None)
}

/// [`save`] with an injected fault plan (testing only): `torn_write=N`
/// truncates the published bytes at offset N (simulating post-rename bit
/// rot / a torn medium — the checksum trailer must catch it at load),
/// `bitflip=OFF:MASK` XORs one byte, `fsync_err` fails the publish at the
/// fsync boundary, leaving the previous artifact untouched.
pub fn save_with_faults(
    path: &Path,
    parts: &PackedLmParts,
    faults: Option<&FaultPlan>,
) -> Result<()> {
    let mut bytes = encode(parts)?;
    if let Some(fp) = faults {
        if let Some(n) = fp.on_publish_torn_write() {
            bytes.truncate(n.min(bytes.len()));
        }
        if let Some((off, mask)) = fp.on_publish_bitflip() {
            if !bytes.is_empty() {
                let i = off % bytes.len();
                bytes[i] ^= mask;
            }
        }
    }

    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = path.file_name().context("publish path has no file name")?;
    let tmp = dir.join(format!("{}.tmp.{}", name.to_string_lossy(), std::process::id()));
    let result = (|| -> Result<()> {
        let mut f =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes).with_context(|| format!("writing {}", tmp.display()))?;
        if let Some(fp) = faults {
            if fp.on_publish_fsync_err() {
                bail!("injected fault: fsync failed publishing {}", path.display());
            }
        }
        f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        // Make the rename itself durable. Directories open as plain files
        // on unix; where they don't, the rename is still atomic — only
        // its durability guarantee weakens, so this is best-effort.
        if let Ok(d) = File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

// ---------------------------------------------------------------- loading

/// Byte-offset cursor over the loaded `u64` arena. All multi-byte reads
/// happen at their natural alignment (the writer pads every section to 8
/// bytes), so values come out by word indexing, never byte reassembly.
struct Cursor<'a> {
    arena: &'a [u64],
    /// Walkable length in bytes (the body for v2 — the trailer is parsed
    /// separately — or the whole file for v1).
    len: usize,
    off: usize,
}

/// One aligned `u32` at byte offset `at` (must be 4-aligned and in range).
fn u32_at(arena: &[u64], at: usize) -> u32 {
    debug_assert_eq!(at % 4, 0);
    let word = u64::from_le(arena[at / 8]);
    if at % 8 == 0 {
        word as u32
    } else {
        (word >> 32) as u32
    }
}

impl Cursor<'_> {
    /// Reserve `n` bytes: bounds-check, advance, return the old offset.
    fn take(&mut self, n: usize) -> Result<usize> {
        let end = self.off.checked_add(n).context("section size overflows")?;
        ensure!(end <= self.len, "file truncated (need {end} bytes, have {})", self.len);
        let at = self.off;
        self.off = end;
        Ok(at)
    }

    fn u32(&mut self) -> Result<u32> {
        let at = self.take(4)?;
        Ok(u32_at(self.arena, at))
    }

    fn u64(&mut self) -> Result<u64> {
        let at = self.take(8)?;
        debug_assert_eq!(at % 8, 0);
        Ok(u64::from_le(self.arena[at / 8]))
    }

    /// A `u64` field that must fit `usize` (rows, cols, lengths).
    fn dim(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("dimension overflows usize")
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let nbytes = n.checked_mul(4).context("f32 section size overflows")?;
        let at = self.take(nbytes)?;
        debug_assert_eq!(at % 8, 0);
        if n % 2 == 1 {
            self.take(4)?; // writer's alignment pad
        }
        let base = at / 8;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let word = u64::from_le(self.arena[base + i / 2]);
            let bits = if i % 2 == 0 { word as u32 } else { (word >> 32) as u32 };
            out.push(f32::from_bits(bits));
        }
        Ok(out)
    }

    /// The bulk move: `n` plane words lifted out of the arena as one slice.
    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let nbytes = n.checked_mul(8).context("plane section size overflows")?;
        let at = self.take(nbytes)?;
        debug_assert_eq!(at % 8, 0);
        let base = at / 8;
        Ok(self.arena[base..base + n].iter().map(|&w| u64::from_le(w)).collect())
    }

    /// Matrix section as raw parts: `(rows, cols, k, alphas, words)`.
    fn matrix(&mut self) -> Result<(usize, usize, usize, Vec<f32>, Vec<u64>)> {
        let (rows, cols, k) = (self.dim()?, self.dim()?, self.dim()?);
        ensure!(rows >= 1 && cols >= 1 && k >= 1, "degenerate matrix shape {rows}x{cols} k={k}");
        let planes = rows.checked_mul(k).context("matrix shape overflows")?;
        let words = planes.checked_mul(cols.div_ceil(64)).context("matrix shape overflows")?;
        let alphas = self.f32s(planes)?;
        let data = self.u64s(words)?;
        Ok((rows, cols, k, alphas, data))
    }

    fn vec(&mut self) -> Result<Vec<f32>> {
        let n = self.dim()?;
        self.f32s(n)
    }
}

/// Per-section checksum verification state for a v2 walk.
struct Verifier<'a> {
    /// Raw file bytes (checksums cover the on-disk byte stream).
    bytes: &'a [u8],
    /// Expected per-section CRCs from the trailer, in walk order.
    expected: &'a [u32],
    seen: usize,
}

impl Verifier<'_> {
    fn section(&mut self, name: &str, start: usize, end: usize) -> Result<()> {
        if self.seen >= self.expected.len() {
            return Err(corrupt("trailer", "more sections than trailer checksums"));
        }
        let got = crc32c(&self.bytes[start..end]);
        let want = self.expected[self.seen];
        if got != want {
            return Err(corrupt(
                name,
                format!("checksum mismatch (stored {want:#010x}, computed {got:#010x})"),
            ));
        }
        self.seen += 1;
        Ok(())
    }
}

/// Walk the body sections (cursor positioned just past magic+version),
/// verifying each against the trailer checksums when `verifier` is armed,
/// and validating every section's shape against the header config.
fn parse_body(c: &mut Cursor, mut verifier: Option<&mut Verifier>) -> Result<PackedLmParts> {
    let [kind, w_bits, a_bits, method] = c.u32()?.to_le_bytes();
    let kind = match kind {
        0 => RnnKind::Lstm,
        1 => RnnKind::Gru,
        other => bail!("unknown model kind tag {other}"),
    };
    ensure!(method == METHOD_ALTERNATING, "unsupported quantization method tag {method}");
    let (w_bits, a_bits) = (w_bits as usize, a_bits as usize);
    ensure!(w_bits >= 1 && a_bits >= 1, "bit widths must be at least 1");
    let layers = c.u32()? as usize;
    let vocab = usize::try_from(c.u64()?).context("vocab overflows usize")?;
    let hidden = usize::try_from(c.u64()?).context("hidden overflows usize")?;
    ensure!(layers >= 1 && vocab >= 1 && hidden >= 1, "degenerate model shape");
    let config = LmConfig { kind, vocab, hidden, layers };
    let gates = kind.gates();

    let mut verify = |name: &str, start: usize, end: usize| -> Result<()> {
        match verifier.as_deref_mut() {
            Some(v) => v.section(name, start, end),
            None => Ok(()),
        }
    };
    let shape = |name: &str, rows: usize, cols: usize, k: usize, wr: usize, wc: usize| {
        ensure!(
            rows == wr && cols == wc && k == w_bits,
            "{name} shape {rows}x{cols} k={k} disagrees with header config {wr}x{wc} k={w_bits}"
        );
        Ok(())
    };

    let start = c.off;
    let (rows, cols, k, alphas, words) = c.matrix()?;
    verify("embedding", start, c.off)?;
    shape("embedding", rows, cols, k, vocab, hidden)?;
    let embedding = RowQuantized::from_raw_parts(rows, cols, k, alphas, &words)
        .map_err(|e| anyhow::anyhow!("embedding: {e}"))?;
    let mut packed_layers = Vec::with_capacity(layers);
    for l in 0..layers {
        let start = c.off;
        let (rows, cols, k, alphas, words) = c.matrix()?;
        verify(&format!("layer {l} wx"), start, c.off)?;
        shape(&format!("layer {l} wx"), rows, cols, k, gates * hidden, hidden)?;
        let wx = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
            .map_err(|e| anyhow::anyhow!("layer {l} wx: {e}"))?;
        let start = c.off;
        let (rows, cols, k, alphas, words) = c.matrix()?;
        verify(&format!("layer {l} wh"), start, c.off)?;
        shape(&format!("layer {l} wh"), rows, cols, k, gates * hidden, hidden)?;
        let wh = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
            .map_err(|e| anyhow::anyhow!("layer {l} wh: {e}"))?;
        let start = c.off;
        let bias = c.vec()?;
        verify(&format!("layer {l} bias"), start, c.off)?;
        ensure!(
            bias.len() == gates * hidden,
            "layer {l} bias length {} disagrees with header config {}",
            bias.len(),
            gates * hidden
        );
        packed_layers.push(PackedLayer { wx, wh, bias });
    }
    let start = c.off;
    let (rows, cols, k, alphas, words) = c.matrix()?;
    verify("softmax", start, c.off)?;
    shape("softmax", rows, cols, k, vocab, hidden)?;
    let softmax = PreparedGemm::from_raw_parts(rows, cols, k, words, alphas)
        .map_err(|e| anyhow::anyhow!("softmax: {e}"))?;
    let start = c.off;
    let softmax_bias = c.vec()?;
    verify("softmax_bias", start, c.off)?;
    ensure!(
        softmax_bias.len() == vocab,
        "softmax_bias length {} disagrees with header vocab {vocab}",
        softmax_bias.len()
    );
    Ok(PackedLmParts {
        config,
        w_bits,
        a_bits,
        embedding,
        layers: packed_layers,
        softmax,
        softmax_bias,
    })
}

/// Load a published model's packed parts: one metadata read, one bulk
/// `read_exact` into a `u64` arena, checksum verification (v2), then
/// section walks that only copy plane/alpha buffers out — no parse, no
/// requantize. Corruption is refused with a downcastable [`CorruptModel`]
/// naming the first damaged section; v1 files load with an `unverified`
/// stderr warning.
pub fn load(path: &Path) -> Result<PackedLmParts> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let len = f.metadata()?.len();
    let len = usize::try_from(len).context("file too large for this host")?;
    ensure!(len >= 32, "not an .amqz file (shorter than the header)");
    let mut arena = vec![0u64; len.div_ceil(8)];
    // SAFETY: u8 has no alignment or validity requirements, and the byte
    // view covers exactly the `len` bytes inside the arena's allocation.
    let bytes = unsafe { std::slice::from_raw_parts_mut(arena.as_mut_ptr().cast::<u8>(), len) };
    f.read_exact(bytes).with_context(|| format!("reading {}", path.display()))?;
    drop(f);
    // SAFETY: same allocation as above, now as a shared view for checksums.
    let bytes = unsafe { std::slice::from_raw_parts(arena.as_ptr().cast::<u8>(), len) };

    ensure!(u32_at(&arena, 0) == MAGIC, "not an .amqz file (bad magic)");
    let version = u32_at(&arena, 4);
    if version == VERSION_UNVERIFIED {
        eprintln!(
            "amqz: {} is a v1 file with no checksums — loaded unverified \
             (republish to upgrade)",
            path.display()
        );
        let mut c = Cursor { arena: &arena, len, off: 8 };
        let parts = parse_body(&mut c, None)?;
        ensure!(c.off == len, "{} trailing bytes after the model payload", len - c.off);
        return Ok(parts);
    }
    ensure!(
        version == VERSION,
        "unsupported .amqz version {version} (expected {VERSION} or {VERSION_UNVERIFIED})"
    );

    // v2: parse the trailer from the end of the file, verify the whole
    // file before trusting anything section-local.
    if len < 32 + 24 || len % 8 != 0 {
        return Err(corrupt("trailer", "file too short or misaligned for the checksum trailer"));
    }
    if u32_at(&arena, len - 16) != TRAILER_MAGIC {
        return Err(corrupt(
            "trailer",
            "checksum trailer missing or damaged (torn write or truncation)",
        ));
    }
    let count = u32_at(&arena, len - 12) as usize;
    let file_crc = u32_at(&arena, len - 8);
    let crc_area = match count.checked_mul(4).map(|b| b + if count % 2 == 1 { 4 } else { 0 }) {
        Some(b) => b,
        None => return Err(corrupt("trailer", "section count overflows")),
    };
    let body_len = match len.checked_sub(crc_area + 16) {
        Some(b) if b >= 32 => b,
        _ => return Err(corrupt("trailer", "section count exceeds the file size")),
    };
    let crc_ok = crc32c(&bytes[..len - 8]) == file_crc;
    let expected: Vec<u32> = (0..count).map(|i| u32_at(&arena, body_len + 4 * i)).collect();

    let mut verifier = Verifier { bytes, expected: &expected, seen: 0 };
    let mut c = Cursor { arena: &arena, len: body_len, off: 8 };
    let walked = parse_body(&mut c, Some(&mut verifier)).and_then(|parts| {
        ensure!(
            c.off == body_len,
            "{} trailing bytes after the model payload",
            body_len - c.off
        );
        ensure!(
            verifier.seen == expected.len(),
            "trailer lists {} sections, file has {}",
            expected.len(),
            verifier.seen
        );
        Ok(parts)
    });
    match walked {
        Ok(parts) => {
            if !crc_ok {
                // Every section verified but the whole-file CRC did not:
                // the damage is in the header or the trailer itself.
                return Err(corrupt("file", "whole-file checksum mismatch outside any section"));
            }
            Ok(parts)
        }
        Err(e) => {
            if !crc_ok && e.downcast_ref::<CorruptModel>().is_none() {
                // The walk failed structurally AND the file checksum says
                // the bytes are not what was published — report corruption,
                // not a writer bug.
                return Err(corrupt("file", format!("checksum mismatch; walk failed: {e:#}")));
            }
            Err(e)
        }
    }
}

/// [`load`] + [`RnnLm::from_packed`]: file → serving model in one call.
pub fn load_model(path: &Path) -> Result<RnnLm> {
    RnnLm::from_packed(load(path)?)
        .with_context(|| format!("assembling model from {}", path.display()))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::lm::PrecisionPolicy;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("amqz_unit_{}_{name}.amqz", std::process::id()))
    }

    fn tiny_model(kind: RnnKind) -> RnnLm {
        let config = LmConfig { kind, vocab: 50, hidden: 24, layers: 1 };
        RnnLm::random(config, 7, PrecisionPolicy::quantized(2, 2))
    }

    /// Trailer length for a 1-layer model: 6 sections → 24 CRC bytes (even
    /// count, no pad) + 16 trailer-end bytes.
    const TRAILER_LEN_1_LAYER: usize = 6 * 4 + 16;

    fn corrupt_section(err: &anyhow::Error) -> String {
        err.downcast_ref::<CorruptModel>()
            .unwrap_or_else(|| panic!("expected CorruptModel, got: {err:#}"))
            .section
            .clone()
    }

    #[test]
    fn roundtrip_preserves_every_buffer() {
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            let model = tiny_model(kind);
            let parts = model.to_packed().unwrap();
            let path = tmp(kind.name());
            save(&path, &parts).unwrap();
            let loaded = load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(loaded.w_bits, parts.w_bits);
            assert_eq!(loaded.a_bits, parts.a_bits);
            assert_eq!(loaded.embedding.alphas, parts.embedding.alphas);
            assert_eq!(loaded.embedding.planes, parts.embedding.planes);
            assert_eq!(loaded.softmax.plane_words(), parts.softmax.plane_words());
            assert_eq!(loaded.softmax.alphas(), parts.softmax.alphas());
            assert_eq!(loaded.softmax_bias, parts.softmax_bias);
            for (a, b) in loaded.layers.iter().zip(&parts.layers) {
                assert_eq!(a.wx.plane_words(), b.wx.plane_words());
                assert_eq!(a.wh.plane_words(), b.wh.plane_words());
                assert_eq!(a.bias, b.bias);
            }
        }
    }

    #[test]
    fn corrupt_and_truncated_files_error_without_panicking() {
        let model = tiny_model(RnnKind::Lstm);
        let path = tmp("corrupt");
        save(&path, &model.to_packed().unwrap()).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(load(&path).unwrap_err().to_string().contains("bad magic"));

        // Truncation at every interesting boundary.
        for cut in [7, 31, 40, good.len() / 2, good.len() - 4] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at {cut} must error");
        }

        // Trailing junk between the payload and where the trailer is
        // expected breaks the end-anchored trailer parse.
        let mut long = good.clone();
        long.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &long).unwrap();
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "trailer");

        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn single_bit_flips_name_the_damaged_section() {
        let model = tiny_model(RnnKind::Lstm);
        let path = tmp("bitflip");
        save(&path, &model.to_packed().unwrap()).unwrap();
        let good = std::fs::read(&path).unwrap();
        let body_len = good.len() - TRAILER_LEN_1_LAYER;

        // Offset 100 sits in the embedding alphas (first section).
        let mut b = good.clone();
        b[100] ^= 0x40;
        std::fs::write(&path, &b).unwrap();
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "embedding");

        // A flip near the end of the body lands in softmax_bias.
        let mut b = good.clone();
        b[body_len - 5] ^= 0x01;
        std::fs::write(&path, &b).unwrap();
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "softmax_bias");

        // A flip in the header (vocab field) fails the whole-file CRC and
        // reports "file" even though the section walk itself derails.
        let mut b = good.clone();
        b[16] ^= 0x10;
        std::fs::write(&path, &b).unwrap();
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "file");

        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v1_files_load_unverified_and_bit_identical() {
        let model = tiny_model(RnnKind::Gru);
        let parts = model.to_packed().unwrap();
        let path = tmp("v1");
        save(&path, &parts).unwrap();
        let good = std::fs::read(&path).unwrap();

        // A v1 file is the v2 body with the version field rolled back and
        // no trailer — the layouts are byte-identical by construction.
        let mut v1 = good[..good.len() - TRAILER_LEN_1_LAYER].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1).unwrap();
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(loaded.embedding.planes, parts.embedding.planes);
        assert_eq!(loaded.softmax.plane_words(), parts.softmax.plane_words());
        assert_eq!(loaded.softmax_bias, parts.softmax_bias);
    }

    #[test]
    fn failed_publish_leaves_the_previous_artifact_intact() {
        let path = tmp("atomic");
        let old = tiny_model(RnnKind::Lstm);
        save(&path, &old.to_packed().unwrap()).unwrap();
        let before = std::fs::read(&path).unwrap();

        // The replacement publish dies at fsync: the destination must be
        // byte-identical to the previous artifact and the temp file gone.
        let fp = FaultPlan::parse("fsync_err=1").unwrap();
        let new = tiny_model(RnnKind::Gru);
        let err = save_with_faults(&path, &new.to_packed().unwrap(), Some(&fp)).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err:#}");
        assert_eq!(fp.injected(), 1);
        assert_eq!(std::fs::read(&path).unwrap(), before, "old artifact must survive");
        let dir = path.parent().unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| {
                n.starts_with(&*path.file_name().unwrap().to_string_lossy())
                    && n.contains(".tmp.")
            })
            .collect();
        assert!(leftovers.is_empty(), "temp files must be cleaned up: {leftovers:?}");
        assert!(load(&path).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_and_bitflipped_publishes_are_refused_at_load() {
        // Torn write: the file ends mid-body, so the end-anchored trailer
        // is gone and the loader refuses before trusting any section.
        let path = tmp("torn");
        let fp = FaultPlan::parse("torn_write=200").unwrap();
        save_with_faults(&path, &tiny_model(RnnKind::Lstm).to_packed().unwrap(), Some(&fp))
            .unwrap();
        assert_eq!(fp.injected(), 1);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 200);
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "trailer");
        std::fs::remove_file(&path).unwrap();

        // Bit flip: the per-section CRC names the damaged section.
        let path = tmp("flip_publish");
        let fp = FaultPlan::parse("bitflip=100:0x20").unwrap();
        save_with_faults(&path, &tiny_model(RnnKind::Lstm).to_packed().unwrap(), Some(&fp))
            .unwrap();
        assert_eq!(fp.injected(), 1);
        assert_eq!(corrupt_section(&load(&path).unwrap_err()), "embedding");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dense_models_refuse_to_publish() {
        let config = LmConfig { kind: RnnKind::Lstm, vocab: 20, hidden: 8, layers: 1 };
        let dense = RnnLm::random(config, 3, PrecisionPolicy::full());
        assert!(dense.to_packed().is_err());
    }
}
