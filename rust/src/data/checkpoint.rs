//! Named-tensor binary checkpoint format, shared with the Layer-2 Python
//! side (`python/compile/tensorio.py`), plus the `AMQS` session-snapshot
//! container used by graceful drain/restore.
//!
//! Tensor layout (little-endian):
//! ```text
//! magic "AMQT" | u32 version | u32 tensor_count
//! per tensor: u32 name_len | name bytes | u32 ndim | u64 dims… | f32 data…
//! ```
//!
//! Session-snapshot layout (little-endian, see [`SessionSnapshot`]):
//! ```text
//! magic "AMQS" | u32 version | u32 model_count
//! per model: u32 name_len | name bytes
//!            u8 kind (0=lstm, 1=gru) | u8×3 pad
//!            u32 layers | u64 hidden | u64 session_count
//!            per session: u64 id
//!                         u32 hist_len | u64 tokens[hist_len]
//!                         f32 state[layers · hidden · (2 lstm | 1 gru)]
//! u32 crc32c of every preceding byte
//! ```

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::model::lm::RnnKind;
use crate::util::crc::crc32c;

const MAGIC: &[u8; 4] = b"AMQT";
const VERSION: u32 = 1;

const SNAP_MAGIC: &[u8; 4] = b"AMQS";
const SNAP_VERSION: u32 = 1;

/// A named tensor: shape + row-major f32 data.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// A checkpoint: ordered map name → tensor.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        self.tensors.insert(name.to_string(), Tensor::new(shape, data));
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // Bulk write of f32 data.
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open checkpoint {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic {:?}", magic);
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut ckpt = Checkpoint::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("tensor name too long ({name_len})");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf8")?;
            let ndim = read_u32(&mut r)? as usize;
            if ndim > 8 {
                bail!("tensor rank too high ({ndim})");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut bytes = vec![0u8; numel * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ckpt.tensors.insert(name, Tensor { shape, data });
        }
        Ok(ckpt)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

// ------------------------------------------------------- session snapshots

/// One drained session: client-chosen id, its capped token history, and the
/// recurrent state flattened to `f32`s (LSTM: per layer `h` then `c`; GRU:
/// per layer `h`). The layout is defined entirely by the owning
/// [`ModelSessions`] header, so restore is a bit-exact memcpy.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionRecord {
    pub id: u64,
    pub history: Vec<usize>,
    pub state: Vec<f32>,
}

/// All drained sessions of one model lane, with enough of the model config
/// to refuse a restore onto a lane with a different shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSessions {
    pub model: String,
    pub kind: RnnKind,
    pub layers: usize,
    pub hidden: usize,
    pub sessions: Vec<SessionRecord>,
}

impl ModelSessions {
    /// Flat `f32` length every session state of this lane must have.
    pub fn state_len(&self) -> usize {
        let per_layer = match self.kind {
            RnnKind::Lstm => 2 * self.hidden,
            RnnKind::Gru => self.hidden,
        };
        self.layers * per_layer
    }
}

/// A drain-time snapshot of every live session, written atomically with a
/// whole-file CRC32C so a crash during drain can never leave a snapshot
/// that restores garbage state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionSnapshot {
    pub models: Vec<ModelSessions>,
}

/// Write `bytes` atomically: same-directory temp file + fsync + rename +
/// best-effort directory fsync (same discipline as `data::amqz::save`).
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = path.file_name().context("snapshot path has no file name")?;
    let tmp = dir.join(format!("{}.tmp.{}", name.to_string_lossy(), std::process::id()));
    let result = (|| -> Result<()> {
        let mut f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        if let Ok(d) = File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Byte cursor for snapshot decoding (unaligned little-endian reads).
struct SnapCursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl SnapCursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let end = self.off.checked_add(n).context("snapshot field overflows")?;
        ensure!(end <= self.bytes.len(), "snapshot truncated");
        let s = &self.bytes[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).context("state size overflows")?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

impl SessionSnapshot {
    fn encode(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        buf.extend_from_slice(SNAP_MAGIC);
        buf.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.models.len() as u32).to_le_bytes());
        for m in &self.models {
            buf.extend_from_slice(&(m.model.len() as u32).to_le_bytes());
            buf.extend_from_slice(m.model.as_bytes());
            let kind = match m.kind {
                RnnKind::Lstm => 0u8,
                RnnKind::Gru => 1u8,
            };
            buf.extend_from_slice(&[kind, 0, 0, 0]);
            buf.extend_from_slice(&(m.layers as u32).to_le_bytes());
            buf.extend_from_slice(&(m.hidden as u64).to_le_bytes());
            buf.extend_from_slice(&(m.sessions.len() as u64).to_le_bytes());
            let want = m.state_len();
            for s in &m.sessions {
                ensure!(
                    s.state.len() == want,
                    "session {} state length {} != lane state length {want}",
                    s.id,
                    s.state.len()
                );
                buf.extend_from_slice(&s.id.to_le_bytes());
                buf.extend_from_slice(&(s.history.len() as u32).to_le_bytes());
                for &t in &s.history {
                    buf.extend_from_slice(&(t as u64).to_le_bytes());
                }
                for &x in &s.state {
                    buf.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
        }
        buf.extend_from_slice(&crc32c(&buf).to_le_bytes());
        Ok(buf)
    }

    /// Atomically write the checksummed snapshot.
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.encode()?)
    }

    /// Load and CRC-verify a snapshot. Any damage — truncation, bit rot, a
    /// torn write that escaped the atomic rename — is refused.
    pub fn load(path: &Path) -> Result<SessionSnapshot> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        ensure!(bytes.len() >= 16, "not a session snapshot (too short)");
        let (body, tail) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let got = crc32c(body);
        ensure!(
            got == stored,
            "session snapshot checksum mismatch (stored {stored:#010x}, computed {got:#010x})"
        );
        let mut c = SnapCursor { bytes: body, off: 0 };
        ensure!(c.take(4)? == SNAP_MAGIC, "not a session snapshot (bad magic)");
        let version = c.u32()?;
        ensure!(version == SNAP_VERSION, "unsupported snapshot version {version}");
        let model_count = c.u32()? as usize;
        let mut models = Vec::with_capacity(model_count.min(1024));
        for _ in 0..model_count {
            let name_len = c.u32()? as usize;
            ensure!(name_len <= 64, "model name too long ({name_len})");
            let name = std::str::from_utf8(c.take(name_len)?)
                .context("model name not utf8")?
                .to_string();
            let kind = match c.take(4)?[0] {
                0 => RnnKind::Lstm,
                1 => RnnKind::Gru,
                other => bail!("unknown model kind tag {other}"),
            };
            let layers = c.u32()? as usize;
            let hidden = usize::try_from(c.u64()?).context("hidden overflows usize")?;
            let session_count = usize::try_from(c.u64()?).context("count overflows usize")?;
            let mut m = ModelSessions { model: name, kind, layers, hidden, sessions: Vec::new() };
            let state_len = m.state_len();
            for _ in 0..session_count {
                let id = c.u64()?;
                let hist_len = c.u32()? as usize;
                let mut history = Vec::with_capacity(hist_len.min(4096));
                for _ in 0..hist_len {
                    history.push(usize::try_from(c.u64()?).context("token overflows usize")?);
                }
                let state = c.f32s(state_len)?;
                m.sessions.push(SessionRecord { id, history, state });
            }
            models.push(m);
        }
        ensure!(c.off == body.len(), "trailing bytes after the snapshot payload");
        Ok(SessionSnapshot { models })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("w", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.insert("b", vec![3], vec![-1.0, 0.0, 1.0]);
        let dir = std::env::temp_dir().join("amq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.amqt");
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(c, l);
    }

    #[test]
    fn missing_tensor_error() {
        let c = Checkpoint::new();
        assert!(c.get("nope").is_err());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = std::env::temp_dir().join("amq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.amqt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    fn snap_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("amqs_unit_{}_{tag}.amqs", std::process::id()))
    }

    #[test]
    fn session_snapshot_roundtrips_bit_exactly() {
        // Awkward floats on purpose: negative zero and a subnormal must
        // survive the trip bit-for-bit (restore is a memcpy, not a parse).
        let snap = SessionSnapshot {
            models: vec![
                ModelSessions {
                    model: "alpha".into(),
                    kind: RnnKind::Lstm,
                    layers: 2,
                    hidden: 3,
                    sessions: vec![
                        SessionRecord {
                            id: 7,
                            history: vec![1, 2, 3],
                            state: vec![-0.0, 1.5e-42, 0.25, -1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0],
                        },
                        SessionRecord { id: 8, history: vec![], state: vec![0.5; 12] },
                    ],
                },
                ModelSessions {
                    model: "beta".into(),
                    kind: RnnKind::Gru,
                    layers: 1,
                    hidden: 4,
                    sessions: vec![SessionRecord {
                        id: 1,
                        history: vec![9],
                        state: vec![0.1, 0.2, 0.3, 0.4],
                    }],
                },
            ],
        };
        let path = snap_path("roundtrip");
        snap.save(&path).unwrap();
        let loaded = SessionSnapshot::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(loaded.models.len(), snap.models.len());
        for (a, b) in loaded.models.iter().zip(&snap.models) {
            assert_eq!((&a.model, a.kind, a.layers, a.hidden), (&b.model, b.kind, b.layers, b.hidden));
            for (x, y) in a.sessions.iter().zip(&b.sessions) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.history, y.history);
                let xb: Vec<u32> = x.state.iter().map(|v| v.to_bits()).collect();
                let yb: Vec<u32> = y.state.iter().map(|v| v.to_bits()).collect();
                assert_eq!(xb, yb, "state must roundtrip bit-exactly");
            }
        }
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let path = snap_path("empty");
        let snap = SessionSnapshot::default();
        snap.save(&path).unwrap();
        assert_eq!(SessionSnapshot::load(&path).unwrap(), snap);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_or_truncated_snapshots_are_refused() {
        let snap = SessionSnapshot {
            models: vec![ModelSessions {
                model: "m".into(),
                kind: RnnKind::Gru,
                layers: 1,
                hidden: 2,
                sessions: vec![SessionRecord { id: 3, history: vec![4, 5], state: vec![1.0, 2.0] }],
            }],
        };
        let path = snap_path("corrupt");
        snap.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        for at in [4, 16, good.len() / 2, good.len() - 2] {
            let mut bad = good.clone();
            bad[at] ^= 0x08;
            std::fs::write(&path, &bad).unwrap();
            let err = SessionSnapshot::load(&path).unwrap_err();
            assert!(err.to_string().contains("checksum mismatch"), "flip at {at}: {err:#}");
        }
        for cut in [0, 3, good.len() / 2, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(SessionSnapshot::load(&path).is_err(), "truncation at {cut}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mismatched_state_length_refuses_to_encode() {
        let snap = SessionSnapshot {
            models: vec![ModelSessions {
                model: "m".into(),
                kind: RnnKind::Lstm,
                layers: 1,
                hidden: 4,
                sessions: vec![SessionRecord { id: 1, history: vec![], state: vec![0.0; 3] }],
            }],
        };
        assert!(snap.save(&snap_path("badlen")).is_err());
    }
}
