//! Named-tensor binary checkpoint format, shared with the Layer-2 Python
//! side (`python/compile/tensorio.py`).
//!
//! Layout (little-endian):
//! ```text
//! magic "AMQT" | u32 version | u32 tensor_count
//! per tensor: u32 name_len | name bytes | u32 ndim | u64 dims… | f32 data…
//! ```

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"AMQT";
const VERSION: u32 = 1;

/// A named tensor: shape + row-major f32 data.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// A checkpoint: ordered map name → tensor.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        self.tensors.insert(name.to_string(), Tensor::new(shape, data));
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // Bulk write of f32 data.
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open checkpoint {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic {:?}", magic);
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut ckpt = Checkpoint::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("tensor name too long ({name_len})");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf8")?;
            let ndim = read_u32(&mut r)? as usize;
            if ndim > 8 {
                bail!("tensor rank too high ({ndim})");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut bytes = vec![0u8; numel * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ckpt.tensors.insert(name, Tensor { shape, data });
        }
        Ok(ckpt)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("w", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.insert("b", vec![3], vec![-1.0, 0.0, 1.0]);
        let dir = std::env::temp_dir().join("amq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.amqt");
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(c, l);
    }

    #[test]
    fn missing_tensor_error() {
        let c = Checkpoint::new();
        assert!(c.get("nope").is_err());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = std::env::temp_dir().join("amq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.amqt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
