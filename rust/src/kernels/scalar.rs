//! Portable scalar count kernel — the reference backend every other
//! backend must match bit-for-bit (trivially: all backends produce the
//! same exact integer mismatch counts; only instruction selection
//! differs).
//!
//! The dataflow is the paper's Appendix A on portable Rust: `u64 ^` +
//! `count_ones`, which LLVM lowers to `xor` + `popcnt` on x86_64. The
//! single entry point is the fused batch-block primitive
//! ([`block_counts`]): one pass over the packed words evaluates every
//! (column, weight-plane, activation-plane) chain of the block, so each
//! weight word is loaded once per word index and the independent
//! XOR+POPCNT chains pipeline. The loop order (word-major, then weight
//! plane, then column, then activation plane) is the fused interleaved
//! order the seam has always used — kept verbatim so the counts, and
//! therefore the shared float reduction downstream, are preserved by
//! construction.

/// Fused batch-block counts, the one scalar count primitive:
///
/// ```text
/// counts[(j·k_w + t)·k_x + s] += Σ_i popcount(w[t][i] ^ x_block[j][s][i])
/// ```
///
/// `w` holds the `k_w` plane slices of one weight row; `x_block[j]` holds
/// the `k_x` plane slices of batch column `j`. All plane slices share one
/// length and every column has the same `k_x`; `counts` is the flat
/// `[column][weight-plane][activation-plane]` accumulator of length
/// `x_block.len() · k_w · k_x`. Accumulates (callers zero the slice).
#[inline]
pub(crate) fn block_counts(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block.first().map_or(0, |c| c.len());
    let wpp = w.first().map_or(0, |p| p.len());
    debug_assert_eq!(counts.len(), x_block.len() * kw * kx);
    for i in 0..wpp {
        for (t, wt) in w.iter().enumerate() {
            let ww = wt[i];
            for (j, xj) in x_block.iter().enumerate() {
                let base = (j * kw + t) * kx;
                for (c, xs) in counts[base..base + kx].iter_mut().zip(xj.iter()) {
                    *c += (ww ^ xs[i]).count_ones();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive pairwise reference: one plane pair at a time.
    fn pair_popcount(a: &[u64], b: &[u64]) -> u32 {
        a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum()
    }

    /// The fused loop must agree with the naive pairwise definition for
    /// every chain of the block, at any (k_w, k_x, B) — including widths
    /// beyond the drivers' MAX_K and the empty cases.
    #[test]
    fn fused_block_matches_pairwise() {
        // Deterministic mixed patterns incl. a tail beyond a 4-word unroll.
        let mk = |seed: u64, n: usize| -> Vec<u64> {
            (0..n)
                .map(|i| seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(i as u32) ^ i as u64)
                .collect()
        };
        for (kw, kx, b, wpp) in [(2, 3, 2, 7), (1, 1, 1, 16), (3, 2, 5, 1), (5, 6, 2, 3)] {
            let wplanes: Vec<Vec<u64>> = (0..kw as u64).map(|t| mk(3 + t, wpp)).collect();
            let xplanes: Vec<Vec<u64>> = (0..(b * kx) as u64).map(|s| mk(11 + s, wpp)).collect();
            let w: Vec<&[u64]> = wplanes.iter().map(|p| &p[..]).collect();
            let cols: Vec<Vec<&[u64]>> = (0..b)
                .map(|j| (0..kx).map(|s| &xplanes[j * kx + s][..]).collect())
                .collect();
            let x_block: Vec<&[&[u64]]> = cols.iter().map(|c| &c[..]).collect();
            let mut counts = vec![0u32; b * kw * kx];
            block_counts(&w, &x_block, &mut counts);
            for j in 0..b {
                for t in 0..kw {
                    for s in 0..kx {
                        assert_eq!(
                            counts[(j * kw + t) * kx + s],
                            pair_popcount(w[t], x_block[j][s]),
                            "kw={kw} kx={kx} b={b} wpp={wpp} j={j} t={t} s={s}"
                        );
                    }
                }
            }
        }
        // Accumulation semantics: a second call adds on top.
        let a = mk(1, 4);
        let bb = mk(2, 4);
        let w: [&[u64]; 1] = [&a];
        let xp: [&[u64]; 1] = [&bb];
        let col: [&[&[u64]]; 1] = [&xp];
        let mut counts = [0u32; 1];
        block_counts(&w, &col, &mut counts);
        let once = counts[0];
        block_counts(&w, &col, &mut counts);
        assert_eq!(counts[0], 2 * once);
    }
}
