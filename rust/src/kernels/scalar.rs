//! Portable scalar count kernels — the reference backend every other
//! backend must match bit-for-bit (trivially: all backends produce the
//! same exact integer mismatch counts; only instruction selection
//! differs).
//!
//! The dataflow is the paper's Appendix A on portable Rust: `u64 ^` +
//! `count_ones`, which LLVM lowers to `xor` + `popcnt` on x86_64. The
//! fused variants evaluate all `k_w · k_x` plane pairs of a weight row in
//! a single pass over the packed words, so each activation word is loaded
//! once per word index and the independent XOR+POPCNT chains pipeline.

use super::backend::MAX_K;

/// `Σ_i popcount(a[i] ^ b[i])`, 4-way unrolled so the popcount units
/// pipeline across independent words.
#[inline]
pub(crate) fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut mism = 0u32;
    let mut i = 0;
    while i + 4 <= a.len() {
        mism += (a[i] ^ b[i]).count_ones()
            + (a[i + 1] ^ b[i + 1]).count_ones()
            + (a[i + 2] ^ b[i + 2]).count_ones()
            + (a[i + 3] ^ b[i + 3]).count_ones();
        i += 4;
    }
    while i < a.len() {
        mism += (a[i] ^ b[i]).count_ones();
        i += 1;
    }
    mism
}

/// Fused single-column counts: one pass over the words, `KW · KX`
/// independent XOR+POPCNT chains, counters in registers.
#[inline]
pub(crate) fn row_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    let wpp = w.first().map_or(0, |p| p.len());
    for i in 0..wpp {
        for t in 0..KW {
            let ww = w[t][i];
            for s in 0..KX {
                counts[t][s] += (ww ^ x[s][i]).count_ones();
            }
        }
    }
}

/// Fused batch-block counts: one load of each weight word serves every
/// column of the block (`xw.len() == counts.len()` columns).
#[inline]
pub(crate) fn block_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    let wpp = w.first().map_or(0, |p| p.len());
    for i in 0..wpp {
        for t in 0..KW {
            let ww = w[t][i];
            for (cj, xj) in counts.iter_mut().zip(xw) {
                for s in 0..KX {
                    cj[t][s] += (ww ^ xj[s][i]).count_ones();
                }
            }
        }
    }
}

/// Runtime-width [`row_counts`]: `w.len() = k_w`, `x.len() = k_x`.
#[inline]
pub(crate) fn row_counts_dyn(w: &[&[u64]], x: &[&[u64]], counts: &mut [[u32; MAX_K]; MAX_K]) {
    let wpp = w.first().map_or(0, |p| p.len());
    for i in 0..wpp {
        for (t, wt) in w.iter().enumerate() {
            let ww = wt[i];
            for (s, xs) in x.iter().enumerate() {
                counts[t][s] += (ww ^ xs[i]).count_ones();
            }
        }
    }
}

/// Runtime-width [`block_counts`]: `xw[j][s]` valid for `s < kx`.
#[inline]
pub(crate) fn block_counts_dyn(
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    let wpp = w.first().map_or(0, |p| p.len());
    for i in 0..wpp {
        for (t, wt) in w.iter().enumerate() {
            let ww = wt[i];
            for (cj, xj) in counts.iter_mut().zip(xw) {
                for (s, c) in cj[t].iter_mut().enumerate().take(kx) {
                    *c += (ww ^ xj[s][i]).count_ones();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fused loops must agree with the naive pairwise definition.
    #[test]
    fn fused_counts_match_pairwise() {
        // Deterministic mixed patterns incl. a tail beyond a 4-word unroll.
        let mk = |seed: u64, n: usize| -> Vec<u64> {
            (0..n).map(|i| seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(i as u32) ^ i as u64).collect()
        };
        let wpp = 7;
        let wplanes: Vec<Vec<u64>> = (0..2u64).map(|t| mk(3 + t, wpp)).collect();
        let xplanes: Vec<Vec<u64>> = (0..3u64).map(|s| mk(11 + s, wpp)).collect();
        let w: [&[u64]; 2] = [&wplanes[0][..], &wplanes[1][..]];
        let x: [&[u64]; 3] = [&xplanes[0][..], &xplanes[1][..], &xplanes[2][..]];
        let mut fused = [[0u32; 3]; 2];
        row_counts::<2, 3>(&w, &x, &mut fused);
        for t in 0..2 {
            for s in 0..3 {
                assert_eq!(fused[t][s], xor_popcount(w[t], x[s]), "t={t} s={s}");
            }
        }
        // Batch block of 2 columns (second column reuses planes rotated).
        let xw: [[&[u64]; 3]; 2] = [x, [&xplanes[2][..], &xplanes[0][..], &xplanes[1][..]]];
        let mut block = [[[0u32; 3]; 2]; 2];
        block_counts::<2, 3>(&w, &xw, &mut block);
        for (j, xj) in xw.iter().enumerate() {
            for t in 0..2 {
                for s in 0..3 {
                    assert_eq!(block[j][t][s], xor_popcount(w[t], xj[s]), "j={j} t={t} s={s}");
                }
            }
        }
        // Dyn variants agree with the const ones.
        let mut dynr = [[0u32; MAX_K]; MAX_K];
        row_counts_dyn(&w, &x, &mut dynr);
        let mut dynb = [[[0u32; MAX_K]; MAX_K]; 2];
        let xw_dyn: Vec<[&[u64]; MAX_K]> = xw
            .iter()
            .map(|xj| [xj[0], xj[1], xj[2], &[][..]])
            .collect();
        block_counts_dyn(&w, &xw_dyn, 3, &mut dynb);
        for t in 0..2 {
            for s in 0..3 {
                assert_eq!(dynr[t][s], fused[t][s]);
                assert_eq!(dynb[0][t][s], block[0][t][s]);
                assert_eq!(dynb[1][t][s], block[1][t][s]);
            }
        }
    }
}
