//! AVX2 backend: XOR + `vpshufb` nibble-LUT popcount behind the single
//! fused batch-block primitive ([`block_counts`]).
//!
//! Two regimes, split at [`HARLEY_SEAL_MIN_WORDS`]:
//!
//! * **Short planes** (the RNN serving shapes: 1024 cols = 16 words per
//!   plane) run the **fused block kernel**: one pass over the word
//!   vectors, holding every `(column, w-plane, x-plane)` chain of the
//!   block as its own 32-byte lane accumulator. Each weight-plane vector
//!   is loaded **once** per word index and XORed against all block
//!   columns; byte popcounts (`vpshufb` low/high nibble lookups, ≤ 8 per
//!   byte) accumulate in `u8` lanes — safe because short planes are < 16
//!   vectors and 15 · 8 = 120 < 256 — so the `vpsadbw` fold and the
//!   horizontal sum are paid **once per chain per row**, outside the word
//!   loop. This is what recovers the SIMD win at the serving shape: the
//!   old pairwise passes paid a full loop + `vpsadbw` per vector + hsum
//!   per plane pair, which at 4 vectors per plane cancelled most of the
//!   vector math. Columns are chunked to the [`FUSED_MAX_CHAINS`] chain
//!   budget (register pressure); a single column at the widest widths may
//!   exceed it and accepts the spills.
//!
//! * **Long planes** keep the Harley–Seal carry-save pass per plane pair
//!   ([`xor_popcount_avx2`]): two CSA levels fold four XOR vectors plus
//!   the carried `ones`/`twos` state so only one byte-popcount is paid
//!   per 1024 bits. Per-pair reduction overhead is amortized over many
//!   vectors there, and the weight planes stay L1-resident across the
//!   `k_w · k_x · B` pairs of the block.
//!
//! Exactness: popcounts are exact integers whatever the instruction mix,
//! so this backend produces the identical mismatch counts as the scalar
//! kernel — the shared float reduction in `kernels::binary` then makes
//! the f32 outputs bit-identical (pinned by `rust/tests/kernel_parity.rs`).
//!
//! This module is normally reached through the [`super::backend`]
//! dispatch with an availability-resolved kernel; as a second line of
//! defense the safe wrapper re-checks AVX2 at runtime (a cached atomic
//! load) and falls back to the scalar kernel — identical counts — so a
//! misused raw `Kernel::Avx2` can never execute AVX2 instructions on a
//! CPU without them.

use core::arch::x86_64::*;

use super::backend::MAX_K;
use super::scalar;

/// Plane length (in words) from which the Harley–Seal pairwise pass takes
/// over from the fused block kernel. Below it the per-pair reduction and
/// carried-state flush dominate; above it carry-save accumulation pays
/// for itself. 64 words = 512 bytes per plane. Derived from the cost
/// model's constant so the `exp::kernel_tables` predictions can never
/// drift from what this kernel actually does.
const HARLEY_SEAL_MIN_WORDS: usize = super::cost::FUSED_SHORT_PLANE_MAX_WORDS as usize;

/// Chain budget (columns × k_w × k_x) per fused-kernel chunk. x86_64 has
/// 16 ymm registers; a budget of 8 keeps the accumulator working set
/// small enough that — after the loops unroll for the actual widths —
/// the LUT, mask, held weight vectors, and most chain accumulators can
/// stay in registers, and whatever does not stays within one hot cache
/// line's worth of stack (W2A2 ⇒ 2 columns per chunk). Widths whose
/// k_w·k_x alone exceeds the budget (e.g. 4×4) run one column per chunk
/// and accept the larger working set — they are not serving shapes.
/// ROADMAP.md flags retuning this against a profiler on real hardware.
const FUSED_MAX_CHAINS: usize = 8;

/// Accumulator slots the fused kernel allocates: a chunk is capped by the
/// chain budget *or* is a single column of up to `MAX_K²` chains,
/// whichever is larger.
const FUSED_ACC_SLOTS: usize = if FUSED_MAX_CHAINS > MAX_K * MAX_K {
    FUSED_MAX_CHAINS
} else {
    MAX_K * MAX_K
};

/// The fused kernel's `u8` lane accumulators hold ≤ 8 per byte per vector
/// and must not overflow before the per-chain fold: the short-plane
/// regime must stay under 31 vectors (31 · 8 = 248 < 256).
const _: () = assert!(HARLEY_SEAL_MIN_WORDS <= 31 * 4);

/// Runtime AVX2 check (cached by std in an atomic — one load + branch).
/// The dispatch layer only hands resolved kernels to this module, but a
/// real check here (not a `debug_assert!` that compiles out in release)
/// is what makes "unavailable falls back to scalar" true even for a
/// misused raw `Kernel::Avx2` on a pre-AVX2 CPU — scalar produces the
/// identical counts, so the fallback is invisible.
#[inline]
fn have_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

/// Fused batch-block counts (AVX2) — the backend's one count primitive;
/// contract as in [`scalar::block_counts`].
#[inline]
pub(crate) fn block_counts(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    if !have_avx2() {
        return scalar::block_counts(w, x_block, counts);
    }
    // SAFETY: AVX2 was detected at runtime just above, so the
    // target-feature contract of the callee holds.
    unsafe { block_counts_avx2(w, x_block, counts) }
}

// ---------------------------------------------------------------------------
// target_feature implementations. All `unsafe fn`s below require AVX2 to
// be present at runtime; slices are read strictly in-bounds via unaligned
// loads.
// ---------------------------------------------------------------------------

/// Byte-wise popcount of a 256-bit vector via the `vpshufb` nibble LUT.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn popcount8(v: __m256i) -> __m256i {
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, mask);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), mask);
    _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
}

/// Carry-save adder: compresses three bit streams into (carry, sum).
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
    let u = _mm256_xor_si256(a, b);
    let h = _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c));
    let l = _mm256_xor_si256(u, c);
    (h, l)
}

/// Load words `i..i+4` of both planes and XOR them.
///
/// # Safety
/// Requires AVX2; `i + 4` must not exceed the planes' length.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn xor_load(a: *const u64, b: *const u64, i: usize) -> __m256i {
    let va = _mm256_loadu_si256(a.add(i) as *const __m256i);
    let vb = _mm256_loadu_si256(b.add(i) as *const __m256i);
    _mm256_xor_si256(va, vb)
}

/// Popcount the bytes of `v` and add the per-64-bit-lane sums into `acc`.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_sad(acc: __m256i, v: __m256i) -> __m256i {
    _mm256_add_epi64(acc, _mm256_sad_epu8(popcount8(v), _mm256_setzero_si256()))
}

/// Horizontal sum of the four u64 lanes.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0].wrapping_add(lanes[1]).wrapping_add(lanes[2]).wrapping_add(lanes[3])
}

/// One-pair XOR-popcount: Harley–Seal carry-save main loop for long
/// planes, `vpshufb`-LUT + `vpsadbw` loop for whole 256-bit vectors,
/// scalar `popcnt` for the last words. The long-plane arm of the block
/// primitive, and the fallback for bit widths beyond `MAX_K`.
///
/// # Safety
/// Requires AVX2; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
unsafe fn xor_popcount_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0usize;
    let mut total_v = _mm256_setzero_si256();
    if n >= HARLEY_SEAL_MIN_WORDS {
        // Main loop: 16 words (4 ymm vectors) per iteration. Two CSA
        // levels fold the four XOR vectors plus the carried ones/twos
        // state so only the `fours` vector is byte-popcounted per
        // iteration (¼ of the popcount work).
        let mut ones = _mm256_setzero_si256();
        let mut twos = _mm256_setzero_si256();
        let mut fours_acc = _mm256_setzero_si256();
        while i + 16 <= n {
            let (twos_a, ones1) = csa(ones, xor_load(pa, pb, i), xor_load(pa, pb, i + 4));
            let (twos_b, ones2) = csa(ones1, xor_load(pa, pb, i + 8), xor_load(pa, pb, i + 12));
            let (fours, twos1) = csa(twos, twos_a, twos_b);
            ones = ones2;
            twos = twos1;
            fours_acc = accumulate_sad(fours_acc, fours);
            i += 16;
        }
        // Flush the carried state with its binary weights:
        // 4·fours + 2·twos + 1·ones, all still as u64×4 lane sums.
        let twos_acc = accumulate_sad(_mm256_setzero_si256(), twos);
        let ones_acc = accumulate_sad(_mm256_setzero_si256(), ones);
        total_v = _mm256_add_epi64(
            _mm256_slli_epi64::<2>(fours_acc),
            _mm256_add_epi64(_mm256_slli_epi64::<1>(twos_acc), ones_acc),
        );
    }
    // Whole vectors (the tail of the HS loop), weight 1.
    while i + 4 <= n {
        total_v = accumulate_sad(total_v, xor_load(pa, pb, i));
        i += 4;
    }
    let mut total = hsum(total_v);
    while i < n {
        total += u64::from((*pa.add(i) ^ *pb.add(i)).count_ones());
        i += 1;
    }
    total as u32
}

/// The block primitive: fused short-plane kernel (columns chunked to the
/// chain budget) or per-pair Harley–Seal passes for long planes. Widths
/// beyond `MAX_K` (no serving shape uses them) take the pairwise arm
/// unconditionally so the fused kernel's accumulator array stays fixed.
///
/// # Safety
/// Requires AVX2; contract as in [`scalar::block_counts`].
#[target_feature(enable = "avx2")]
unsafe fn block_counts_avx2(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block.first().map_or(0, |c| c.len());
    let wpp = w.first().map_or(0, |p| p.len());
    debug_assert_eq!(counts.len(), x_block.len() * kw * kx);
    if kw == 0 || kx == 0 {
        return;
    }
    if wpp >= HARLEY_SEAL_MIN_WORDS || kw > MAX_K || kx > MAX_K {
        // Long planes: one Harley–Seal pass per plane pair. The weight
        // planes stay L1-resident across the k_w·k_x·B pairs, and the
        // per-pair reduction is amortized over ≥ 16 vectors.
        for (j, xj) in x_block.iter().enumerate() {
            for (t, wt) in w.iter().enumerate() {
                for (s, xs) in xj.iter().enumerate() {
                    counts[(j * kw + t) * kx + s] += xor_popcount_avx2(wt, xs);
                }
            }
        }
        return;
    }
    // Short planes: fused kernel over column chunks sized to the chain
    // budget. A single column may exceed the budget at the widest widths
    // (k_w·k_x ≤ MAX_K² = FUSED_ACC_SLOTS accumulator slots cover it).
    let cols_per_chunk = (FUSED_MAX_CHAINS / (kw * kx)).max(1);
    let mut j0 = 0;
    while j0 < x_block.len() {
        let jb = cols_per_chunk.min(x_block.len() - j0);
        block_counts_avx2_short(
            w,
            &x_block[j0..j0 + jb],
            &mut counts[j0 * kw * kx..(j0 + jb) * kw * kx],
        );
        j0 += jb;
    }
}

/// The fused short-plane block kernel: every (column, w-plane, x-plane)
/// chain gets a dedicated `u8`-lane accumulator; one pass over the word
/// vectors loads each weight vector once and each activation vector once
/// per column-plane, XORs, and byte-accumulates the nibble-LUT popcounts.
/// The `vpsadbw` fold + horizontal sum are paid once per chain at the
/// end, never inside the word loop.
///
/// # Safety
/// Requires AVX2; contract as in [`scalar::block_counts`], with
/// `x_block.len() · k_w · k_x ≤ FUSED_ACC_SLOTS`, widths ≤ `MAX_K`, and
/// planes shorter than `HARLEY_SEAL_MIN_WORDS` (u8 lanes must not
/// saturate).
#[target_feature(enable = "avx2")]
unsafe fn block_counts_avx2_short(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block[0].len();
    let wpp = w[0].len();
    debug_assert!(x_block.len() * kw * kx <= FUSED_ACC_SLOTS);
    debug_assert!(wpp < HARLEY_SEAL_MIN_WORDS);
    let mut acc8 = [_mm256_setzero_si256(); FUSED_ACC_SLOTS];
    let mut i = 0usize;
    while i + 4 <= wpp {
        let mut wv = [_mm256_setzero_si256(); MAX_K];
        for (t, wt) in w.iter().enumerate() {
            wv[t] = _mm256_loadu_si256(wt.as_ptr().add(i) as *const __m256i);
        }
        for (j, xj) in x_block.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let xv = _mm256_loadu_si256(xs.as_ptr().add(i) as *const __m256i);
                for (t, &wt) in wv.iter().enumerate().take(kw) {
                    let c = (j * kw + t) * kx + s;
                    acc8[c] = _mm256_add_epi8(acc8[c], popcount8(_mm256_xor_si256(wt, xv)));
                }
            }
        }
        i += 4;
    }
    // Per-chain fold (the only vpsadbw + hsum of the whole block) plus
    // the scalar word tail.
    let tail = i;
    for (j, xj) in x_block.iter().enumerate() {
        for (t, wt) in w.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let c = (j * kw + t) * kx + s;
                let mut total = hsum(_mm256_sad_epu8(acc8[c], _mm256_setzero_si256()));
                for ii in tail..wpp {
                    total += u64::from((wt[ii] ^ xs[ii]).count_ones());
                }
                counts[c] += total as u32;
            }
        }
    }
}
