//! AVX2 backend: XOR + `vpshufb` nibble-LUT popcount with Harley–Seal
//! carry-save accumulation over 256-bit lanes.
//!
//! The pairwise primitive streams both bit planes four `u64` words (one
//! ymm register) at a time. For long planes, blocks of four vectors are
//! first compressed with a carry-save-adder tree (Harley–Seal): two CSAs
//! fold four XOR results plus the carried `ones`/`twos` state into one
//! `fours` vector, so only **one** byte-popcount (`vpshufb` low/high
//! nibble lookups + `vpsadbw` horizontal sum) is paid per 1024 bits
//! instead of four. The carried state and any remaining vectors/words are
//! popcounted once at the end with their binary weights (4·fours + 2·twos
//! + 1·ones + tail). Short planes (most RNN shapes: 1024 cols = 16 words)
//! skip the carry-save stage and run the plain LUT + `vpsadbw` loop,
//! which is lower-latency there.
//!
//! Exactness: popcounts are exact integers whatever the instruction mix,
//! so this backend produces the identical mismatch counts as the scalar
//! kernel — the shared float reduction in `kernels::binary` then makes
//! the f32 outputs bit-identical (pinned by `rust/tests/kernel_parity.rs`).
//!
//! This module is normally reached through the [`super::backend`]
//! dispatch with an availability-resolved kernel; as a second line of
//! defense every safe wrapper re-checks AVX2 at runtime (a cached atomic
//! load) and falls back to the scalar kernel — identical counts — so a
//! misused raw `Kernel::Avx2` can never execute AVX2 instructions on a
//! CPU without them.

use core::arch::x86_64::*;

use super::backend::MAX_K;
use super::scalar;

/// Runtime AVX2 check (cached by std in an atomic — one load + branch).
/// The dispatch layer only hands resolved kernels to this module, but a
/// real check here (not a `debug_assert!` that compiles out in release)
/// is what makes "unavailable falls back to scalar" true even for a
/// misused raw `Kernel::Avx2` on a pre-AVX2 CPU — scalar produces the
/// identical counts, so the fallback is invisible.
#[inline]
fn have_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

/// `Σ_i popcount(a[i] ^ b[i])` (AVX2).
#[inline]
pub(crate) fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    if !have_avx2() {
        return scalar::xor_popcount(a, b);
    }
    // SAFETY: AVX2 was detected at runtime just above, so the
    // target-feature contract of the callee holds.
    unsafe { xor_popcount_avx2(a, b) }
}

/// Fused single-column counts (AVX2): pairwise Harley–Seal passes — the
/// weight row stays in L1 across the `KW · KX` plane pairs.
#[inline]
pub(crate) fn row_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    if !have_avx2() {
        return scalar::row_counts::<KW, KX>(w, x, counts);
    }
    // SAFETY: AVX2 was detected at runtime just above.
    unsafe { row_counts_avx2::<KW, KX>(w, x, counts) }
}

/// Fused batch-block counts (AVX2).
#[inline]
pub(crate) fn block_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    if !have_avx2() {
        return scalar::block_counts::<KW, KX>(w, xw, counts);
    }
    // SAFETY: AVX2 was detected at runtime just above.
    unsafe { block_counts_avx2::<KW, KX>(w, xw, counts) }
}

/// Runtime-width `row_counts` (AVX2).
#[inline]
pub(crate) fn row_counts_dyn(w: &[&[u64]], x: &[&[u64]], counts: &mut [[u32; MAX_K]; MAX_K]) {
    if !have_avx2() {
        return scalar::row_counts_dyn(w, x, counts);
    }
    // SAFETY: AVX2 was detected at runtime just above.
    unsafe { row_counts_dyn_avx2(w, x, counts) }
}

/// Runtime-width `block_counts` (AVX2).
#[inline]
pub(crate) fn block_counts_dyn(
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    if !have_avx2() {
        return scalar::block_counts_dyn(w, xw, kx, counts);
    }
    // SAFETY: AVX2 was detected at runtime just above.
    unsafe { block_counts_dyn_avx2(w, xw, kx, counts) }
}

// ---------------------------------------------------------------------------
// target_feature implementations. All `unsafe fn`s below require AVX2 to
// be present at runtime; slices are read strictly in-bounds via unaligned
// loads.
// ---------------------------------------------------------------------------

/// Byte-wise popcount of a 256-bit vector via the `vpshufb` nibble LUT.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn popcount8(v: __m256i) -> __m256i {
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, mask);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), mask);
    _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
}

/// Carry-save adder: compresses three bit streams into (carry, sum).
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
    let u = _mm256_xor_si256(a, b);
    let h = _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c));
    let l = _mm256_xor_si256(u, c);
    (h, l)
}

/// Load words `i..i+4` of both planes and XOR them.
///
/// # Safety
/// Requires AVX2; `i + 4` must not exceed the planes' length.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn xor_load(a: *const u64, b: *const u64, i: usize) -> __m256i {
    let va = _mm256_loadu_si256(a.add(i) as *const __m256i);
    let vb = _mm256_loadu_si256(b.add(i) as *const __m256i);
    _mm256_xor_si256(va, vb)
}

/// Popcount the bytes of `v` and add the per-64-bit-lane sums into `acc`.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_sad(acc: __m256i, v: __m256i) -> __m256i {
    _mm256_add_epi64(acc, _mm256_sad_epu8(popcount8(v), _mm256_setzero_si256()))
}

/// Horizontal sum of the four u64 lanes.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0].wrapping_add(lanes[1]).wrapping_add(lanes[2]).wrapping_add(lanes[3])
}

/// Plane length (in words) from which the Harley–Seal main loop engages.
/// Below it the carried-state flush would dominate; the plain LUT loop is
/// both lower-latency and fewer ops there. 64 words = 512 bytes, the
/// regime where carry-save accumulation starts to pay for itself.
const HARLEY_SEAL_MIN_WORDS: usize = 64;

/// The XOR-popcount over two equal-length word slices: Harley–Seal
/// carry-save main loop for long planes, `vpshufb`-LUT + `vpsadbw` loop
/// for whole 256-bit vectors, scalar `popcnt` for the last words.
///
/// # Safety
/// Requires AVX2; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
unsafe fn xor_popcount_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0usize;
    let mut total_v = _mm256_setzero_si256();
    if n >= HARLEY_SEAL_MIN_WORDS {
        // Main loop: 16 words (4 ymm vectors) per iteration. Two CSA
        // levels fold the four XOR vectors plus the carried ones/twos
        // state so only the `fours` vector is byte-popcounted per
        // iteration (¼ of the popcount work).
        let mut ones = _mm256_setzero_si256();
        let mut twos = _mm256_setzero_si256();
        let mut fours_acc = _mm256_setzero_si256();
        while i + 16 <= n {
            let (twos_a, ones1) = csa(ones, xor_load(pa, pb, i), xor_load(pa, pb, i + 4));
            let (twos_b, ones2) = csa(ones1, xor_load(pa, pb, i + 8), xor_load(pa, pb, i + 12));
            let (fours, twos1) = csa(twos, twos_a, twos_b);
            ones = ones2;
            twos = twos1;
            fours_acc = accumulate_sad(fours_acc, fours);
            i += 16;
        }
        // Flush the carried state with its binary weights:
        // 4·fours + 2·twos + 1·ones, all still as u64×4 lane sums.
        let twos_acc = accumulate_sad(_mm256_setzero_si256(), twos);
        let ones_acc = accumulate_sad(_mm256_setzero_si256(), ones);
        total_v = _mm256_add_epi64(
            _mm256_slli_epi64::<2>(fours_acc),
            _mm256_add_epi64(_mm256_slli_epi64::<1>(twos_acc), ones_acc),
        );
    }
    // Whole vectors (short planes, and the tail of the HS loop), weight 1.
    while i + 4 <= n {
        total_v = accumulate_sad(total_v, xor_load(pa, pb, i));
        i += 4;
    }
    let mut total = hsum(total_v);
    while i < n {
        total += u64::from((*pa.add(i) ^ *pb.add(i)).count_ones());
        i += 1;
    }
    total as u32
}

/// # Safety
/// Requires AVX2; all plane slices share one length.
#[target_feature(enable = "avx2")]
unsafe fn row_counts_avx2<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    for (ct, wt) in counts.iter_mut().zip(w) {
        for (c, xs) in ct.iter_mut().zip(x) {
            *c += xor_popcount_avx2(wt, xs);
        }
    }
}

/// # Safety
/// Requires AVX2; all plane slices share one length.
#[target_feature(enable = "avx2")]
unsafe fn block_counts_avx2<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    for (cj, xj) in counts.iter_mut().zip(xw) {
        row_counts_avx2::<KW, KX>(w, xj, cj);
    }
}

/// # Safety
/// Requires AVX2; all plane slices share one length.
#[target_feature(enable = "avx2")]
unsafe fn row_counts_dyn_avx2(w: &[&[u64]], x: &[&[u64]], counts: &mut [[u32; MAX_K]; MAX_K]) {
    for (ct, wt) in counts.iter_mut().zip(w) {
        for (c, xs) in ct.iter_mut().zip(x) {
            *c += xor_popcount_avx2(wt, xs);
        }
    }
}

/// # Safety
/// Requires AVX2; `xw[j][s]` valid for `s < kx`.
#[target_feature(enable = "avx2")]
unsafe fn block_counts_dyn_avx2(
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    for (cj, xj) in counts.iter_mut().zip(xw) {
        row_counts_dyn_avx2(w, &xj[..kx], cj);
    }
}
