//! NEON backend (aarch64): XOR + `vcntq_u8` byte popcount with a widening
//! `vpaddlq`/`vpadalq` reduction.
//!
//! The pairwise primitive streams both bit planes two `u64` words (one
//! 128-bit vector) at a time. Byte popcounts (`vcntq_u8`, ≤ 8 per byte)
//! are accumulated in a `u8x16` register for up to 31 vectors (31 · 8 =
//! 248 < 256, no overflow), then folded into a `u64x2` accumulator with
//! the pairwise widening adds — so the expensive widening chain is paid
//! once per ~4 KiB of plane data, not per vector.
//!
//! Exactness: popcounts are exact integers, so this backend produces the
//! identical mismatch counts as the scalar kernel; the shared float
//! reduction in `kernels::binary` then makes the f32 outputs bit-identical
//! (pinned by `rust/tests/kernel_parity.rs`).
//!
//! NEON is baseline on aarch64, so [`super::backend::Kernel::Neon`] is
//! always available there; this module is compiled only for that arch.

use core::arch::aarch64::*;

use super::backend::MAX_K;

/// Max 128-bit vectors whose byte popcounts fit a `u8` accumulator.
const U8_BLOCK_VECS: usize = 31;

/// `Σ_i popcount(a[i] ^ b[i])` (NEON).
#[inline]
pub(crate) fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: NEON is a baseline feature of every aarch64 target this
    // module is compiled for (see Kernel::is_available).
    unsafe { xor_popcount_neon(a, b) }
}

/// Fused single-column counts (NEON): pairwise passes — the weight row
/// stays in L1 across the `KW · KX` plane pairs.
#[inline]
pub(crate) fn row_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    // SAFETY: NEON is baseline on aarch64 (see xor_popcount).
    unsafe { row_counts_neon::<KW, KX>(w, x, counts) }
}

/// Fused batch-block counts (NEON).
#[inline]
pub(crate) fn block_counts<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    // SAFETY: NEON is baseline on aarch64 (see xor_popcount).
    unsafe { block_counts_neon::<KW, KX>(w, xw, counts) }
}

/// Runtime-width `row_counts` (NEON).
#[inline]
pub(crate) fn row_counts_dyn(w: &[&[u64]], x: &[&[u64]], counts: &mut [[u32; MAX_K]; MAX_K]) {
    // SAFETY: NEON is baseline on aarch64 (see xor_popcount).
    unsafe { row_counts_dyn_neon(w, x, counts) }
}

/// Runtime-width `block_counts` (NEON).
#[inline]
pub(crate) fn block_counts_dyn(
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    // SAFETY: NEON is baseline on aarch64 (see xor_popcount).
    unsafe { block_counts_dyn_neon(w, xw, kx, counts) }
}

/// The blocked XOR-popcount over two equal-length word slices.
///
/// # Safety
/// Requires NEON; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
unsafe fn xor_popcount_neon(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0usize; // word index
    let mut total = vdupq_n_u64(0);
    while i + 2 <= n {
        // One u8x16 accumulator per block of ≤ 31 vectors (no overflow).
        let block_end = n.min(i + 2 * U8_BLOCK_VECS);
        let mut acc8 = vdupq_n_u8(0);
        while i + 2 <= block_end {
            let va = vld1q_u8(pa.add(i) as *const u8);
            let vb = vld1q_u8(pb.add(i) as *const u8);
            acc8 = vaddq_u8(acc8, vcntq_u8(veorq_u8(va, vb)));
            i += 2;
        }
        total = vpadalq_u32(total, vpaddlq_u16(vpaddlq_u8(acc8)));
    }
    let mut sum = vaddvq_u64(total);
    while i < n {
        sum += u64::from((*pa.add(i) ^ *pb.add(i)).count_ones());
        i += 1;
    }
    sum as u32
}

/// # Safety
/// Requires NEON; all plane slices share one length.
#[target_feature(enable = "neon")]
unsafe fn row_counts_neon<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    for (ct, wt) in counts.iter_mut().zip(w) {
        for (c, xs) in ct.iter_mut().zip(x) {
            *c += xor_popcount_neon(wt, xs);
        }
    }
}

/// # Safety
/// Requires NEON; all plane slices share one length.
#[target_feature(enable = "neon")]
unsafe fn block_counts_neon<const KW: usize, const KX: usize>(
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    for (cj, xj) in counts.iter_mut().zip(xw) {
        row_counts_neon::<KW, KX>(w, xj, cj);
    }
}

/// # Safety
/// Requires NEON; all plane slices share one length.
#[target_feature(enable = "neon")]
unsafe fn row_counts_dyn_neon(w: &[&[u64]], x: &[&[u64]], counts: &mut [[u32; MAX_K]; MAX_K]) {
    for (ct, wt) in counts.iter_mut().zip(w) {
        for (c, xs) in ct.iter_mut().zip(x) {
            *c += xor_popcount_neon(wt, xs);
        }
    }
}

/// # Safety
/// Requires NEON; `xw[j][s]` valid for `s < kx`.
#[target_feature(enable = "neon")]
unsafe fn block_counts_dyn_neon(
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    for (cj, xj) in counts.iter_mut().zip(xw) {
        row_counts_dyn_neon(w, &xj[..kx], cj);
    }
}
