//! NEON backend (aarch64): XOR + `vcntq_u8` byte popcount behind the
//! single fused batch-block primitive ([`block_counts`]).
//!
//! The fused kernel walks the planes in `u8`-blocks of up to
//! [`U8_BLOCK_VECS`] 128-bit vectors (31 · 8 = 248 < 256, no byte
//! overflow). Within a block, every `(column, w-plane, x-plane)` chain of
//! the batch block keeps its own `u8x16` accumulator: each weight-plane
//! vector is loaded **once** per word index and XORed against all block
//! columns, and `vcntq_u8` byte popcounts accumulate with plain
//! `vaddq_u8`. The widening fold (`vaddlvq_u8`) is paid once per chain
//! per block — never inside the word loop — which is what recovers the
//! SIMD win at short serving planes where the old per-pair passes spent
//! most of their time in per-pair reductions. Columns are chunked so at
//! most [`FUSED_MAX_CHAINS`] accumulators are live at once.
//!
//! Exactness: popcounts are exact integers, so this backend produces the
//! identical mismatch counts as the scalar kernel; the shared float
//! reduction in `kernels::binary` then makes the f32 outputs bit-identical
//! (pinned by `rust/tests/kernel_parity.rs`).
//!
//! NEON is baseline on aarch64, so [`super::backend::Kernel::Neon`] is
//! always available there; this module is compiled only for that arch.

use core::arch::aarch64::*;

use super::backend::MAX_K;

/// Max 128-bit vectors whose byte popcounts fit a `u8` accumulator.
const U8_BLOCK_VECS: usize = 31;

/// Most chains (columns × k_w × k_x) the fused kernel keeps live at once;
/// columns are chunked to fit.
const FUSED_MAX_CHAINS: usize = 16;

/// Fused batch-block counts (NEON) — the backend's one count primitive;
/// contract as in [`super::scalar::block_counts`].
#[inline]
pub(crate) fn block_counts(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    // SAFETY: NEON is a baseline feature of every aarch64 target this
    // module is compiled for (see Kernel::is_available).
    unsafe { block_counts_neon(w, x_block, counts) }
}

/// One-pair XOR-popcount — the fallback for bit widths beyond `MAX_K`.
///
/// # Safety
/// Requires NEON; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
unsafe fn xor_popcount_neon(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0usize; // word index
    let mut total = vdupq_n_u64(0);
    while i + 2 <= n {
        // One u8x16 accumulator per block of ≤ 31 vectors (no overflow).
        let block_end = n.min(i + 2 * U8_BLOCK_VECS);
        let mut acc8 = vdupq_n_u8(0);
        while i + 2 <= block_end {
            let va = vld1q_u8(pa.add(i) as *const u8);
            let vb = vld1q_u8(pb.add(i) as *const u8);
            acc8 = vaddq_u8(acc8, vcntq_u8(veorq_u8(va, vb)));
            i += 2;
        }
        total = vpadalq_u32(total, vpaddlq_u16(vpaddlq_u8(acc8)));
    }
    let mut sum = vaddvq_u64(total);
    while i < n {
        sum += u64::from((*pa.add(i) ^ *pb.add(i)).count_ones());
        i += 1;
    }
    sum as u32
}

/// The block primitive: fused chains for the table widths, per-pair
/// passes only for widths beyond `MAX_K` (so the fused kernel's
/// accumulator array stays fixed).
///
/// # Safety
/// Requires NEON; contract as in [`super::scalar::block_counts`].
#[target_feature(enable = "neon")]
unsafe fn block_counts_neon(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block.first().map_or(0, |c| c.len());
    debug_assert_eq!(counts.len(), x_block.len() * kw * kx);
    if kw == 0 || kx == 0 {
        return;
    }
    if kw > MAX_K || kx > MAX_K {
        for (j, xj) in x_block.iter().enumerate() {
            for (t, wt) in w.iter().enumerate() {
                for (s, xs) in xj.iter().enumerate() {
                    counts[(j * kw + t) * kx + s] += xor_popcount_neon(wt, xs);
                }
            }
        }
        return;
    }
    // Column chunks sized to the chain budget (k_w·k_x ≤ MAX_K² =
    // FUSED_MAX_CHAINS, so at least one column always fits).
    let cols_per_chunk = (FUSED_MAX_CHAINS / (kw * kx)).max(1);
    let mut j0 = 0;
    while j0 < x_block.len() {
        let jb = cols_per_chunk.min(x_block.len() - j0);
        block_counts_neon_fused(
            w,
            &x_block[j0..j0 + jb],
            &mut counts[j0 * kw * kx..(j0 + jb) * kw * kx],
        );
        j0 += jb;
    }
}

/// The fused block kernel: per-chain `u8x16` accumulators over ≤ 31
/// vector blocks, widening fold once per chain per block, scalar word
/// tail.
///
/// # Safety
/// Requires NEON; contract as in [`super::scalar::block_counts`], with
/// `x_block.len() · k_w · k_x ≤ FUSED_MAX_CHAINS` and widths ≤ `MAX_K`.
#[target_feature(enable = "neon")]
unsafe fn block_counts_neon_fused(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block[0].len();
    let wpp = w[0].len();
    debug_assert!(x_block.len() * kw * kx <= FUSED_MAX_CHAINS);
    let mut i = 0usize; // word index
    while i + 2 <= wpp {
        let block_end = wpp.min(i + 2 * U8_BLOCK_VECS);
        let mut acc8 = [vdupq_n_u8(0); FUSED_MAX_CHAINS];
        while i + 2 <= block_end {
            let mut wv = [vdupq_n_u8(0); MAX_K];
            for (t, wt) in w.iter().enumerate() {
                wv[t] = vld1q_u8(wt.as_ptr().add(i) as *const u8);
            }
            for (j, xj) in x_block.iter().enumerate() {
                for (s, xs) in xj.iter().enumerate() {
                    let xv = vld1q_u8(xs.as_ptr().add(i) as *const u8);
                    for (t, &wt) in wv.iter().enumerate().take(kw) {
                        let c = (j * kw + t) * kx + s;
                        acc8[c] = vaddq_u8(acc8[c], vcntq_u8(veorq_u8(wt, xv)));
                    }
                }
            }
            i += 2;
        }
        // Widening fold, once per chain per u8-block: every byte is
        // ≤ 248, so the across-vector sum ≤ 3968 fits vaddlv's u16.
        for (c, &a8) in acc8.iter().enumerate().take(x_block.len() * kw * kx) {
            counts[c] += u32::from(vaddlvq_u8(a8));
        }
    }
    // Scalar word tail, per chain.
    let tail = i;
    for (j, xj) in x_block.iter().enumerate() {
        for (t, wt) in w.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let c = (j * kw + t) * kx + s;
                for ii in tail..wpp {
                    counts[c] += (wt[ii] ^ xs[ii]).count_ones();
                }
            }
        }
    }
}
