//! Compute kernels for the inference hot path.
//!
//! * [`dense`] — full-precision f32 GEMV/GEMM baselines (the stand-in for
//!   the paper's MKL comparison, single-threaded like Appendix A).
//! * [`binary`] — the paper's Appendix-A contribution: bit-packed
//!   XNOR + popcount matrix–vector products over multi-bit quantized
//!   operands, including the **online activation quantization** step whose
//!   cost Table 6 breaks out.
//! * [`backend`] — runtime-dispatched kernel backends behind **one fused
//!   batch-block primitive** (`block_counts(w, x_block, counts)`): the
//!   portable scalar reference ([`scalar`]), AVX2 (`vpshufb` nibble-LUT
//!   popcount; per-chain byte accumulators on short planes, Harley–Seal
//!   carry-save on long ones — `avx2`, x86_64), AVX-512 (two arms behind
//!   runtime detection: native `vpopcntq` lane popcount on
//!   `avx512vpopcntdq` hardware, fused at every plane length, or a
//!   512-bit LUT + Harley–Seal fallback on `avx512f+avx512bw` —
//!   `avx512`, x86_64), and NEON (`vcntq_u8` fused block kernel —
//!   `neon`, aarch64). Selection order: forced choice (`--kernel` /
//!   `server.kernel`) > `AMQ_KERNEL` env > feature detection (AVX-512
//!   before AVX2). Every backend is bit-exact against scalar
//!   (`rust/tests/kernel_parity.rs`); a new backend is exactly one
//!   function.
//! * [`cost`] — the analytic operation-count model of §3/§4 (binary vs
//!   non-binary op counts, theoretical speedup γ) plus the block-kernel
//!   micro-model (fused block vs pairwise plane passes) and the
//!   cache-tiling term (L2 detection/`AMQ_L2_KB` override, batch-tile
//!   width, predicted DRAM-traffic advantage) that sizes
//!   [`binary::PreparedGemm`]'s column tiles.
//!
//! **The tiling layer** lives above the count primitive, in
//! [`binary::PreparedGemm::gemm_rows`]: batch columns are tiled so one
//! tile's packed activation planes stay L2-resident while every weight
//! row streams over them once, with software prefetch of the next row's
//! planes (x86_64; no-op elsewhere). Tiling only reorders **whole output
//! elements** — each element's counts still come from exactly one
//! `block_counts` call and the float reduction is element-local — so
//! every backend stays bit-exact at any tile size (pinned across
//! `AMQ_L2_KB` overrides by the parity suite).

pub mod backend;
pub mod binary;
pub mod cost;
pub mod dense;
pub(crate) mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx512;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

pub use backend::Kernel;
