//! Compute kernels for the inference hot path.
//!
//! * [`dense`] — full-precision f32 GEMV/GEMM baselines (the stand-in for
//!   the paper's MKL comparison, single-threaded like Appendix A).
//! * [`binary`] — the paper's Appendix-A contribution: bit-packed
//!   XNOR + popcount matrix–vector products over multi-bit quantized
//!   operands, including the **online activation quantization** step whose
//!   cost Table 6 breaks out.
//! * [`cost`] — the analytic operation-count model of §3/§4 (binary vs
//!   non-binary op counts, theoretical speedup γ).

pub mod binary;
pub mod cost;
pub mod dense;
