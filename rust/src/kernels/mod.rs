//! Compute kernels for the inference hot path.
//!
//! * [`dense`] — full-precision f32 GEMV/GEMM baselines (the stand-in for
//!   the paper's MKL comparison, single-threaded like Appendix A).
//! * [`binary`] — the paper's Appendix-A contribution: bit-packed
//!   XNOR + popcount matrix–vector products over multi-bit quantized
//!   operands, including the **online activation quantization** step whose
//!   cost Table 6 breaks out.
//! * [`backend`] — runtime-dispatched kernel backends behind **one fused
//!   batch-block primitive** (`block_counts(w, x_block, counts)`): the
//!   portable scalar reference ([`scalar`]), AVX2 (`vpshufb` nibble-LUT
//!   popcount; per-chain byte accumulators on short planes, Harley–Seal
//!   carry-save on long ones — `avx2`, x86_64), and NEON (`vcntq_u8`
//!   fused block kernel — `neon`, aarch64). Selection order: forced
//!   choice (`--kernel` / `server.kernel`) > `AMQ_KERNEL` env > feature
//!   detection. Every backend is bit-exact against scalar
//!   (`rust/tests/kernel_parity.rs`); a new backend is exactly one
//!   function.
//! * [`cost`] — the analytic operation-count model of §3/§4 (binary vs
//!   non-binary op counts, theoretical speedup γ) plus the block-kernel
//!   micro-model (fused block vs pairwise plane passes).

pub mod backend;
pub mod binary;
pub mod cost;
pub mod dense;
pub(crate) mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

pub use backend::Kernel;
