//! The analytic cost model of §3–§4 of the paper.
//!
//! For quantizing `w ∈ ℝⁿ` to `k` bits with `T` alternating cycles:
//! `2Tk²n` binary + `2(T+1)kn` non-binary operations.
//!
//! For the quantized product between a `k_w`-bit `m×n` matrix and a
//! `k_h`-bit vector: `2·k_w·k_h·m·n + 4·k_h²·n` binary and
//! `6·k_h·n + 2·k_w·k_h·m` non-binary operations, giving the theoretical
//! speedup over the `2mn`-op full-precision product (binary ops discounted
//! 32×):
//!
//! ```text
//! γ = 2mn / ( (2·k_w·k_h·m·n + 4·k_h²·n)/32 + 6·k_h·n + 2·k_w·k_h·m )
//! ```

/// Operation counts for quantizing a length-`n` vector to `k` bits with `T`
/// alternating cycles (includes the greedy init's `2kn`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn quantization_cost(n: u64, k: u64, t: u64) -> QuantCost {
    QuantCost {
        binary_ops: 2 * t * k * k * n,
        nonbinary_ops: 2 * (t + 1) * k * n,
    }
}

/// Operation counts for the quantized `m×n` GEMV (weights `k_w` bits,
/// activations `k_h` bits, online activation quantization with `T = 2`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemvCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn gemv_cost(m: u64, n: u64, k_w: u64, k_h: u64) -> GemvCost {
    GemvCost {
        binary_ops: 2 * k_w * k_h * m * n + 4 * k_h * k_h * n,
        nonbinary_ops: 6 * k_h * n + 2 * k_w * k_h * m,
    }
}

/// The paper's theoretical acceleration γ over a full-precision GEMV,
/// counting one binary op as 1/32 of a non-binary op.
pub fn theoretical_speedup(m: u64, n: u64, k_w: u64, k_h: u64) -> f64 {
    let fp_ops = (2 * m * n) as f64;
    let c = gemv_cost(m, n, k_w, k_h);
    fp_ops / (c.binary_ops as f64 / 32.0 + c.nonbinary_ops as f64)
}

/// Memory saving factor for a `k`-bit row-quantized `m×n` f32 matrix
/// (packed planes + per-row coefficients).
pub fn memory_saving(m: u64, n: u64, k: u64) -> f64 {
    let dense = (m * n * 32) as f64;
    let packed = (m * k * n) as f64 + (m * k * 32) as f64;
    dense / packed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_gamma_examples() {
        // §4: for W_h ∈ R^{4096×1024}, γ ≈ 7.5 at (2,2) and ≈ 3.5 at (3,3).
        let g22 = theoretical_speedup(4096, 1024, 2, 2);
        let g33 = theoretical_speedup(4096, 1024, 3, 3);
        assert!((7.0..8.0).contains(&g22), "γ(2,2) = {g22}");
        assert!((3.2..3.8).contains(&g33), "γ(3,3) = {g33}");
    }

    #[test]
    fn memory_saving_matches_abstract() {
        // Abstract: ~16× at 2 bits, ~10.5× at 3 bits.
        let m2 = memory_saving(4096, 1024, 2);
        let m3 = memory_saving(4096, 1024, 3);
        assert!((15.0..16.1).contains(&m2), "2-bit saving {m2}");
        assert!((10.0..11.0).contains(&m3), "3-bit saving {m3}");
    }

    #[test]
    fn quant_cost_formula() {
        // §3: 2Tk²n binary, 2(T+1)kn non-binary.
        let c = quantization_cost(1024, 2, 2);
        assert_eq!(c.binary_ops, 2 * 2 * 4 * 1024);
        assert_eq!(c.nonbinary_ops, 2 * 3 * 2 * 1024);
    }

    #[test]
    fn speedup_decreases_with_bits() {
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let g = theoretical_speedup(4096, 1024, k, k);
            assert!(g < prev);
            prev = g;
        }
    }

    #[test]
    fn softmax_layer_shape_still_accelerates() {
        // Table 6's larger case: 42000×1024.
        let g = theoretical_speedup(42000, 1024, 2, 2);
        assert!(g > 7.0, "γ = {g}");
    }
}
