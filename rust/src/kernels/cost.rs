//! The analytic cost model of §3–§4 of the paper.
//!
//! For quantizing `w ∈ ℝⁿ` to `k` bits with `T` alternating cycles:
//! `2Tk²n` binary + `2(T+1)kn` non-binary operations.
//!
//! For the quantized product between a `k_w`-bit `m×n` matrix and a
//! `k_h`-bit vector: `2·k_w·k_h·m·n + 4·k_h²·n` binary and
//! `6·k_h·n + 2·k_w·k_h·m` non-binary operations, giving the theoretical
//! speedup over the `2mn`-op full-precision product (binary ops discounted
//! 32×):
//!
//! ```text
//! γ = 2mn / ( (2·k_w·k_h·m·n + 4·k_h²·n)/32 + 6·k_h·n + 2·k_w·k_h·m )
//! ```

/// Operation counts for quantizing a length-`n` vector to `k` bits with `T`
/// alternating cycles (includes the greedy init's `2kn`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn quantization_cost(n: u64, k: u64, t: u64) -> QuantCost {
    QuantCost {
        binary_ops: 2 * t * k * k * n,
        nonbinary_ops: 2 * (t + 1) * k * n,
    }
}

/// Operation counts for the quantized `m×n` GEMV (weights `k_w` bits,
/// activations `k_h` bits, online activation quantization with `T = 2`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemvCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn gemv_cost(m: u64, n: u64, k_w: u64, k_h: u64) -> GemvCost {
    GemvCost {
        binary_ops: 2 * k_w * k_h * m * n + 4 * k_h * k_h * n,
        nonbinary_ops: 6 * k_h * n + 2 * k_w * k_h * m,
    }
}

/// The paper's theoretical acceleration γ over a full-precision GEMV,
/// counting one binary op as 1/32 of a non-binary op.
pub fn theoretical_speedup(m: u64, n: u64, k_w: u64, k_h: u64) -> f64 {
    let fp_ops = (2 * m * n) as f64;
    let c = gemv_cost(m, n, k_w, k_h);
    fp_ops / (c.binary_ops as f64 / 32.0 + c.nonbinary_ops as f64)
}

/// Memory saving factor for a `k`-bit row-quantized `m×n` f32 matrix
/// (packed planes + per-row coefficients).
pub fn memory_saving(m: u64, n: u64, k: u64) -> f64 {
    let dense = (m * n * 32) as f64;
    let packed = (m * k * n) as f64 + (m * k * 32) as f64;
    dense / packed
}

// ---------------------------------------------------------------------------
// Block-kernel micro-model: fused batch-block vs pairwise plane passes.
//
// The §3/§4 model above counts *binary ops* and so cannot see why a SIMD
// backend used to lose at short planes: the old pairwise decomposition
// paid a full pass — loop setup, per-vector `vpsadbw` folds, and a
// horizontal sum — per (column, w-plane, x-plane) chain, while a plane of
// the serving shape (1024 cols) is only 4 × 256-bit vectors of payload.
// The fused block kernel loads each weight vector once per word index,
// keeps one byte-lane accumulator per chain, and pays the fold + hsum
// once per chain per row. This model counts both layouts in SIMD-op
// units so `exp::kernel_tables` can print a predicted fused-vs-pairwise
// ratio next to the measured one.
// ---------------------------------------------------------------------------

/// Words per plane at which the AVX2 backend — and the AVX-512 LUT arm —
/// switch the block primitive from the fused short-plane kernel to
/// Harley–Seal pairwise passes.
/// This is the **single source of truth**: `kernels::avx2` and
/// `kernels::avx512` derive their `HARLEY_SEAL_MIN_WORDS` from it, so
/// model and kernel cannot drift. Beyond it, fused and pairwise are the
/// same code path for those arms and the predicted advantage is 1. (NEON,
/// and the AVX-512 `vpopcntq` arm, run the fused kernel at every plane
/// length — see [`fused_block_ratio`] / [`fused_block_ratio_512`].)
pub const FUSED_SHORT_PLANE_MAX_WORDS: u64 = 64;

/// Fused-kernel chain budget (columns × k_w × k_x per chunk) of the
/// AVX-512 backend. x86_64 with EVEX has **32 zmm registers** — twice
/// AVX2's 16 ymm — so the 512-bit fused kernel can hold twice the chain
/// accumulators (16) plus the held weight vectors, the LUT, and the mask
/// in registers: W2A2 runs a full GEMM_BLOCK of 4 columns per chunk
/// instead of AVX2's 2. `kernels::avx512` derives its `FUSED_MAX_CHAINS`
/// from this constant so model and kernel cannot drift.
pub const AVX512_FUSED_MAX_CHAINS: u64 = 16;

/// 64-bit words per 256-bit SIMD vector.
const WORDS_PER_VEC: u64 = 4;
/// 64-bit words per 512-bit SIMD vector (the AVX-512 arms).
const WORDS_PER_VEC_512: u64 = 8;
/// Ops per chain per vector shared by both layouts: XOR + nibble-LUT byte
/// popcount (mask, shift, mask, 2 shuffles, add) + byte accumulate.
const CHAIN_OPS: u64 = 8;
/// Per-chain reduction: `vpsadbw` fold + horizontal sum of four lanes.
const REDUCTION_OPS: u64 = 10;
/// Per-pass overhead of one pairwise plane pass (loop setup, tail
/// handling, accumulator init).
const PASS_OVERHEAD_OPS: u64 = 8;

/// [`pairwise_block_ops`] parameterized on the vector width.
fn pairwise_block_ops_w(words: u64, k_w: u64, k_h: u64, b: u64, words_per_vec: u64) -> u64 {
    let vecs = words.div_ceil(words_per_vec);
    let chains = b * k_w * k_h;
    chains * (vecs * (CHAIN_OPS + 2) + REDUCTION_OPS + PASS_OVERHEAD_OPS)
}

/// [`fused_block_ops`] parameterized on the vector width.
fn fused_block_ops_w(words: u64, k_w: u64, k_h: u64, b: u64, words_per_vec: u64) -> u64 {
    let vecs = words.div_ceil(words_per_vec);
    let chains = b * k_w * k_h;
    vecs * (k_w + b * k_h + chains * CHAIN_OPS) + chains * REDUCTION_OPS + PASS_OVERHEAD_OPS
}

/// SIMD-op estimate of the **pairwise** layout: every chain is an
/// independent pass that reloads both planes and reduces on its own.
pub fn pairwise_block_ops(words: u64, k_w: u64, k_h: u64, b: u64) -> u64 {
    pairwise_block_ops_w(words, k_w, k_h, b, WORDS_PER_VEC)
}

/// SIMD-op estimate of the **fused** block layout: per vector index, k_w
/// weight loads serve every column and b·k_h activation loads serve every
/// weight plane; each chain still does its popcount pipeline, but folds
/// and reduces once at the end of the block.
pub fn fused_block_ops(words: u64, k_w: u64, k_h: u64, b: u64) -> u64 {
    fused_block_ops_w(words, k_w, k_h, b, WORDS_PER_VEC)
}

/// Raw predicted ratio of the two layouts, with no plane-length cutoff —
/// the model for a backend that runs the fused kernel at every length
/// (NEON).
pub fn fused_block_ratio(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if k_w * k_h * b == 0 {
        return 1.0;
    }
    pairwise_block_ops(words, k_w, k_h, b) as f64 / fused_block_ops(words, k_w, k_h, b) as f64
}

/// Predicted speedup of the fused block kernel over the old pairwise
/// decomposition at one batch block (`b` columns), for the **AVX2**
/// backend: 1.0 in the long-plane regime, where both layouts run the
/// same Harley–Seal pairwise pass.
pub fn fused_block_advantage(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if words >= FUSED_SHORT_PLANE_MAX_WORDS {
        return 1.0;
    }
    fused_block_ratio(words, k_w, k_h, b)
}

/// [`fused_block_ratio`] for a 512-bit backend — the model for the
/// AVX-512 `vpopcntq` arm, which runs the fused kernel at every plane
/// length (u64-lane accumulators never saturate, masked loads kill the
/// scalar tail, so there is no Harley–Seal cutoff).
pub fn fused_block_ratio_512(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if k_w * k_h * b == 0 {
        return 1.0;
    }
    pairwise_block_ops_w(words, k_w, k_h, b, WORDS_PER_VEC_512) as f64
        / fused_block_ops_w(words, k_w, k_h, b, WORDS_PER_VEC_512) as f64
}

/// [`fused_block_advantage`] for the AVX-512 **LUT** arm, which mirrors
/// the AVX2 structure: fused below [`FUSED_SHORT_PLANE_MAX_WORDS`],
/// Harley–Seal pairwise at and above it (ratio exactly 1).
pub fn fused_block_advantage_512(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if words >= FUSED_SHORT_PLANE_MAX_WORDS {
        return 1.0;
    }
    fused_block_ratio_512(words, k_w, k_h, b)
}

// ---------------------------------------------------------------------------
// Cache-tiling term: plane bytes vs L2 residency.
//
// `binary::PreparedGemm::gemm_rows` tiles the batch columns so that the
// packed activation planes of one tile (tile_cols × k_x × words × 8
// bytes) stay L2-resident while every weight row streams over them once.
// Untiled, a matrix whose activation block exceeds L2 re-fetches the
// activations from memory once per GEMM_BLOCK-column group; tiled, the
// weights stream once per tile and the activations are read from cache.
// The traffic model below predicts the DRAM-byte advantage so the bench
// can print predicted-vs-measured next to each other.
// ---------------------------------------------------------------------------

/// Default L2 budget (bytes) when detection finds nothing: 512 KB is a
/// conservative floor across the x86_64/aarch64 serving fleet.
pub const DEFAULT_L2_BYTES: usize = 512 * 1024;

/// Parse an `AMQ_L2_KB`-style override: a positive integer in KiB.
pub fn parse_l2_kb(s: &str) -> Result<usize, String> {
    match s.trim().parse::<usize>() {
        Ok(kb) if kb > 0 => Ok(kb * 1024),
        _ => Err(format!(
            "invalid AMQ_L2_KB '{s}': expected a positive integer (KiB)"
        )),
    }
}

/// Read the per-core L2 size from Linux sysfs (`cache/index2/size`,
/// e.g. "512K" / "1024K" / "2M"). Returns `None` off Linux or when the
/// file is absent/unparseable.
fn sysfs_l2_bytes() -> Option<usize> {
    let s = std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cache/index2/size").ok()?;
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024usize),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    let n: usize = digits.parse().ok()?;
    (n > 0).then_some(n * mult)
}

/// The L2 byte budget the tiler sizes against, resolved once per process:
/// `AMQ_L2_KB` override > Linux sysfs detection > [`DEFAULT_L2_BYTES`].
/// A malformed override falls back to detection with a warning rather
/// than aborting serving.
pub fn l2_bytes() -> usize {
    static L2: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *L2.get_or_init(|| {
        if let Ok(s) = std::env::var("AMQ_L2_KB") {
            match parse_l2_kb(&s) {
                Ok(bytes) => return bytes,
                Err(e) => eprintln!("amq: warning: {e}; falling back to detection"),
            }
        }
        sysfs_l2_bytes().unwrap_or(DEFAULT_L2_BYTES)
    })
}

/// Batch-tile width (columns) for a GEMM whose activation planes are
/// `words_per_plane`-word, `k_x`-deep: the widest multiple of `block`
/// whose packed activations fit half the L2 budget (the other half is
/// left to the streaming weight row and the outputs). Never below
/// `block` — a serving-sized batch is a single tile and the loop
/// structure degenerates to the untiled one.
pub fn tile_cols(words_per_plane: usize, k_x: usize, l2_budget: usize, block: usize) -> usize {
    let block = block.max(1);
    let per_col = k_x.max(1) * words_per_plane.max(1) * 8;
    let fit = (l2_budget / 2) / per_col;
    (fit / block * block).max(block)
}

/// Predicted DRAM-traffic ratio untiled/tiled for an `rows ×
/// (words·64)` weight matrix at batch `b`: ≥ 1, and exactly 1 whenever
/// the whole activation block already fits the tile budget (one tile —
/// the code path is identical). Traffic is modeled in packed bytes. The
/// untiled loop is row-outer: each row walks the full activation block,
/// so when that block exceeds the budget the activations re-stream from
/// DRAM once per row while weights stream once. Tiled, the activations
/// of one tile stay cache-resident across every row, at the price of
/// re-streaming the weights once per tile.
pub fn tiled_traffic_advantage(
    rows: u64,
    words_per_plane: u64,
    k_w: u64,
    k_x: u64,
    b: u64,
    l2_budget: u64,
    block: u64,
) -> f64 {
    let w_bytes = rows * k_w * words_per_plane * 8;
    let a_bytes = b * k_x * words_per_plane * 8;
    if a_bytes <= l2_budget / 2 {
        return 1.0; // single tile: tiled and untiled are the same loop
    }
    let tile = tile_cols(
        words_per_plane as usize,
        k_x as usize,
        l2_budget as usize,
        block as usize,
    ) as u64;
    let tiles = b.div_ceil(tile);
    // Untiled: weights stream once (row-major, each row touched once);
    // the over-budget activation block re-streams once per row.
    let untiled = w_bytes + rows * a_bytes;
    // Tiled: weights stream once per tile; each tile's activations are
    // fetched once and then served from cache for all rows.
    let tiled = tiles * w_bytes + a_bytes;
    untiled as f64 / tiled as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_gamma_examples() {
        // §4: for W_h ∈ R^{4096×1024}, γ ≈ 7.5 at (2,2) and ≈ 3.5 at (3,3).
        let g22 = theoretical_speedup(4096, 1024, 2, 2);
        let g33 = theoretical_speedup(4096, 1024, 3, 3);
        assert!((7.0..8.0).contains(&g22), "γ(2,2) = {g22}");
        assert!((3.2..3.8).contains(&g33), "γ(3,3) = {g33}");
    }

    #[test]
    fn memory_saving_matches_abstract() {
        // Abstract: ~16× at 2 bits, ~10.5× at 3 bits.
        let m2 = memory_saving(4096, 1024, 2);
        let m3 = memory_saving(4096, 1024, 3);
        assert!((15.0..16.1).contains(&m2), "2-bit saving {m2}");
        assert!((10.0..11.0).contains(&m3), "3-bit saving {m3}");
    }

    #[test]
    fn quant_cost_formula() {
        // §3: 2Tk²n binary, 2(T+1)kn non-binary.
        let c = quantization_cost(1024, 2, 2);
        assert_eq!(c.binary_ops, 2 * 2 * 4 * 1024);
        assert_eq!(c.nonbinary_ops, 2 * 3 * 2 * 1024);
    }

    #[test]
    fn speedup_decreases_with_bits() {
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let g = theoretical_speedup(4096, 1024, k, k);
            assert!(g < prev);
            prev = g;
        }
    }

    #[test]
    fn softmax_layer_shape_still_accelerates() {
        // Table 6's larger case: 42000×1024.
        let g = theoretical_speedup(42000, 1024, 2, 2);
        assert!(g > 7.0, "γ = {g}");
    }

    #[test]
    fn fused_block_wins_at_serving_shape() {
        // The serving shape: 1024 cols = 16 words per plane, W2A2, one
        // GEMM batch block of 4 columns. The fused layout must predict a
        // strict win — this is the shape where pairwise overhead used to
        // cancel the SIMD gain.
        let adv = fused_block_advantage(16, 2, 2, 4);
        assert!(adv > 1.1, "predicted fused advantage {adv}");
        // Degenerate single-chain block: overheads match more closely but
        // fused never predicts a loss.
        assert!(fused_block_advantage(16, 1, 1, 1) >= 1.0);
    }

    #[test]
    fn fused_advantage_decays_with_plane_length() {
        // Per-pass overhead amortizes as planes grow, so the predicted
        // advantage shrinks monotonically and hits exactly 1 in the
        // Harley–Seal regime (same code path).
        let mut prev = f64::INFINITY;
        for words in [4u64, 8, 16, 32, 48] {
            let adv = fused_block_advantage(words, 2, 2, 4);
            assert!(adv < prev, "advantage not decaying at {words} words");
            assert!(adv > 1.0, "fused should stay ahead at {words} words");
            prev = adv;
        }
        assert_eq!(fused_block_advantage(FUSED_SHORT_PLANE_MAX_WORDS, 2, 2, 4), 1.0);
        assert_eq!(fused_block_advantage(128, 2, 2, 4), 1.0);
    }

    #[test]
    fn avx512_fused_model_mirrors_avx2_shape() {
        // Same qualitative behavior at 512 bits: strict win at the
        // serving shape, exactly 1 for the LUT arm past the HS cutoff,
        // while the vpopcnt-arm ratio stays defined (> 1) everywhere.
        assert!(fused_block_advantage_512(16, 2, 2, 4) > 1.1);
        assert_eq!(fused_block_advantage_512(FUSED_SHORT_PLANE_MAX_WORDS, 2, 2, 4), 1.0);
        assert!(fused_block_ratio_512(128, 2, 2, 4) >= 1.0);
        // Twice the chain budget of AVX2's 8 — the 32-zmm file.
        assert_eq!(AVX512_FUSED_MAX_CHAINS, 16);
    }

    #[test]
    fn l2_override_parsing() {
        assert_eq!(parse_l2_kb("512"), Ok(512 * 1024));
        assert_eq!(parse_l2_kb(" 1024\n"), Ok(1024 * 1024));
        assert!(parse_l2_kb("0").is_err());
        assert!(parse_l2_kb("-3").is_err());
        assert!(parse_l2_kb("lots").is_err());
        assert!(parse_l2_kb("").is_err());
    }

    #[test]
    fn l2_bytes_is_positive_and_stable() {
        let a = l2_bytes();
        assert!(a > 0);
        assert_eq!(a, l2_bytes(), "OnceLock must cache the resolution");
    }

    #[test]
    fn tile_cols_properties() {
        // Fits half the budget, floors to a block multiple, never
        // below one block.
        let block = 4;
        for &(wpp, kx, l2) in &[
            (16usize, 2usize, 512 * 1024usize),
            (128, 4, 256 * 1024),
            (657, 3, 64 * 1024),
            (1, 1, 1024),
        ] {
            let t = tile_cols(wpp, kx, l2, block);
            assert!(t >= block, "tile {t} below block at {wpp}/{kx}/{l2}");
            assert_eq!(t % block, 0, "tile {t} not a block multiple");
            if t > block {
                assert!(
                    t * kx * wpp * 8 <= l2 / 2,
                    "tile {t} overflows the half-L2 budget at {wpp}/{kx}/{l2}"
                );
            }
        }
        // Degenerate budget: clamps to one block rather than zero.
        assert_eq!(tile_cols(1024, 4, 1, 4), 4);
    }

    #[test]
    fn tiled_advantage_is_one_when_activations_fit() {
        // Serving shape: 16-word planes, B up to 64 — activations are a
        // few KB, one tile, identical code path, ratio exactly 1.
        assert_eq!(tiled_traffic_advantage(4096, 16, 2, 2, 64, 512 * 1024, 4), 1.0);
    }

    #[test]
    fn tiled_advantage_grows_past_the_budget() {
        // Long planes and a batch whose activation block blows a small
        // budget: tiling must predict strictly less DRAM traffic.
        let adv = tiled_traffic_advantage(4096, 1024, 2, 2, 1024, 64 * 1024, 4);
        assert!(adv > 1.0, "predicted tiled advantage {adv}");
        // A roomier budget means wider tiles and fewer weight re-streams:
        // the advantage must not shrink.
        let adv2 = tiled_traffic_advantage(4096, 1024, 2, 2, 1024, 256 * 1024, 4);
        assert!(adv2 >= adv, "{adv2} < {adv}");
    }
}
