//! The analytic cost model of §3–§4 of the paper.
//!
//! For quantizing `w ∈ ℝⁿ` to `k` bits with `T` alternating cycles:
//! `2Tk²n` binary + `2(T+1)kn` non-binary operations.
//!
//! For the quantized product between a `k_w`-bit `m×n` matrix and a
//! `k_h`-bit vector: `2·k_w·k_h·m·n + 4·k_h²·n` binary and
//! `6·k_h·n + 2·k_w·k_h·m` non-binary operations, giving the theoretical
//! speedup over the `2mn`-op full-precision product (binary ops discounted
//! 32×):
//!
//! ```text
//! γ = 2mn / ( (2·k_w·k_h·m·n + 4·k_h²·n)/32 + 6·k_h·n + 2·k_w·k_h·m )
//! ```

/// Operation counts for quantizing a length-`n` vector to `k` bits with `T`
/// alternating cycles (includes the greedy init's `2kn`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn quantization_cost(n: u64, k: u64, t: u64) -> QuantCost {
    QuantCost {
        binary_ops: 2 * t * k * k * n,
        nonbinary_ops: 2 * (t + 1) * k * n,
    }
}

/// Operation counts for the quantized `m×n` GEMV (weights `k_w` bits,
/// activations `k_h` bits, online activation quantization with `T = 2`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemvCost {
    pub binary_ops: u64,
    pub nonbinary_ops: u64,
}

pub fn gemv_cost(m: u64, n: u64, k_w: u64, k_h: u64) -> GemvCost {
    GemvCost {
        binary_ops: 2 * k_w * k_h * m * n + 4 * k_h * k_h * n,
        nonbinary_ops: 6 * k_h * n + 2 * k_w * k_h * m,
    }
}

/// The paper's theoretical acceleration γ over a full-precision GEMV,
/// counting one binary op as 1/32 of a non-binary op.
pub fn theoretical_speedup(m: u64, n: u64, k_w: u64, k_h: u64) -> f64 {
    let fp_ops = (2 * m * n) as f64;
    let c = gemv_cost(m, n, k_w, k_h);
    fp_ops / (c.binary_ops as f64 / 32.0 + c.nonbinary_ops as f64)
}

/// Memory saving factor for a `k`-bit row-quantized `m×n` f32 matrix
/// (packed planes + per-row coefficients).
pub fn memory_saving(m: u64, n: u64, k: u64) -> f64 {
    let dense = (m * n * 32) as f64;
    let packed = (m * k * n) as f64 + (m * k * 32) as f64;
    dense / packed
}

// ---------------------------------------------------------------------------
// Block-kernel micro-model: fused batch-block vs pairwise plane passes.
//
// The §3/§4 model above counts *binary ops* and so cannot see why a SIMD
// backend used to lose at short planes: the old pairwise decomposition
// paid a full pass — loop setup, per-vector `vpsadbw` folds, and a
// horizontal sum — per (column, w-plane, x-plane) chain, while a plane of
// the serving shape (1024 cols) is only 4 × 256-bit vectors of payload.
// The fused block kernel loads each weight vector once per word index,
// keeps one byte-lane accumulator per chain, and pays the fold + hsum
// once per chain per row. This model counts both layouts in SIMD-op
// units so `exp::kernel_tables` can print a predicted fused-vs-pairwise
// ratio next to the measured one.
// ---------------------------------------------------------------------------

/// Words per plane at which the AVX2 backend switches the block primitive
/// from the fused short-plane kernel to Harley–Seal pairwise passes.
/// This is the **single source of truth**: `kernels::avx2` derives its
/// `HARLEY_SEAL_MIN_WORDS` from it, so model and kernel cannot drift.
/// Beyond it, fused and pairwise are the same AVX2 code path and the
/// predicted advantage is 1. (NEON runs the fused kernel at every plane
/// length — see [`fused_block_ratio`].)
pub const FUSED_SHORT_PLANE_MAX_WORDS: u64 = 64;

/// 64-bit words per 256-bit SIMD vector.
const WORDS_PER_VEC: u64 = 4;
/// Ops per chain per vector shared by both layouts: XOR + nibble-LUT byte
/// popcount (mask, shift, mask, 2 shuffles, add) + byte accumulate.
const CHAIN_OPS: u64 = 8;
/// Per-chain reduction: `vpsadbw` fold + horizontal sum of four lanes.
const REDUCTION_OPS: u64 = 10;
/// Per-pass overhead of one pairwise plane pass (loop setup, tail
/// handling, accumulator init).
const PASS_OVERHEAD_OPS: u64 = 8;

/// SIMD-op estimate of the **pairwise** layout: every chain is an
/// independent pass that reloads both planes and reduces on its own.
pub fn pairwise_block_ops(words: u64, k_w: u64, k_h: u64, b: u64) -> u64 {
    let vecs = words.div_ceil(WORDS_PER_VEC);
    let chains = b * k_w * k_h;
    chains * (vecs * (CHAIN_OPS + 2) + REDUCTION_OPS + PASS_OVERHEAD_OPS)
}

/// SIMD-op estimate of the **fused** block layout: per vector index, k_w
/// weight loads serve every column and b·k_h activation loads serve every
/// weight plane; each chain still does its popcount pipeline, but folds
/// and reduces once at the end of the block.
pub fn fused_block_ops(words: u64, k_w: u64, k_h: u64, b: u64) -> u64 {
    let vecs = words.div_ceil(WORDS_PER_VEC);
    let chains = b * k_w * k_h;
    vecs * (k_w + b * k_h + chains * CHAIN_OPS) + chains * REDUCTION_OPS + PASS_OVERHEAD_OPS
}

/// Raw predicted ratio of the two layouts, with no plane-length cutoff —
/// the model for a backend that runs the fused kernel at every length
/// (NEON).
pub fn fused_block_ratio(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if k_w * k_h * b == 0 {
        return 1.0;
    }
    pairwise_block_ops(words, k_w, k_h, b) as f64 / fused_block_ops(words, k_w, k_h, b) as f64
}

/// Predicted speedup of the fused block kernel over the old pairwise
/// decomposition at one batch block (`b` columns), for the **AVX2**
/// backend: 1.0 in the long-plane regime, where both layouts run the
/// same Harley–Seal pairwise pass.
pub fn fused_block_advantage(words: u64, k_w: u64, k_h: u64, b: u64) -> f64 {
    if words >= FUSED_SHORT_PLANE_MAX_WORDS {
        return 1.0;
    }
    fused_block_ratio(words, k_w, k_h, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_gamma_examples() {
        // §4: for W_h ∈ R^{4096×1024}, γ ≈ 7.5 at (2,2) and ≈ 3.5 at (3,3).
        let g22 = theoretical_speedup(4096, 1024, 2, 2);
        let g33 = theoretical_speedup(4096, 1024, 3, 3);
        assert!((7.0..8.0).contains(&g22), "γ(2,2) = {g22}");
        assert!((3.2..3.8).contains(&g33), "γ(3,3) = {g33}");
    }

    #[test]
    fn memory_saving_matches_abstract() {
        // Abstract: ~16× at 2 bits, ~10.5× at 3 bits.
        let m2 = memory_saving(4096, 1024, 2);
        let m3 = memory_saving(4096, 1024, 3);
        assert!((15.0..16.1).contains(&m2), "2-bit saving {m2}");
        assert!((10.0..11.0).contains(&m3), "3-bit saving {m3}");
    }

    #[test]
    fn quant_cost_formula() {
        // §3: 2Tk²n binary, 2(T+1)kn non-binary.
        let c = quantization_cost(1024, 2, 2);
        assert_eq!(c.binary_ops, 2 * 2 * 4 * 1024);
        assert_eq!(c.nonbinary_ops, 2 * 3 * 2 * 1024);
    }

    #[test]
    fn speedup_decreases_with_bits() {
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let g = theoretical_speedup(4096, 1024, k, k);
            assert!(g < prev);
            prev = g;
        }
    }

    #[test]
    fn softmax_layer_shape_still_accelerates() {
        // Table 6's larger case: 42000×1024.
        let g = theoretical_speedup(42000, 1024, 2, 2);
        assert!(g > 7.0, "γ = {g}");
    }

    #[test]
    fn fused_block_wins_at_serving_shape() {
        // The serving shape: 1024 cols = 16 words per plane, W2A2, one
        // GEMM batch block of 4 columns. The fused layout must predict a
        // strict win — this is the shape where pairwise overhead used to
        // cancel the SIMD gain.
        let adv = fused_block_advantage(16, 2, 2, 4);
        assert!(adv > 1.1, "predicted fused advantage {adv}");
        // Degenerate single-chain block: overheads match more closely but
        // fused never predicts a loss.
        assert!(fused_block_advantage(16, 1, 1, 1) >= 1.0);
    }

    #[test]
    fn fused_advantage_decays_with_plane_length() {
        // Per-pass overhead amortizes as planes grow, so the predicted
        // advantage shrinks monotonically and hits exactly 1 in the
        // Harley–Seal regime (same code path).
        let mut prev = f64::INFINITY;
        for words in [4u64, 8, 16, 32, 48] {
            let adv = fused_block_advantage(words, 2, 2, 4);
            assert!(adv < prev, "advantage not decaying at {words} words");
            assert!(adv > 1.0, "fused should stay ahead at {words} words");
            prev = adv;
        }
        assert_eq!(fused_block_advantage(FUSED_SHORT_PLANE_MAX_WORDS, 2, 2, 4), 1.0);
        assert_eq!(fused_block_advantage(128, 2, 2, 4), 1.0);
    }
}
