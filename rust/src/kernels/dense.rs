//! Full-precision f32 reference kernels.
//!
//! These are the FP baseline of Table 6. The paper compares against
//! single-threaded MKL GEMV; we use a register-blocked, autovectorizable
//! native GEMV — the honest portable equivalent (the reported quantity is
//! the binary/FP *ratio*, not MKL's absolute numbers).

/// `y = W x` for row-major `W (m×n)`. `y` must have length `m`.
pub fn gemv(w: &[f32], m: usize, n: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for (r, yr) in y.iter_mut().enumerate() {
        let row = &w[r * n..(r + 1) * n];
        // 4 independent accumulators so LLVM vectorizes + pipelines.
        let mut acc = [0.0f32; 4];
        let chunks = n / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc[0] += row[i] * x[i];
            acc[1] += row[i + 1] * x[i + 1];
            acc[2] += row[i + 2] * x[i + 2];
            acc[3] += row[i + 3] * x[i + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..n {
            s += row[i] * x[i];
        }
        *yr = s;
    }
}

/// `C = A B` for row-major `A (m×k)`, `B (k×n)`, `C (m×n)`, ikj loop order
/// (streams B rows, keeps C row hot).
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// `y += a * x` (axpy).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        s += x as f64 * y as f64;
    }
    s as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_gemv(w: &[f32], m: usize, n: usize, x: &[f32]) -> Vec<f32> {
        (0..m)
            .map(|r| (0..n).map(|c| w[r * n + c] * x[c]).sum())
            .collect()
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(91);
        for (m, n) in [(1, 1), (3, 5), (17, 33), (64, 127)] {
            let w = rng.normal_vec(m * n, 1.0);
            let x = rng.normal_vec(n, 1.0);
            let mut y = vec![0.0; m];
            gemv(&w, m, n, &x, &mut y);
            let expect = naive_gemv(&w, m, n, &x);
            for (a, b) in y.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn gemm_matches_gemv_per_column() {
        let mut rng = Rng::new(92);
        let (m, k, n) = (5, 7, 3);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut c = vec![0.0; m * n];
        gemm(&a, &b, m, k, n, &mut c);
        for col in 0..n {
            let x: Vec<f32> = (0..k).map(|p| b[p * n + col]).collect();
            let mut y = vec![0.0; m];
            gemv(&a, m, k, &x, &mut y);
            for r in 0..m {
                assert!((c[r * n + col] - y[r]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn axpy_and_dot() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((dot(&x, &x) - 14.0).abs() < 1e-6);
    }
}
