//! Kernel-backend selection and dispatch for the XNOR/popcount GEMM.
//!
//! The binary kernels reduce every output element to **exact integer
//! mismatch counts** (`popcount(w ⊕ x)` summed over packed words) followed
//! by a small float reduction. The counts are the same integers no matter
//! how the popcounts are computed, and the float reduction lives in one
//! place ([`crate::kernels::binary`]) shared by every backend — so any
//! backend that produces correct counts is automatically **bit-exact**
//! against the portable scalar kernel, across batch sizes and thread
//! counts alike. `rust/tests/kernel_parity.rs` pins this with `assert_eq`
//! on `f32` outputs (no tolerance).
//!
//! Backends:
//!
//! * [`Kernel::Scalar`] — portable `u64 ^` + `count_ones` (LLVM lowers to
//!   `xor` + `popcnt` on x86_64). Always available; the reference.
//! * [`Kernel::Avx2`] — x86_64 AVX2: `vpshufb` nibble-LUT popcount with
//!   Harley–Seal carry-save accumulation over 256-bit lanes
//!   ([`super::avx2`]).
//! * [`Kernel::Neon`] — aarch64 NEON: `vcntq_u8` byte popcount with a
//!   widening `vpaddlq`/`vpadalq` reduction ([`super::neon`]).
//!
//! Selection order (first hit wins):
//!
//! 1. an explicit choice via [`force`] — `amq serve --kernel` or the
//!    `server.kernel` config key;
//! 2. the `AMQ_KERNEL` environment variable (`scalar|avx2|neon|auto`);
//! 3. runtime feature detection ([`Kernel::detect`]):
//!    `is_x86_feature_detected!("avx2")` on x86_64, NEON (baseline) on
//!    aarch64, scalar elsewhere.
//!
//! Adding a backend: add an enum variant + `is_available` arm, implement
//! `xor_popcount` / `row_counts` / `block_counts` (+ the `_dyn` variants)
//! in a new arch-gated module, and add the dispatch arms below. The
//! cross-backend parity suite picks the new backend up automatically via
//! [`Kernel::available`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::scalar;

#[cfg(target_arch = "x86_64")]
use super::avx2;
#[cfg(target_arch = "aarch64")]
use super::neon;

/// Max bit width the fused inner loops specialize for (the paper never
/// exceeds 4 bits).
pub const MAX_K: usize = 4;

/// A compute backend for the XNOR/popcount kernels.
///
/// All variants exist on every architecture so that names parse uniformly
/// (configs are portable); [`Kernel::is_available`] answers whether this
/// host can actually run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar kernel — always available, the exactness reference.
    Scalar,
    /// x86_64 AVX2 (`vpshufb` LUT popcount + Harley–Seal).
    Avx2,
    /// aarch64 NEON (`vcntq_u8` + widening adds).
    Neon,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Can this backend run on the current host (architecture + runtime
    /// CPU features)?
    pub fn is_available(&self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => true, // NEON is baseline on aarch64
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every backend this host can run, scalar first.
    pub fn available() -> Vec<Kernel> {
        [Kernel::Scalar, Kernel::Avx2, Kernel::Neon]
            .into_iter()
            .filter(|k| k.is_available())
            .collect()
    }

    /// The best backend runtime detection finds on this host.
    pub fn detect() -> Kernel {
        if Kernel::Avx2.is_available() {
            Kernel::Avx2
        } else if Kernel::Neon.is_available() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// This backend if available, else the scalar fallback. Every stored
    /// kernel (e.g. in `PreparedGemm`) is resolved, so dispatch never has
    /// to re-check CPU features on the hot path.
    pub fn resolve(self) -> Kernel {
        if self.is_available() {
            self
        } else {
            Kernel::Scalar
        }
    }

    /// Parse a *selection* string: `"auto"` (or empty) means "no explicit
    /// choice" (`None` — fall through to env/detection), anything else
    /// must name an available backend.
    pub fn parse_choice(s: &str) -> Result<Option<Kernel>, String> {
        let t = s.trim();
        if t.is_empty() || t.eq_ignore_ascii_case("auto") {
            return Ok(None);
        }
        t.parse().map(Some)
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl std::str::FromStr for Kernel {
    type Err = String;

    /// Strict parse of a backend name. Known-but-unavailable names are an
    /// error (listing what this host supports) so a forced `--kernel` can
    /// never silently run something else.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let k = match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Kernel::Scalar,
            "avx2" => Kernel::Avx2,
            "neon" => Kernel::Neon,
            other => {
                return Err(format!(
                    "unknown kernel '{other}' (scalar|avx2|neon|auto)"
                ))
            }
        };
        if !k.is_available() {
            let have: Vec<&str> = Kernel::available().iter().map(|k| k.name()).collect();
            return Err(format!(
                "kernel '{}' is not available on this host (available: {})",
                k.name(),
                have.join(", ")
            ));
        }
        Ok(k)
    }
}

/// CPU features relevant to the binary kernels that runtime detection sees
/// on this host (recorded in the `--json` bench summaries).
pub fn cpu_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("sse4.2", is_x86_feature_detected!("sse4.2")),
            ("popcnt", is_x86_feature_detected!("popcnt")),
            ("avx2", is_x86_feature_detected!("avx2")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
        ] {
            if have {
                f.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        f.push("neon");
    }
    f
}

// ---------------------------------------------------------------------------
// Process-wide selection (force > AMQ_KERNEL > detection).
// ---------------------------------------------------------------------------

/// 0 = not forced; otherwise `code(kernel)`.
static FORCED: AtomicU8 = AtomicU8::new(0);
/// The env/detection choice, resolved once per process.
static AUTO: OnceLock<Kernel> = OnceLock::new();

fn code(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        Kernel::Avx2 => 2,
        Kernel::Neon => 3,
    }
}

fn from_code(c: u8) -> Option<Kernel> {
    match c {
        1 => Some(Kernel::Scalar),
        2 => Some(Kernel::Avx2),
        3 => Some(Kernel::Neon),
        _ => None,
    }
}

/// Force the process-wide backend (the `--kernel` / `server.kernel`
/// override). Resolved against availability; wins over `AMQ_KERNEL` and
/// detection for every kernel object built afterwards.
pub fn force(k: Kernel) {
    FORCED.store(code(k.resolve()), Ordering::Relaxed);
}

/// The backend new kernel objects resolve to right now: [`force`]d choice
/// if any, else `AMQ_KERNEL` (read once per process), else detection.
pub fn active() -> Kernel {
    if let Some(k) = from_code(FORCED.load(Ordering::Relaxed)) {
        return k;
    }
    *AUTO.get_or_init(|| match std::env::var("AMQ_KERNEL") {
        Ok(v) => match Kernel::parse_choice(&v) {
            Ok(Some(k)) => k,
            Ok(None) => Kernel::detect(),
            Err(e) => {
                eprintln!("warning: ignoring AMQ_KERNEL: {e}");
                Kernel::detect()
            }
        },
        Err(_) => Kernel::detect(),
    })
}

// ---------------------------------------------------------------------------
// Count-primitive dispatch — the one seam every hot loop goes through.
//
// Callers pass a *resolved* kernel. Unavailable variants still fall back
// to scalar (same counts, so still exact): wrong-architecture variants hit
// the catch-all arms below, and a same-architecture variant on a CPU
// without the feature is caught by the runtime check inside the backend's
// safe wrappers (e.g. `avx2::have_avx2`), never a compiled-out assert.
// ---------------------------------------------------------------------------

/// `Σ_i popcount(a[i] ^ b[i])` — the pairwise primitive (legacy GEMV paths
/// and exotic bit widths).
#[inline]
pub(crate) fn xor_popcount(kernel: Kernel, a: &[u64], b: &[u64]) -> u32 {
    match kernel {
        Kernel::Scalar => scalar::xor_popcount(a, b),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::xor_popcount(a, b),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::xor_popcount(a, b),
        #[allow(unreachable_patterns)]
        _ => scalar::xor_popcount(a, b),
    }
}

/// `counts[t][s] += Σ_i popcount(w[t][i] ^ x[s][i])` — one weight row
/// (`KW` plane slices) against one activation column (`KX` plane slices).
#[inline]
pub(crate) fn row_counts<const KW: usize, const KX: usize>(
    kernel: Kernel,
    w: &[&[u64]; KW],
    x: &[&[u64]; KX],
    counts: &mut [[u32; KX]; KW],
) {
    match kernel {
        Kernel::Scalar => scalar::row_counts::<KW, KX>(w, x, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::row_counts::<KW, KX>(w, x, counts),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::row_counts::<KW, KX>(w, x, counts),
        #[allow(unreachable_patterns)]
        _ => scalar::row_counts::<KW, KX>(w, x, counts),
    }
}

/// Batched variant: one weight row against `xw.len()` activation columns
/// (`counts.len() == xw.len()`, a batch block of the GEMM).
#[inline]
pub(crate) fn block_counts<const KW: usize, const KX: usize>(
    kernel: Kernel,
    w: &[&[u64]; KW],
    xw: &[[&[u64]; KX]],
    counts: &mut [[[u32; KX]; KW]],
) {
    debug_assert_eq!(xw.len(), counts.len());
    match kernel {
        Kernel::Scalar => scalar::block_counts::<KW, KX>(w, xw, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::block_counts::<KW, KX>(w, xw, counts),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::block_counts::<KW, KX>(w, xw, counts),
        #[allow(unreachable_patterns)]
        _ => scalar::block_counts::<KW, KX>(w, xw, counts),
    }
}

/// Runtime-width variant of [`row_counts`] for (k_w, k_x) pairs outside
/// the const-generic table: `w.len() = k_w ≤ MAX_K`, `x.len() = k_x ≤
/// MAX_K`.
#[inline]
pub(crate) fn row_counts_dyn(
    kernel: Kernel,
    w: &[&[u64]],
    x: &[&[u64]],
    counts: &mut [[u32; MAX_K]; MAX_K],
) {
    match kernel {
        Kernel::Scalar => scalar::row_counts_dyn(w, x, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::row_counts_dyn(w, x, counts),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::row_counts_dyn(w, x, counts),
        #[allow(unreachable_patterns)]
        _ => scalar::row_counts_dyn(w, x, counts),
    }
}

/// Runtime-width variant of [`block_counts`]: `xw[j][s]` is valid for
/// `s < kx`; `w.len() = k_w`.
#[inline]
pub(crate) fn block_counts_dyn(
    kernel: Kernel,
    w: &[&[u64]],
    xw: &[[&[u64]; MAX_K]],
    kx: usize,
    counts: &mut [[[u32; MAX_K]; MAX_K]],
) {
    debug_assert_eq!(xw.len(), counts.len());
    match kernel {
        Kernel::Scalar => scalar::block_counts_dyn(w, xw, kx, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::block_counts_dyn(w, xw, kx, counts),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::block_counts_dyn(w, xw, kx, counts),
        #[allow(unreachable_patterns)]
        _ => scalar::block_counts_dyn(w, xw, kx, counts),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn scalar_always_available_and_detect_resolves() {
        assert!(Kernel::Scalar.is_available());
        let d = Kernel::detect();
        assert!(d.is_available());
        assert_eq!(d.resolve(), d);
        assert!(Kernel::available().contains(&Kernel::Scalar));
        assert!(Kernel::available().contains(&d));
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for k in Kernel::available() {
            assert_eq!(k.name().parse::<Kernel>().unwrap(), k);
            assert_eq!(format!("{k}").parse::<Kernel>().unwrap(), k);
        }
        assert_eq!(Kernel::parse_choice("auto").unwrap(), None);
        assert_eq!(Kernel::parse_choice("").unwrap(), None);
        assert_eq!(Kernel::parse_choice("scalar").unwrap(), Some(Kernel::Scalar));
        assert!("wat".parse::<Kernel>().is_err());
        // Named-but-unavailable backends must error, not silently remap.
        for k in [Kernel::Avx2, Kernel::Neon] {
            if !k.is_available() {
                assert!(k.name().parse::<Kernel>().is_err(), "{k}");
            }
        }
    }

    #[test]
    fn unavailable_resolves_to_scalar() {
        for k in [Kernel::Avx2, Kernel::Neon] {
            if !k.is_available() {
                assert_eq!(k.resolve(), Kernel::Scalar);
            }
        }
    }

    #[test]
    fn active_is_available() {
        assert!(active().is_available());
    }

    #[test]
    fn cpu_features_consistent_with_backends() {
        let f = cpu_features();
        if Kernel::Avx2.is_available() {
            assert!(f.contains(&"avx2"));
        }
        if Kernel::Neon.is_available() {
            assert!(f.contains(&"neon"));
        }
    }

    /// Every backend's pairwise popcount must equal scalar's on lengths
    /// that cover the SIMD main loops, their tails, and the empty case.
    #[test]
    fn xor_popcount_matches_scalar_across_backends() {
        let mut rng = Rng::new(0xC0DE);
        for words in [0usize, 1, 3, 4, 5, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 130] {
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let want = scalar::xor_popcount(&a, &b);
            for k in Kernel::available() {
                assert_eq!(xor_popcount(k, &a, &b), want, "{k} words={words}");
            }
            // Edge patterns: identical, complementary, all-ones.
            let ones = vec![u64::MAX; words];
            for k in Kernel::available() {
                assert_eq!(xor_popcount(k, &a, &a), 0, "{k} self");
                assert_eq!(xor_popcount(k, &a, &ones), scalar::xor_popcount(&a, &ones), "{k} ones");
            }
        }
    }

    #[test]
    fn count_primitives_match_scalar_across_backends() {
        let mut rng = Rng::new(0xBEE5);
        for wpp in [1usize, 2, 16, 18, 33] {
            let wplanes: Vec<Vec<u64>> =
                (0..MAX_K).map(|_| (0..wpp).map(|_| rng.next_u64()).collect()).collect();
            let xplanes: Vec<Vec<u64>> =
                (0..MAX_K).map(|_| (0..wpp).map(|_| rng.next_u64()).collect()).collect();
            let w: [&[u64]; 3] = [&wplanes[0][..], &wplanes[1][..], &wplanes[2][..]];
            let x: [&[u64]; 2] = [&xplanes[0][..], &xplanes[1][..]];
            let mut want = [[0u32; 2]; 3];
            scalar::row_counts::<3, 2>(&w, &x, &mut want);
            for k in Kernel::available() {
                let mut got = [[0u32; 2]; 3];
                row_counts::<3, 2>(k, &w, &x, &mut got);
                assert_eq!(got, want, "row_counts {k} wpp={wpp}");

                let xw: [[&[u64]; 2]; 2] = [x, [&xplanes[2][..], &xplanes[3][..]]];
                let mut want_b = [[[0u32; 2]; 3]; 2];
                scalar::block_counts::<3, 2>(&w, &xw, &mut want_b);
                let mut got_b = [[[0u32; 2]; 3]; 2];
                block_counts::<3, 2>(k, &w, &xw, &mut got_b);
                assert_eq!(got_b, want_b, "block_counts {k} wpp={wpp}");

                let wd: Vec<&[u64]> = w.to_vec();
                let xd: Vec<&[u64]> = x.to_vec();
                let mut want_d = [[0u32; MAX_K]; MAX_K];
                scalar::row_counts_dyn(&wd, &xd, &mut want_d);
                let mut got_d = [[0u32; MAX_K]; MAX_K];
                row_counts_dyn(k, &wd, &xd, &mut got_d);
                assert_eq!(got_d, want_d, "row_counts_dyn {k} wpp={wpp}");
            }
        }
    }
}
