//! Kernel-backend selection and dispatch for the XNOR/popcount GEMM.
//!
//! The binary kernels reduce every output element to **exact integer
//! mismatch counts** (`popcount(w ⊕ x)` summed over packed words) followed
//! by a small float reduction. The counts are the same integers no matter
//! how the popcounts are computed, and the float reduction lives in one
//! place ([`crate::kernels::binary`]) shared by every backend — so any
//! backend that produces correct counts is automatically **bit-exact**
//! against the portable scalar kernel, across batch sizes and thread
//! counts alike. `rust/tests/kernel_parity.rs` pins this with `assert_eq`
//! on `f32` outputs (no tolerance).
//!
//! The seam is a **single primitive per backend** — the fused batch-block
//! counts:
//!
//! ```text
//! block_counts(w, x_block, counts):
//!   counts[(j·k_w + t)·k_x + s] += Σ_i popcount(w[t][i] ^ x_block[j][s][i])
//! ```
//!
//! `w` holds one weight row's plane slices, `x_block` one batch block of
//! columns (each a slice of plane slices), `counts` the flat accumulator.
//! Every hot path is a special case of it: the single-vector GEMV is a
//! one-column block, a plane pair is a 1×1×1 block. Each backend fuses
//! the whole block in one pass (weight vectors loaded once per word
//! index, per-chain lane accumulators, one reduction per chain per row)
//! instead of decomposing into pairwise plane passes — that is what makes
//! SIMD win even at short serving planes (1024 cols = 16 words), where
//! per-pair reduction overhead used to cancel the vector math.
//!
//! Backends:
//!
//! * [`Kernel::Scalar`] — portable `u64 ^` + `count_ones` (LLVM lowers to
//!   `xor` + `popcnt` on x86_64). Always available; the reference.
//! * [`Kernel::Avx2`] — x86_64 AVX2: fused block kernel with `vpshufb`
//!   nibble-LUT popcount and per-chain byte accumulators on short planes;
//!   Harley–Seal carry-save pairwise passes on long planes
//!   ([`super::avx2`]).
//! * [`Kernel::Avx512`] — x86_64 AVX-512: two arms behind runtime
//!   detection — native `vpopcntq` lane popcount on `avx512vpopcntdq`
//!   hardware (fused at every plane length), or a 512-bit `vpshufb`
//!   nibble-LUT + `vpsadbw` fallback on `avx512f+avx512bw` with a
//!   Harley–Seal pass for long planes ([`super::avx512`]).
//! * [`Kernel::Neon`] — aarch64 NEON: fused block kernel with `vcntq_u8`
//!   byte popcount, `u8`-block accumulation, widening fold per chain
//!   ([`super::neon`]).
//!
//! Selection order (first hit wins):
//!
//! 1. an explicit choice via [`force`] — `amq serve --kernel` or the
//!    `server.kernel` config key;
//! 2. the `AMQ_KERNEL` environment variable
//!    (`scalar|avx2|avx512|neon|auto`);
//! 3. runtime feature detection ([`Kernel::detect`]): AVX-512 before
//!    AVX2 on x86_64, NEON (baseline) on aarch64, scalar elsewhere.
//!
//! Adding a backend: add an enum variant + `is_available` arm, implement
//! **one function** — `block_counts(w, x_block, counts)` — in a new
//! arch-gated module, and add one dispatch arm below. The cross-backend
//! parity suite picks the new backend up automatically via
//! [`Kernel::available`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::scalar;

#[cfg(target_arch = "x86_64")]
use super::avx2;
#[cfg(target_arch = "x86_64")]
use super::avx512;
#[cfg(target_arch = "aarch64")]
use super::neon;

/// Max bit width the GEMM drivers stack-allocate plane-slice and count
/// buffers for (the paper never exceeds 4 bits). Backends accept any
/// width — beyond `MAX_K` the SIMD backends take their pairwise arm.
pub const MAX_K: usize = 4;

/// A compute backend for the XNOR/popcount kernels.
///
/// All variants exist on every architecture so that names parse uniformly
/// (configs are portable); [`Kernel::is_available`] answers whether this
/// host can actually run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar kernel — always available, the exactness reference.
    Scalar,
    /// x86_64 AVX2 (`vpshufb` LUT popcount; fused block kernel on short
    /// planes, Harley–Seal on long ones).
    Avx2,
    /// x86_64 AVX-512 (`vpopcntq` arm on `avx512vpopcntdq` hardware, a
    /// 512-bit LUT + Harley–Seal arm on `avx512f+avx512bw`).
    Avx512,
    /// aarch64 NEON (`vcntq_u8` fused block kernel).
    Neon,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Avx512 => "avx512",
            Kernel::Neon => "neon",
        }
    }

    /// Can this backend run on the current host (architecture + runtime
    /// CPU features)?
    pub fn is_available(&self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => avx512::have_avx512(),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => true, // NEON is baseline on aarch64
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every backend this host can run, scalar first.
    pub fn available() -> Vec<Kernel> {
        [Kernel::Scalar, Kernel::Avx2, Kernel::Avx512, Kernel::Neon]
            .into_iter()
            .filter(|k| k.is_available())
            .collect()
    }

    /// The best backend runtime detection finds on this host. AVX-512
    /// outranks AVX2: even the LUT arm doubles the vector width with the
    /// same per-vector op count, and the `vpopcntq` arm beats both.
    pub fn detect() -> Kernel {
        if Kernel::Avx512.is_available() {
            Kernel::Avx512
        } else if Kernel::Avx2.is_available() {
            Kernel::Avx2
        } else if Kernel::Neon.is_available() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// This backend if available, else the scalar fallback. Every stored
    /// kernel (e.g. in `PreparedGemm`) is resolved, so dispatch never has
    /// to re-check CPU features on the hot path.
    pub fn resolve(self) -> Kernel {
        if self.is_available() {
            self
        } else {
            Kernel::Scalar
        }
    }

    /// Parse a *selection* string: `"auto"` (or empty) means "no explicit
    /// choice" (`None` — fall through to env/detection), anything else
    /// must name an available backend.
    pub fn parse_choice(s: &str) -> Result<Option<Kernel>, String> {
        let t = s.trim();
        if t.is_empty() || t.eq_ignore_ascii_case("auto") {
            return Ok(None);
        }
        t.parse().map(Some)
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl std::str::FromStr for Kernel {
    type Err = String;

    /// Strict parse of a backend name. Known-but-unavailable names are an
    /// error (listing what this host supports) so a forced `--kernel` can
    /// never silently run something else.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let k = match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Kernel::Scalar,
            "avx2" => Kernel::Avx2,
            "avx512" => Kernel::Avx512,
            "neon" => Kernel::Neon,
            other => {
                return Err(format!(
                    "unknown kernel '{other}' (scalar|avx2|avx512|neon|auto)"
                ))
            }
        };
        if !k.is_available() {
            let have: Vec<&str> = Kernel::available().iter().map(|k| k.name()).collect();
            let hint = match k {
                Kernel::Avx512 => " (needs avx512f+avx512bw)",
                _ => "",
            };
            return Err(format!(
                "kernel '{}' is not available on this host{} (available: {})",
                k.name(),
                hint,
                have.join(", ")
            ));
        }
        Ok(k)
    }
}

/// Which AVX-512 arm this host would run: `Some("vpopcntq")` on
/// `avx512vpopcntdq` hardware, `Some("lut")` with only `avx512f+avx512bw`,
/// `None` when the backend is unavailable. Startup lines and the bench
/// JSONs record it so "which arm ran" is never a guess.
pub fn avx512_arm() -> Option<&'static str> {
    if !Kernel::Avx512.is_available() {
        return None;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx512::have_vpopcntdq() {
            return Some("vpopcntq");
        }
        return Some("lut");
    }
    #[allow(unreachable_code)]
    None
}

/// Human-readable backend descriptor for startup lines and STATS:
/// the plain name, except `avx512` which carries its active arm
/// (`avx512(vpopcntq)` / `avx512(lut)`).
pub fn describe(k: Kernel) -> String {
    match (k, avx512_arm()) {
        (Kernel::Avx512, Some(arm)) => format!("avx512({arm})"),
        _ => k.name().to_string(),
    }
}

/// CPU features relevant to the binary kernels that runtime detection sees
/// on this host (recorded in the `--json` bench summaries).
pub fn cpu_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("sse4.2", is_x86_feature_detected!("sse4.2")),
            ("popcnt", is_x86_feature_detected!("popcnt")),
            ("avx2", is_x86_feature_detected!("avx2")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
            ("avx512bw", is_x86_feature_detected!("avx512bw")),
            ("avx512vpopcntdq", is_x86_feature_detected!("avx512vpopcntdq")),
        ] {
            if have {
                f.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        f.push("neon");
    }
    f
}

// ---------------------------------------------------------------------------
// Process-wide selection (force > AMQ_KERNEL > detection).
// ---------------------------------------------------------------------------

/// 0 = not forced; otherwise `code(kernel)`.
static FORCED: AtomicU8 = AtomicU8::new(0);
/// The env/detection choice, resolved once per process.
static AUTO: OnceLock<Kernel> = OnceLock::new();

fn code(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        Kernel::Avx2 => 2,
        Kernel::Neon => 3,
        Kernel::Avx512 => 4,
    }
}

fn from_code(c: u8) -> Option<Kernel> {
    match c {
        1 => Some(Kernel::Scalar),
        2 => Some(Kernel::Avx2),
        3 => Some(Kernel::Neon),
        4 => Some(Kernel::Avx512),
        _ => None,
    }
}

/// Force the process-wide backend (the `--kernel` / `server.kernel`
/// override). Resolved against availability; wins over `AMQ_KERNEL` and
/// detection for every kernel object built afterwards.
pub fn force(k: Kernel) {
    FORCED.store(code(k.resolve()), Ordering::Relaxed);
}

/// The backend new kernel objects resolve to right now: [`force`]d choice
/// if any, else `AMQ_KERNEL` (read once per process), else detection.
pub fn active() -> Kernel {
    if let Some(k) = from_code(FORCED.load(Ordering::Relaxed)) {
        return k;
    }
    *AUTO.get_or_init(|| match std::env::var("AMQ_KERNEL") {
        Ok(v) => match Kernel::parse_choice(&v) {
            Ok(Some(k)) => k,
            Ok(None) => Kernel::detect(),
            Err(e) => {
                eprintln!("warning: ignoring AMQ_KERNEL: {e}");
                Kernel::detect()
            }
        },
        Err(_) => Kernel::detect(),
    })
}

// ---------------------------------------------------------------------------
// The count primitive — the one seam every hot loop goes through.
//
// Callers pass a *resolved* kernel. Unavailable variants still fall back
// to scalar (same counts, so still exact): wrong-architecture variants hit
// the catch-all arm below, and a same-architecture variant on a CPU
// without the feature is caught by the runtime check inside the backend's
// safe wrapper (e.g. `avx2::have_avx2`), never a compiled-out assert.
// ---------------------------------------------------------------------------

/// Fused batch-block counts — the single count primitive:
///
/// ```text
/// counts[(j·k_w + t)·k_x + s] += Σ_i popcount(w[t][i] ^ x_block[j][s][i])
/// ```
///
/// `w`: the `k_w` plane slices of one weight row. `x_block[j]`: the `k_x`
/// plane slices of batch column `j`. All plane slices share one length;
/// every column has the same `k_x`; `counts.len()` is
/// `x_block.len() · k_w · k_x`, layout `[column][w-plane][x-plane]`.
/// Accumulates into `counts` (callers zero the slice first).
///
/// A one-column block is the GEMV case; a 1×1×1 block is a plane pair —
/// every caller shape is this one primitive, so a backend is exactly one
/// function.
#[inline]
pub(crate) fn block_counts(
    kernel: Kernel,
    w: &[&[u64]],
    x_block: &[&[&[u64]]],
    counts: &mut [u32],
) {
    debug_assert_eq!(
        counts.len(),
        x_block.len() * w.len() * x_block.first().map_or(0, |c| c.len())
    );
    match kernel {
        Kernel::Scalar => scalar::block_counts(w, x_block, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::block_counts(w, x_block, counts),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx512 => avx512::block_counts(w, x_block, counts),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => neon::block_counts(w, x_block, counts),
        #[allow(unreachable_patterns)]
        _ => scalar::block_counts(w, x_block, counts),
    }
}

/// Test-only hooks. `#[doc(hidden)]` — not API; the parity suite uses
/// them to drive each AVX-512 arm explicitly (integration tests cannot
/// force the LUT arm on `vpopcntdq` hardware through the public seam).
#[doc(hidden)]
pub mod testing {
    /// Run one specific AVX-512 arm (`"vpopcntq"` / `"lut"`) against the
    /// block-counts contract. Returns `false` — leaving `counts`
    /// untouched — when this host cannot run the requested arm, so
    /// callers can skip-with-notice.
    pub fn avx512_block_counts_arm(
        arm: &str,
        w: &[&[u64]],
        x_block: &[&[&[u64]]],
        counts: &mut [u32],
    ) -> bool {
        let vpopcnt = match arm {
            "vpopcntq" => true,
            "lut" => false,
            _ => return false,
        };
        #[cfg(target_arch = "x86_64")]
        {
            super::avx512::block_counts_arm(vpopcnt, w, x_block, counts)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (vpopcnt, w, x_block, counts);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn scalar_always_available_and_detect_resolves() {
        assert!(Kernel::Scalar.is_available());
        let d = Kernel::detect();
        assert!(d.is_available());
        assert_eq!(d.resolve(), d);
        assert!(Kernel::available().contains(&Kernel::Scalar));
        assert!(Kernel::available().contains(&d));
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for k in Kernel::available() {
            assert_eq!(k.name().parse::<Kernel>().unwrap(), k);
            assert_eq!(format!("{k}").parse::<Kernel>().unwrap(), k);
        }
        assert_eq!(Kernel::parse_choice("auto").unwrap(), None);
        assert_eq!(Kernel::parse_choice("").unwrap(), None);
        assert_eq!(Kernel::parse_choice("scalar").unwrap(), Some(Kernel::Scalar));
        assert!("wat".parse::<Kernel>().is_err());
        // Named-but-unavailable backends must error, not silently remap.
        for k in [Kernel::Avx2, Kernel::Avx512, Kernel::Neon] {
            if !k.is_available() {
                assert!(k.name().parse::<Kernel>().is_err(), "{k}");
            }
        }
    }

    /// The satellite error-path contract: forcing `avx512` on a host
    /// without it must be a clear, actionable parse error (what's
    /// missing + what's available) — the strict `FromStr` is exactly
    /// what `amq serve --kernel avx512` hits at startup, so old hardware
    /// gets a message, never a SIGILL. `parse_choice` must carry the
    /// same error, and on supporting hosts both must succeed.
    #[test]
    fn avx512_unavailable_is_a_clear_error_not_a_sigill() {
        if Kernel::Avx512.is_available() {
            assert_eq!("avx512".parse::<Kernel>().unwrap(), Kernel::Avx512);
            assert_eq!(Kernel::parse_choice("avx512").unwrap(), Some(Kernel::Avx512));
            assert!(avx512_arm().is_some());
            return;
        }
        let err = "avx512".parse::<Kernel>().unwrap_err();
        assert!(err.contains("not available"), "{err}");
        assert!(err.contains("avx512f+avx512bw"), "{err}");
        assert!(err.contains("available: "), "{err}");
        assert!(err.contains("scalar"), "{err}");
        let err2 = Kernel::parse_choice("avx512").unwrap_err();
        assert_eq!(err, err2);
        assert_eq!(avx512_arm(), None);
        // And even a misused raw variant degrades to scalar counts, not
        // a SIGILL: resolve() plus the in-backend runtime re-check.
        assert_eq!(Kernel::Avx512.resolve(), Kernel::Scalar);
        let w_plane = [0u64; 4];
        let x_plane = [u64::MAX; 4];
        let w: [&[u64]; 1] = [&w_plane];
        let col: [&[u64]; 1] = [&x_plane];
        let block: [&[&[u64]]; 1] = [&col];
        let mut got = [0u32; 1];
        block_counts(Kernel::Avx512, &w, &block, &mut got);
        assert_eq!(got[0], 256);
    }

    #[test]
    fn unavailable_resolves_to_scalar() {
        for k in [Kernel::Avx2, Kernel::Avx512, Kernel::Neon] {
            if !k.is_available() {
                assert_eq!(k.resolve(), Kernel::Scalar);
            }
        }
    }

    #[test]
    fn active_is_available() {
        assert!(active().is_available());
    }

    #[test]
    fn cpu_features_consistent_with_backends() {
        let f = cpu_features();
        if Kernel::Avx2.is_available() {
            assert!(f.contains(&"avx2"));
        }
        if Kernel::Avx512.is_available() {
            assert!(f.contains(&"avx512f"));
            assert!(f.contains(&"avx512bw"));
        }
        if Kernel::Neon.is_available() {
            assert!(f.contains(&"neon"));
        }
    }

    /// `describe` carries the active AVX-512 arm; the arm is consistent
    /// with `cpu_features` and availability.
    #[test]
    fn describe_and_arm_are_consistent() {
        assert_eq!(describe(Kernel::Scalar), "scalar");
        assert_eq!(describe(Kernel::Avx2), "avx2");
        match avx512_arm() {
            Some("vpopcntq") => {
                assert!(cpu_features().contains(&"avx512vpopcntdq"));
                assert_eq!(describe(Kernel::Avx512), "avx512(vpopcntq)");
            }
            Some("lut") => {
                assert!(!cpu_features().contains(&"avx512vpopcntdq"));
                assert_eq!(describe(Kernel::Avx512), "avx512(lut)");
            }
            Some(other) => panic!("unexpected arm {other}"),
            None => {
                assert!(!Kernel::Avx512.is_available());
                assert_eq!(describe(Kernel::Avx512), "avx512");
            }
        }
    }

    /// Build a block of `b` columns × `kx` planes from flat plane storage.
    fn mk_planes(rng: &mut Rng, planes: usize, words: usize) -> Vec<Vec<u64>> {
        (0..planes).map(|_| (0..words).map(|_| rng.next_u64()).collect()).collect()
    }

    /// Every backend's block counts must equal scalar's across widths
    /// (incl. asymmetric and beyond-MAX_K), batch blocks, and plane
    /// lengths that cover the fused short path, its vector tails, the
    /// long-plane (Harley–Seal / multi-u8-block) path, and the empty case.
    #[test]
    fn block_counts_matches_scalar_across_backends() {
        let mut rng = Rng::new(0xBEE5);
        for (kw, kx, b) in [(1, 1, 1), (2, 2, 4), (3, 2, 5), (2, 3, 3), (4, 4, 4), (5, 6, 2)] {
            for words in [0usize, 1, 3, 4, 5, 15, 16, 17, 33, 63, 64, 65, 130] {
                let wplanes = mk_planes(&mut rng, kw, words);
                let xplanes = mk_planes(&mut rng, b * kx, words);
                let w: Vec<&[u64]> = wplanes.iter().map(|p| &p[..]).collect();
                let cols: Vec<Vec<&[u64]>> = (0..b)
                    .map(|j| (0..kx).map(|s| &xplanes[j * kx + s][..]).collect())
                    .collect();
                let x_block: Vec<&[&[u64]]> = cols.iter().map(|c| &c[..]).collect();
                let mut want = vec![0u32; b * kw * kx];
                scalar::block_counts(&w, &x_block, &mut want);
                for k in Kernel::available() {
                    let mut got = vec![0u32; b * kw * kx];
                    block_counts(k, &w, &x_block, &mut got);
                    assert_eq!(got, want, "{k} kw={kw} kx={kx} b={b} words={words}");
                }
            }
        }
    }

    /// Edge patterns: identical planes count zero, all-ones complements
    /// count full width — on every backend, through the one primitive.
    #[test]
    fn block_counts_edge_patterns() {
        let mut rng = Rng::new(0xC0DE);
        for words in [4usize, 16, 65] {
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let ones = vec![u64::MAX; words];
            let w: [&[u64]; 1] = [&a];
            let self_col: [&[u64]; 1] = [&a];
            let ones_col: [&[u64]; 1] = [&ones];
            let block: [&[&[u64]]; 2] = [&self_col, &ones_col];
            let want_ones: u32 = a.iter().map(|x| (x ^ u64::MAX).count_ones()).sum();
            for k in Kernel::available() {
                let mut got = [0u32; 2];
                block_counts(k, &w, &block, &mut got);
                assert_eq!(got[0], 0, "{k} self words={words}");
                assert_eq!(got[1], want_ones, "{k} ones words={words}");
            }
        }
    }
}
