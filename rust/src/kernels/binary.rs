//! Binary XNOR/popcount matrix–vector kernels (Appendix A of the paper).
//!
//! The quantized product between a `k_w`-bit row-quantized matrix and a
//! `k_h`-bit quantized vector decomposes into `k_w · k_h` binary dot
//! products per row:
//!
//! ```text
//! y_r = Σ_t Σ_s  α_w[r,t] · α_x[s] · ⟨b_w[r,t], b_x[s]⟩
//! ⟨a, b⟩ = n − 2·popcount(a XOR b)        (the 1-bit identity)
//! ```
//!
//! The paper implements XOR with `_mm256_xor_ps` and popcount with
//! `_popcnt64`. Here every mismatch-count inner loop goes through the one
//! fused batch-block primitive of the runtime-dispatched backend layer
//! ([`super::backend::block_counts`]): the portable scalar kernel, the
//! AVX2 fused block kernel (per-chain byte accumulators on short planes,
//! Harley–Seal on long ones), and the NEON `vcntq_u8` fused kernel.
//! Because the counts are **exact integers** whatever the instruction mix,
//! and the float reduction below is shared by every backend, the f32
//! outputs are bit-identical across backends, batch sizes, and thread
//! counts (`rust/tests/kernel_parity.rs`, `rust/tests/exec_parity.rs`).
//!
//! Activations are quantized **online** with the alternating method
//! (`T = 2`) — its cost is the "Quant" column of Table 6.

use crate::exec::{Exec, SendPtr};
use crate::kernels::backend::{self, Kernel, MAX_K};
use crate::kernels::cost;
use crate::model::batch::OutputBatch;
use crate::quant::{alternating, Method, Quantized, QuantizedBatch, RowQuantized};

/// Quantize an activation vector online (paper setting: alternating, T=2).
pub fn quantize_activations(x: &[f32], k: usize) -> Quantized {
    alternating::quantize(x, k, 2)
}

/// Quantize activations with an arbitrary method (for ablations).
pub fn quantize_activations_with(x: &[f32], k: usize, method: Method) -> Quantized {
    crate::quant::quantize(x, k, method)
}

/// `y = Ŵ x̂` where both operands are already quantized.
/// `y.len() == w.rows`; panics on shape mismatch.
///
/// Legacy `RowQuantized` entry point (the trainer's path); runs on the
/// process-wide active backend ([`backend::active`]) through the same
/// one-column block primitive as [`PreparedGemm::gemv`], just over
/// scattered plane storage. Any bit width works (the backends route
/// widths beyond `MAX_K` through their pairwise arm). The serving path
/// uses [`PreparedGemm`], whose contiguous layout streams better.
pub fn quantized_gemv(w: &RowQuantized, x: &Quantized, y: &mut [f32]) {
    assert_eq!(w.cols, x.n, "inner dimension mismatch");
    assert_eq!(y.len(), w.rows);
    let kernel = backend::active();
    let (kw, kx) = (w.k, x.k());
    let n = w.cols as i32;
    let xp: Vec<&[u64]> = x.planes.iter().map(|p| p.words()).collect();
    let col: [&[&[u64]]; 1] = [&xp[..]];
    let mut wp: Vec<&[u64]> = Vec::with_capacity(kw);
    let mut counts = vec![0u32; kw * kx];
    for (r, yr) in y.iter_mut().enumerate() {
        wp.clear();
        wp.extend(w.planes[r * kw..(r + 1) * kw].iter().map(|p| p.words()));
        counts.fill(0);
        backend::block_counts(kernel, &wp, &col, &mut counts);
        let mut acc = 0.0f32;
        for t in 0..kw {
            let mut inner = 0.0f32;
            for (s, &c) in counts[t * kx..(t + 1) * kx].iter().enumerate() {
                inner += x.alphas[s] * (n - 2 * c as i32) as f32;
            }
            acc += w.alphas[r * kw + t] * inner;
        }
        *yr = acc;
    }
}

/// Serving-path matrix: the planes of [`RowQuantized`] repacked into one
/// contiguous buffer, layout `[row][plane][word]`, so a row's entire k·words
/// working set streams sequentially from memory (Perf iteration 2 — the
/// per-plane `Vec`s of `RowQuantized` scatter across the heap).
///
/// The same layout serves the single-vector path ([`Self::gemv`]) and the
/// batched path ([`Self::gemm`], Fig. 3 right): the batched kernel sweeps
/// each packed weight row **once per batch**, amortizing the DRAM traffic
/// of the weight planes over all `B` activation columns.
///
/// Each instance carries the [`Kernel`] backend its count loops dispatch
/// to — resolved from [`backend::active`] at construction (forced choice >
/// `AMQ_KERNEL` > runtime detection) and overridable per-instance via
/// [`Self::set_kernel`]. Backends only change *how* the exact integer
/// mismatch counts are computed, never the float reduction, so every
/// backend is bit-exact against scalar.
#[derive(Clone, Debug)]
pub struct PreparedGemm {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    words_per_plane: usize,
    data: Vec<u64>,
    alphas: Vec<f32>, // rows * k
    kernel: Kernel,
    /// L2 byte budget the batched driver sizes its column tiles against
    /// ([`cost::l2_bytes`] at construction; overridable per instance for
    /// tests/benches via [`Self::set_l2_budget`]).
    l2_budget: usize,
}

/// Historical name of [`PreparedGemm`] from the single-vector era; the
/// B=1 entry points (`gemv`, `online_gemv`) still exist on the new type.
pub type PreparedGemv = PreparedGemm;

/// Batch-block width of the batched kernel: columns handed to the fused
/// block primitive together per weight-row pass. 4 keeps the k_w·k_x·BB
/// chain accumulators within the SIMD backends' register budget at the
/// paper's bit widths.
const GEMM_BLOCK: usize = 4;

/// Minimum output rows per worker task when row-sharding the batched GEMM.
/// 1 ⇒ oversubscription (`threads > rows`) degenerates to one task per row;
/// correctness never depends on the partition (each output element has
/// exactly one producer).
const GEMM_MIN_ROWS_PER_TASK: usize = 1;

/// Byte cap on the next-row software prefetch: enough to cover the packed
/// planes of every serving shape (W2 at 1024 cols = 256 bytes per row),
/// small enough not to flood the L1 fill buffers on huge-row matrices
/// where the hardware streamer takes over anyway.
const PREFETCH_ROW_MAX_BYTES: usize = 4096;

/// The batch-tile width serving would use for a `cols`-column layer with
/// `k_x`-bit activations, at the process-wide L2 budget — the number the
/// `amq serve` startup line and STATS report (see [`cost::tile_cols`]).
pub fn serving_tile_cols(cols: usize, k_x: usize) -> usize {
    cost::tile_cols(cols.div_ceil(64), k_x, cost::l2_bytes(), GEMM_BLOCK)
}

impl PreparedGemm {
    /// Build on the process-wide active backend ([`backend::active`]).
    pub fn new(w: &RowQuantized) -> Self {
        Self::with_kernel(w, backend::active())
    }

    /// Build with an explicit backend (resolved against availability —
    /// an unavailable choice falls back to scalar).
    pub fn with_kernel(w: &RowQuantized, kernel: Kernel) -> Self {
        let wpp = w.cols.div_ceil(64);
        let mut data = Vec::with_capacity(w.rows * w.k * wpp);
        for plane in &w.planes {
            data.extend_from_slice(plane.words());
        }
        PreparedGemm {
            rows: w.rows,
            cols: w.cols,
            k: w.k,
            words_per_plane: wpp,
            data,
            alphas: w.alphas.clone(),
            kernel: kernel.resolve(),
            l2_budget: cost::l2_bytes(),
        }
    }

    /// Reassemble a prepared matrix from the contiguous buffers that
    /// [`Self::plane_words`] / [`Self::alphas`] expose — the `.amqz`
    /// loader's constructor. The packed planes go straight from the file
    /// arena into the serving layout with **no requantization**; only
    /// shape and tail-bit invariants are checked. Dispatches on the
    /// process-wide active backend, like [`Self::new`].
    pub fn from_raw_parts(
        rows: usize,
        cols: usize,
        k: usize,
        data: Vec<u64>,
        alphas: Vec<f32>,
    ) -> Result<Self, String> {
        if rows == 0 || cols == 0 || k == 0 {
            return Err(format!("degenerate matrix shape {rows}x{cols} k={k}"));
        }
        let wpp = cols.div_ceil(64);
        let planes = rows
            .checked_mul(k)
            .ok_or_else(|| format!("matrix shape {rows}x{cols} k={k} overflows"))?;
        if alphas.len() != planes {
            return Err(format!("expected {planes} alphas, got {}", alphas.len()));
        }
        let words = planes
            .checked_mul(wpp)
            .ok_or_else(|| format!("matrix shape {rows}x{cols} k={k} overflows"))?;
        if data.len() != words {
            return Err(format!("expected {words} plane words, got {}", data.len()));
        }
        // Same invariant `PackedBits::from_words` asserts: bits past `cols`
        // in each plane's last word must be zero (the count kernels rely
        // on a clean tail). A corrupt file fails here instead of panicking.
        if cols % 64 != 0 {
            for (p, plane) in data.chunks_exact(wpp).enumerate() {
                if plane[wpp - 1] >> (cols % 64) != 0 {
                    return Err(format!("plane {p} has nonzero bits past column {cols}"));
                }
            }
        }
        Ok(PreparedGemm {
            rows,
            cols,
            k,
            words_per_plane: wpp,
            data,
            alphas,
            kernel: backend::active().resolve(),
            l2_budget: cost::l2_bytes(),
        })
    }

    /// The packed planes as one contiguous buffer, layout
    /// `[row][plane][word]` with `cols.div_ceil(64)` words per plane —
    /// exactly what the `.amqz` format stores.
    pub fn plane_words(&self) -> &[u64] {
        &self.data
    }

    /// The `rows * k` row coefficients, row-major (`alphas[r*k + t]`).
    pub fn alphas(&self) -> &[f32] {
        &self.alphas
    }

    /// The backend this matrix dispatches its count loops to.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Override the backend (resolved against availability). Outputs stay
    /// bit-identical — only wall time changes.
    pub fn set_kernel(&mut self, kernel: Kernel) {
        self.kernel = kernel.resolve();
    }

    /// The L2 byte budget the batched driver tiles against.
    pub fn l2_budget(&self) -> usize {
        self.l2_budget
    }

    /// Override the tile budget (tests/benches — e.g. `usize::MAX` forces
    /// a single tile, tiny values force many). Outputs stay bit-identical
    /// at any budget: tiling only reorders whole output elements, each of
    /// which is produced by exactly one `block_counts` call and one
    /// element-local float reduction. Only wall time changes.
    pub fn set_l2_budget(&mut self, bytes: usize) {
        self.l2_budget = bytes.max(1);
    }

    /// Batch-tile width (columns) the batched driver uses for activations
    /// of depth `k_x`: wide enough to amortize the weight stream, narrow
    /// enough that the tile's packed activation planes stay L2-resident
    /// (see [`cost::tile_cols`]).
    pub fn tile_cols(&self, k_x: usize) -> usize {
        cost::tile_cols(self.words_per_plane, k_x, self.l2_budget, GEMM_BLOCK)
    }

    /// The plane slices of row `r`, gathered into `wp[..k]`.
    #[inline]
    fn row_planes<'a>(&'a self, r: usize, wp: &mut [&'a [u64]; MAX_K]) {
        let wpp = self.words_per_plane;
        let row = &self.data[r * self.k * wpp..(r + 1) * self.k * wpp];
        for (t, slot) in wp.iter_mut().enumerate().take(self.k) {
            *slot = &row[t * wpp..(t + 1) * wpp];
        }
    }

    /// Fused single-pass GEMV over the contiguous layout: a one-column
    /// batch block of the same slice-based primitive as [`Self::gemm`],
    /// reduced in the identical order — so `gemm` bit-matches `gemv`
    /// column by column.
    pub fn gemv(&self, x: &Quantized, y: &mut [f32]) {
        assert_eq!(self.cols, x.n, "inner dimension mismatch");
        assert_eq!(y.len(), self.rows);
        let (kw, kx) = (self.k, x.k());
        assert!(kw <= MAX_K && kx <= MAX_K, "bit width beyond MAX_K");
        let n = self.cols as i32;
        let mut xp: [&[u64]; MAX_K] = [&[]; MAX_K];
        for (s, p) in x.planes.iter().enumerate() {
            xp[s] = p.words();
        }
        let col: [&[&[u64]]; 1] = [&xp[..kx]];
        let mut counts = [0u32; MAX_K * MAX_K];
        let mut wp: [&[u64]; MAX_K] = [&[]; MAX_K];
        for (r, yr) in y.iter_mut().enumerate() {
            self.row_planes(r, &mut wp);
            let cnt = &mut counts[..kw * kx];
            cnt.fill(0);
            backend::block_counts(self.kernel, &wp[..kw], &col, cnt);
            let mut acc = 0.0f32;
            for t in 0..kw {
                let mut inner = 0.0f32;
                for (s, &c) in cnt[t * kx..(t + 1) * kx].iter().enumerate() {
                    inner += x.alphas[s] * (n - 2 * c as i32) as f32;
                }
                acc += self.alphas[r * kw + t] * inner;
            }
            *yr = acc;
        }
    }

    /// Quantize the input online, then run the fused GEMV (the full
    /// request-path operation of Table 6).
    pub fn online_gemv(&self, x: &[f32], k_x: usize, y: &mut [f32]) {
        let xq = quantize_activations(x, k_x);
        self.gemv(&xq, y);
    }

    /// Dense reconstruction (for `Linear::to_dense` and eval paths).
    ///
    /// Word-at-a-time expansion (one shift per element) in the same
    /// plane-major, ascending-column accumulation order as the per-bit
    /// reference, so the result is bit-identical to
    /// [`RowQuantized::dequantize`].
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let wpp = self.words_per_plane;
        for r in 0..self.rows {
            let o = &mut out[r * self.cols..(r + 1) * self.cols];
            for t in 0..self.k {
                let alpha = self.alphas[r * self.k + t];
                let words = &self.data[(r * self.k + t) * wpp..(r * self.k + t + 1) * wpp];
                for (wi, &word) in words.iter().enumerate() {
                    let base = wi * 64;
                    let live = 64.min(self.cols - base);
                    let mut bits = word;
                    for v in o[base..base + live].iter_mut() {
                        *v += if bits & 1 == 1 { alpha } else { -alpha };
                        bits >>= 1;
                    }
                }
            }
        }
        out
    }

    /// Packed footprint in bytes (planes + coefficients).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8 + self.alphas.len() * 4
    }

    /// Batched XNOR/popcount GEMM: `Y[b] = Ŵ x̂[b]` for every column of the
    /// batch, `y` row-major `batch × rows` (serial engine).
    ///
    /// All batch blocks of a weight row's **tile** complete before the next
    /// row is touched, so the packed weight planes stream from memory once
    /// per L2-sized batch tile — one tile covers the whole batch at serving
    /// sizes, the concatenated layout of Fig. 3 (right) — while the tile's
    /// activation planes stay cache-resident. Each output is reduced in
    /// exactly the order of [`Self::gemv`], so `gemm` bit-matches `gemv`
    /// column by column at any tile size.
    pub fn gemm(&self, x: &QuantizedBatch, y: &mut [f32]) {
        self.gemm_exec(x, y, &Exec::serial());
    }

    /// Row-sharded batched GEMM: the output rows are split into disjoint
    /// contiguous ranges, one per worker of `exec`. Every `y[b·rows + r]`
    /// is produced by exactly one task running the identical scalar
    /// reduction as the serial path, so the result is **bit-exact for any
    /// thread count** (pinned by `rust/tests/exec_parity.rs`).
    pub fn gemm_exec(&self, x: &QuantizedBatch, y: &mut [f32], exec: &Exec) {
        assert_eq!(self.cols, x.n, "inner dimension mismatch");
        assert_eq!(y.len(), x.batch * self.rows, "output batch shape mismatch");
        assert!(self.k <= MAX_K && x.k <= MAX_K, "bit width beyond MAX_K");
        let out = SendPtr::new(y);
        let out = &out;
        exec.run_chunks(self.rows, GEMM_MIN_ROWS_PER_TASK, &|r0, r1| {
            self.gemm_rows(x, out, r0, r1)
        });
    }

    /// Prefetch the leading packed bytes of row `r`'s planes (capped at
    /// [`PREFETCH_ROW_MAX_BYTES`]) so the next row's weight stream is
    /// already in flight while the current row computes. x86_64 only
    /// (`prefetcht0` is baseline SSE there); a no-op elsewhere. Purely a
    /// hint — no architectural effect, so correctness is untouched.
    #[inline]
    fn prefetch_row_planes(&self, r: usize, r_end: usize) {
        #[cfg(target_arch = "x86_64")]
        {
            if r >= r_end {
                return;
            }
            use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            let wpp = self.words_per_plane;
            let row = &self.data[r * self.k * wpp..(r + 1) * self.k * wpp];
            let bytes = (row.len() * 8).min(PREFETCH_ROW_MAX_BYTES);
            let base = row.as_ptr() as *const i8;
            let mut off = 0usize;
            while off < bytes {
                // SAFETY: off < bytes ≤ the row slice's byte length, so the
                // address is in-bounds; prefetch reads nothing architecturally.
                unsafe { _mm_prefetch::<_MM_HINT_T0>(base.add(off)) };
                off += 64;
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (r, r_end);
        }
    }

    /// The one batched driver, over output rows `r0..r1`, **column-tiled**:
    /// the batch is cut into [`Self::tile_cols`]-wide tiles whose packed
    /// activation planes fit (half) the L2 budget; within a tile, each
    /// weight row's planes are loaded once, prefetching the next row's,
    /// and `GEMM_BLOCK`-column blocks go to the fused count primitive
    /// followed by the shared float reduction. At serving batch sizes the
    /// whole batch is one tile and the loop is identical to the untiled
    /// driver; at large batches the tile keeps activations cache-resident
    /// instead of re-streaming them from DRAM once per row. Bit-exact at
    /// any tile size: tiling only reorders whole output elements. Writes
    /// only indices `y[b·rows + r]` with `r ∈ [r0, r1)` — the
    /// disjoint-write contract of the row sharding.
    fn gemm_rows(&self, x: &QuantizedBatch, out: &SendPtr<f32>, r0: usize, r1: usize) {
        let (kw, kx) = (self.k, x.k);
        let n = self.cols as i32;
        let tile = self.tile_cols(kx);
        let mut wp: [&[u64]; MAX_K] = [&[]; MAX_K];
        let mut counts = [0u32; GEMM_BLOCK * MAX_K * MAX_K];
        let mut c0 = 0usize;
        while c0 < x.batch {
            let c1 = (c0 + tile).min(x.batch);
            for r in r0..r1 {
                self.row_planes(r, &mut wp);
                self.prefetch_row_planes(r + 1, r1);
                let mut b0 = c0;
                while b0 < c1 {
                    let bb = GEMM_BLOCK.min(c1 - b0);
                    // Per-column plane slices of this batch block.
                    let mut planes: [[&[u64]; MAX_K]; GEMM_BLOCK] = [[&[]; MAX_K]; GEMM_BLOCK];
                    for (j, pj) in planes.iter_mut().enumerate().take(bb) {
                        for (s, slot) in pj.iter_mut().enumerate().take(kx) {
                            *slot = x.plane_words(b0 + j, s);
                        }
                    }
                    let cols: [&[&[u64]]; GEMM_BLOCK] = std::array::from_fn(|j| &planes[j][..kx]);
                    let cnt = &mut counts[..bb * kw * kx];
                    cnt.fill(0);
                    backend::block_counts(self.kernel, &wp[..kw], &cols[..bb], cnt);
                    for j in 0..bb {
                        let b = b0 + j;
                        let mut acc = 0.0f32;
                        for t in 0..kw {
                            let mut inner = 0.0f32;
                            let row_c = &cnt[(j * kw + t) * kx..(j * kw + t + 1) * kx];
                            for (s, &c) in row_c.iter().enumerate() {
                                inner += x.alpha(b, s) * (n - 2 * c as i32) as f32;
                            }
                            acc += self.alphas[r * kw + t] * inner;
                        }
                        // SAFETY: r ∈ [r0, r1) — this task's disjoint row range.
                        unsafe { out.write(b * self.rows + r, acc) };
                    }
                    b0 += bb;
                }
            }
            c0 = c1;
        }
    }

    /// Batched GEMM into a caller-owned [`OutputBatch`], resized in place
    /// (capacity kept) — the workspace-reuse entry point of the serving
    /// path. Identical counts and reduction order to [`Self::gemm`]; only
    /// the output's ownership differs. The per-row count scratch is already
    /// stack-resident (`GEMM_BLOCK · MAX_K²` words inside the driver), so a
    /// steady-state call performs no heap allocation.
    pub fn gemm_into(&self, x: &QuantizedBatch, y: &mut OutputBatch) {
        self.gemm_into_exec(x, y, &Exec::serial());
    }

    /// [`Self::gemm_into`] on an execution engine (row-sharded exactly like
    /// [`Self::gemm_exec`], bit-exact for any thread count).
    pub fn gemm_into_exec(&self, x: &QuantizedBatch, y: &mut OutputBatch, exec: &Exec) {
        y.reset(x.batch, self.rows);
        self.gemm_exec(x, y.data_mut(), exec);
    }

    /// Quantize a row-major `batch × cols` activation matrix online, then
    /// run the batched GEMM (full request path for a timestep batch).
    pub fn online_gemm(&self, x: &[f32], batch: usize, k_x: usize, y: &mut [f32]) {
        self.online_gemm_exec(x, batch, k_x, y, &Exec::serial());
    }

    /// [`Self::online_gemm`] on an execution engine: the per-row online
    /// quantization and the GEMM rows are both sharded across the workers.
    pub fn online_gemm_exec(&self, x: &[f32], batch: usize, k_x: usize, y: &mut [f32], exec: &Exec) {
        let xq = QuantizedBatch::quantize_exec(x, batch, self.cols, k_x, exec);
        self.gemm_exec(&xq, y, exec);
    }
}

/// Full online path of Table 6: quantize `x` (the "Quant" share), then run
/// the binary GEMV. Returns `(y, quant_fraction_estimate_unused)`.
pub fn online_gemv(w: &RowQuantized, x: &[f32], k_x: usize, y: &mut [f32]) {
    let xq = quantize_activations(x, k_x);
    quantized_gemv(w, &xq, y);
}

/// Batched variant: `Y = Ŵ X̂` for `batch` activation vectors (columns of a
/// row-major `batch × n` matrix). The weight planes are streamed once per
/// batch — the concatenated layout of Fig. 3 (right).
pub fn quantized_gemv_batch(
    w: &RowQuantized,
    xs: &[Quantized],
    y: &mut [f32], // batch * rows, row-major per request
) {
    assert_eq!(y.len(), xs.len() * w.rows);
    for (b, xq) in xs.iter().enumerate() {
        quantized_gemv(w, xq, &mut y[b * w.rows..(b + 1) * w.rows]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense;
    use crate::quant::Method;
    use crate::util::prop;
    use crate::util::Rng;

    /// The core exactness property: the binary kernel must equal the dense
    /// GEMV computed on the *dequantized* operands (the popcount identity is
    /// exact; only float summation order differs).
    #[test]
    fn binary_gemv_equals_dense_on_dequantized_property() {
        prop::check(
            "binary-gemv-exact",
            prop::Config { cases: 60, ..Default::default() },
            |rng| {
                let m = 1 + rng.below(24);
                let n = 1 + rng.below(200);
                let kw = 1 + rng.below(3);
                let kx = 1 + rng.below(3);
                let w: Vec<f32> = (0..m * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let x: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                (m, n, kw, kx, w, x)
            },
            |_| vec![],
            |(m, n, kw, kx, w, x)| {
                let wq = RowQuantized::quantize(w, *m, *n, *kw, Method::Alternating { t: 2 });
                let xq = quantize_activations(x, *kx);
                let mut y = vec![0.0f32; *m];
                quantized_gemv(&wq, &xq, &mut y);

                let wd = wq.dequantize();
                let xd = xq.dequantize();
                let mut yd = vec![0.0f32; *m];
                dense::gemv(&wd, *m, *n, &xd, &mut yd);
                y.iter().zip(&yd).all(|(a, b)| (a - b).abs() < 1e-3 * (1.0 + b.abs()))
            },
        );
    }

    #[test]
    fn approximates_full_precision_gemv() {
        // End-to-end: quantized product should track the FP product within
        // the quantization error budget.
        let mut rng = Rng::new(101);
        let (m, n) = (128, 512);
        let w = rng.normal_vec(m * n, 0.1);
        let x = rng.normal_vec(n, 0.5);
        let wq = RowQuantized::quantize(&w, m, n, 3, Method::Alternating { t: 2 });
        let mut y = vec![0.0; m];
        online_gemv(&wq, &x, 3, &mut y);
        let mut y_fp = vec![0.0; m];
        dense::gemv(&w, m, n, &x, &mut y_fp);
        // Relative output error is bounded by the combined weight+activation
        // quantization error (~4–5% each at 3 bits, compounding in the product).
        let num: f64 = y.iter().zip(&y_fp).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = y_fp.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(num / den < 0.2, "output relative error {}", num / den);
    }

    #[test]
    fn prepared_matches_quantized_gemv() {
        let mut rng = Rng::new(103);
        for (m, n, kw, kx) in [(17, 100, 2, 2), (8, 64, 3, 2), (5, 300, 4, 4)] {
            let w = rng.normal_vec(m * n, 0.3);
            let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
            let prep = PreparedGemm::new(&wq);
            let xq = quantize_activations(&rng.normal_vec(n, 1.0), kx);
            let mut y1 = vec![0.0; m];
            let mut y2 = vec![0.0; m];
            quantized_gemv(&wq, &xq, &mut y1);
            prep.gemv(&xq, &mut y2);
            assert_eq!(y1, y2, "m={m} n={n} kw={kw} kx={kx}");
            // Dequantization also agrees (word-wise fast path vs per-bit
            // reference inside RowQuantized).
            assert_eq!(prep.dequantize(), wq.dequantize());
        }
    }

    /// Bit widths beyond MAX_K still work on the legacy path (the backends
    /// route them through their pairwise arm) and stay exact vs dense.
    #[test]
    fn exotic_bit_widths_stay_exact() {
        let mut rng = Rng::new(107);
        let (m, n) = (7, 90);
        let w = rng.normal_vec(m * n, 0.3);
        let wq = RowQuantized::quantize(&w, m, n, 6, Method::Greedy);
        let xq = quantize_activations(&rng.normal_vec(n, 1.0), 5);
        let mut y = vec![0.0f32; m];
        quantized_gemv(&wq, &xq, &mut y);
        let wd = wq.dequantize();
        let xd = xq.dequantize();
        let mut yd = vec![0.0f32; m];
        dense::gemv(&wd, m, n, &xd, &mut yd);
        for (a, b) in y.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let mut rng = Rng::new(102);
        let (m, n, bsz) = (16, 96, 4);
        let w = rng.normal_vec(m * n, 0.2);
        let wq = RowQuantized::quantize(&w, m, n, 2, Method::Greedy);
        let xs: Vec<Quantized> = (0..bsz)
            .map(|_| quantize_activations(&rng.normal_vec(n, 1.0), 2))
            .collect();
        let mut y = vec![0.0; bsz * m];
        quantized_gemv_batch(&wq, &xs, &mut y);
        for (b, xq) in xs.iter().enumerate() {
            let mut yb = vec![0.0; m];
            quantized_gemv(&wq, xq, &mut yb);
            assert_eq!(&y[b * m..(b + 1) * m], &yb[..]);
        }
    }

    #[test]
    fn gemm_bitmatches_gemv_per_column() {
        // The batched kernel must be EXACT against the single-vector kernel
        // for every column — same counts, same reduction order.
        let mut rng = Rng::new(104);
        for (kw, kx) in [(1, 1), (1, 2), (2, 2), (2, 3), (3, 2), (3, 3), (4, 4)] {
            for batch in [1usize, 2, 3, 4, 5, 9] {
                let (m, n) = (13, 130);
                let w = rng.normal_vec(m * n, 0.3);
                let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
                let prep = PreparedGemm::new(&wq);
                let x = rng.normal_vec(batch * n, 1.0);
                let xq = QuantizedBatch::quantize(&x, batch, n, kx);
                let mut y = vec![0.0f32; batch * m];
                prep.gemm(&xq, &mut y);
                for b in 0..batch {
                    let mut yb = vec![0.0f32; m];
                    prep.gemv(&xq.column(b), &mut yb);
                    assert_eq!(
                        &y[b * m..(b + 1) * m],
                        &yb[..],
                        "kw={kw} kx={kx} batch={batch} col={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_into_matches_gemm_with_reused_output() {
        let mut rng = Rng::new(108);
        let (m, n) = (9, 100);
        let w = rng.normal_vec(m * n, 0.3);
        let prep = PreparedGemm::new(&RowQuantized::quantize(&w, m, n, 2, Method::Greedy));
        let mut out = OutputBatch::zeros(0, 0);
        for batch in [4usize, 1, 7] {
            let xq = QuantizedBatch::quantize(&rng.normal_vec(batch * n, 1.0), batch, n, 2);
            let mut want = vec![0.0f32; batch * m];
            prep.gemm(&xq, &mut want);
            prep.gemm_into(&xq, &mut out);
            assert_eq!(out.batch(), batch);
            assert_eq!(out.dim(), m);
            assert_eq!(out.data(), &want[..], "batch={batch}");
        }
    }

    #[test]
    fn online_gemm_matches_online_gemv_per_column() {
        let mut rng = Rng::new(105);
        let (m, n, batch, k) = (11, 96, 6, 2);
        let w = rng.normal_vec(m * n, 0.2);
        let prep = PreparedGemm::new(&RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 }));
        let x = rng.normal_vec(batch * n, 1.0);
        let mut y = vec![0.0f32; batch * m];
        prep.online_gemm(&x, batch, k, &mut y);
        for b in 0..batch {
            let mut yb = vec![0.0f32; m];
            prep.online_gemv(&x[b * n..(b + 1) * n], k, &mut yb);
            assert_eq!(&y[b * m..(b + 1) * m], &yb[..], "col {b}");
        }
    }

    /// Every available backend must reproduce the scalar outputs exactly
    /// (the quick in-module check; the full grid lives in
    /// `rust/tests/kernel_parity.rs`).
    #[test]
    fn backends_bitmatch_scalar_gemv_and_gemm() {
        let mut rng = Rng::new(106);
        // n=1090 exercises the SIMD main loops + tails; n=70 is tail-only.
        for (m, n, kw, kx) in [(7, 1090, 2, 2), (5, 70, 3, 2), (4, 130, 4, 4)] {
            let w = rng.normal_vec(m * n, 0.3);
            let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
            let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
            let xq = quantize_activations(&rng.normal_vec(n, 1.0), kx);
            let mut y_ref = vec![0.0f32; m];
            reference.gemv(&xq, &mut y_ref);
            let batch = 5;
            let xb = QuantizedBatch::quantize(&rng.normal_vec(batch * n, 1.0), batch, n, kx);
            let mut g_ref = vec![0.0f32; batch * m];
            reference.gemm(&xb, &mut g_ref);
            for kernel in Kernel::available() {
                let prep = PreparedGemm::with_kernel(&wq, kernel);
                assert_eq!(prep.kernel(), kernel);
                let mut y = vec![0.0f32; m];
                prep.gemv(&xq, &mut y);
                assert_eq!(y, y_ref, "gemv {kernel} m={m} n={n} kw={kw} kx={kx}");
                let mut g = vec![0.0f32; batch * m];
                prep.gemm(&xb, &mut g);
                assert_eq!(g, g_ref, "gemm {kernel} m={m} n={n} kw={kw} kx={kx}");
            }
        }
    }

    /// Tiling is bit-neutral by construction: every budget — from one
    /// tile per GEMM_BLOCK to a single tile for the whole batch — must
    /// produce byte-identical outputs, on the serial and threaded paths.
    #[test]
    fn tiling_is_bit_neutral_across_budgets() {
        let mut rng = Rng::new(109);
        let (m, n, kw, kx) = (13, 200, 2, 2);
        let w = rng.normal_vec(m * n, 0.3);
        let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
        for batch in [1usize, 5, 17, 64] {
            let xq = QuantizedBatch::quantize(&rng.normal_vec(batch * n, 1.0), batch, n, kx);
            let mut reference = PreparedGemm::new(&wq);
            reference.set_l2_budget(usize::MAX); // single tile
            assert!(reference.tile_cols(kx) >= batch);
            let mut want = vec![0.0f32; batch * m];
            reference.gemm(&xq, &mut want);
            for budget in [1usize, 64, 4096, 1 << 20] {
                let mut prep = PreparedGemm::new(&wq);
                prep.set_l2_budget(budget);
                let mut got = vec![0.0f32; batch * m];
                prep.gemm(&xq, &mut got);
                assert_eq!(got, want, "budget={budget} batch={batch}");
                let exec = Exec::new(crate::exec::ExecConfig::with_threads(3));
                let mut got_mt = vec![0.0f32; batch * m];
                prep.gemm_exec(&xq, &mut got_mt, &exec);
                assert_eq!(got_mt, want, "threaded budget={budget} batch={batch}");
            }
        }
    }

    /// The instance tile width honors the budget override and matches the
    /// cost-model helper the startup line reports.
    #[test]
    fn tile_cols_follows_the_budget() {
        let wq = RowQuantized::quantize(&[0.5; 2 * 1024], 2, 1024, 2, Method::Greedy);
        let mut prep = PreparedGemm::new(&wq);
        assert_eq!(prep.tile_cols(2), cost::tile_cols(16, 2, prep.l2_budget(), GEMM_BLOCK));
        prep.set_l2_budget(1); // degenerate: clamps to one GEMM_BLOCK
        assert_eq!(prep.tile_cols(2), GEMM_BLOCK);
        // 512 KB budget, 1024 cols (16 words), k_x=2: 256 KB / 256 B per
        // column = 1024 columns per tile.
        prep.set_l2_budget(512 * 1024);
        assert_eq!(prep.tile_cols(2), 1024);
        // serving_tile_cols is the same formula at the process-wide budget.
        assert_eq!(
            serving_tile_cols(1024, 2),
            cost::tile_cols(16, 2, cost::l2_bytes(), GEMM_BLOCK)
        );
    }

    #[test]
    fn unavailable_kernel_resolves_to_scalar_on_construction() {
        let wq = RowQuantized::quantize(&[0.5; 12], 3, 4, 2, Method::Greedy);
        for k in [Kernel::Avx2, Kernel::Avx512, Kernel::Neon] {
            if !k.is_available() {
                let prep = PreparedGemm::with_kernel(&wq, k);
                assert_eq!(prep.kernel(), Kernel::Scalar);
            }
        }
        let mut prep = PreparedGemm::new(&wq);
        prep.set_kernel(Kernel::Scalar);
        assert_eq!(prep.kernel(), Kernel::Scalar);
    }

    #[test]
    #[should_panic(expected = "output batch shape mismatch")]
    fn gemm_shape_mismatch_panics() {
        let w = RowQuantized::quantize(&[0.0; 12], 3, 4, 2, Method::Greedy);
        let prep = PreparedGemm::new(&w);
        let xq = QuantizedBatch::quantize(&[0.0; 8], 2, 4, 2);
        let mut y = vec![0.0; 3]; // needs 2*3
        prep.gemm(&xq, &mut y);
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn shape_mismatch_panics() {
        let w = RowQuantized::quantize(&[0.0; 12], 3, 4, 2, Method::Greedy);
        let x = quantize_activations(&[0.0; 5], 2);
        let mut y = vec![0.0; 3];
        quantized_gemv(&w, &x, &mut y);
    }
}
