//! Binary XNOR/popcount matrix–vector kernels (Appendix A of the paper).
//!
//! The quantized product between a `k_w`-bit row-quantized matrix and a
//! `k_h`-bit quantized vector decomposes into `k_w · k_h` binary dot
//! products per row:
//!
//! ```text
//! y_r = Σ_t Σ_s  α_w[r,t] · α_x[s] · ⟨b_w[r,t], b_x[s]⟩
//! ⟨a, b⟩ = n − 2·popcount(a XOR b)        (the 1-bit identity)
//! ```
//!
//! The paper implements XOR with `_mm256_xor_ps` and popcount with
//! `_popcnt64`; on portable Rust the same dataflow is `u64 ^` +
//! `count_ones`, which LLVM lowers to the identical instructions.
//!
//! Activations are quantized **online** with the alternating method
//! (`T = 2`) — its cost is the "Quant" column of Table 6.

use crate::exec::{Exec, SendPtr};
use crate::quant::{alternating, Method, PackedBits, Quantized, QuantizedBatch, RowQuantized};

/// Quantize an activation vector online (paper setting: alternating, T=2).
pub fn quantize_activations(x: &[f32], k: usize) -> Quantized {
    alternating::quantize(x, k, 2)
}

/// Quantize activations with an arbitrary method (for ablations).
pub fn quantize_activations_with(x: &[f32], k: usize, method: Method) -> Quantized {
    crate::quant::quantize(x, k, method)
}

/// Max bit width the fused inner loop specializes for (the paper never
/// exceeds 4 bits).
const MAX_K: usize = 4;

/// `y = Ŵ x̂` where both operands are already quantized.
/// `y.len() == w.rows`; panics on shape mismatch.
///
/// Perf note (EXPERIMENTS.md §Perf): the k_w·k_x binary dot products of one
/// row are evaluated in a **single fused pass** over the packed words — the
/// activation plane words are loaded once per word index instead of k_w
/// times, and the k_w·k_x XOR+POPCNT chains are independent so they pipeline.
pub fn quantized_gemv(w: &RowQuantized, x: &Quantized, y: &mut [f32]) {
    assert_eq!(w.cols, x.n, "inner dimension mismatch");
    assert_eq!(y.len(), w.rows);
    let kw = w.k;
    let kx = x.k();
    if kw <= MAX_K && kx <= MAX_K {
        return fused_gemv(w, x, y);
    }
    // Fallback for exotic bit widths: plane-pair loop.
    let n = w.cols as i32;
    for (r, yr) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for t in 0..kw {
            let plane_w = &w.planes[r * kw + t];
            let alpha_w = w.alphas[r * kw + t];
            let mut inner = 0.0f32;
            for s in 0..kx {
                let dot = xor_popcount_dot(plane_w, &x.planes[s], n);
                inner += x.alphas[s] * dot as f32;
            }
            acc += alpha_w * inner;
        }
        *yr = acc;
    }
}

/// Serving-path matrix: the planes of [`RowQuantized`] repacked into one
/// contiguous buffer, layout `[row][plane][word]`, so a row's entire k·words
/// working set streams sequentially from memory (Perf iteration 2 — the
/// per-plane `Vec`s of `RowQuantized` scatter across the heap).
///
/// The same layout serves the single-vector path ([`Self::gemv`]) and the
/// batched path ([`Self::gemm`], Fig. 3 right): the batched kernel sweeps
/// each packed weight row **once per batch**, amortizing the DRAM traffic
/// of the weight planes over all `B` activation columns.
#[derive(Clone, Debug)]
pub struct PreparedGemm {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    words_per_plane: usize,
    data: Vec<u64>,
    alphas: Vec<f32>, // rows * k
}

/// Historical name of [`PreparedGemm`] from the single-vector era; the
/// B=1 entry points (`gemv`, `online_gemv`) still exist on the new type.
pub type PreparedGemv = PreparedGemm;

/// Batch-block width of the batched kernel: columns processed together per
/// weight-word load. 4 keeps the k_w·k_x·BB popcount counters in registers
/// at the paper's bit widths.
const GEMM_BLOCK: usize = 4;

/// Minimum output rows per worker task when row-sharding the batched GEMM.
/// 1 ⇒ oversubscription (`threads > rows`) degenerates to one task per row;
/// correctness never depends on the partition (each output element has
/// exactly one producer).
const GEMM_MIN_ROWS_PER_TASK: usize = 1;

impl PreparedGemm {
    pub fn new(w: &RowQuantized) -> Self {
        let wpp = w.cols.div_ceil(64);
        let mut data = Vec::with_capacity(w.rows * w.k * wpp);
        for plane in &w.planes {
            data.extend_from_slice(plane.words());
        }
        PreparedGemm {
            rows: w.rows,
            cols: w.cols,
            k: w.k,
            words_per_plane: wpp,
            data,
            alphas: w.alphas.clone(),
        }
    }

    /// Fused single-pass GEMV over the contiguous layout. Dispatches to a
    /// const-generic body so the k_w×k_x popcount counters live in registers
    /// and the plane loops fully unroll (Perf iteration 3).
    pub fn gemv(&self, x: &Quantized, y: &mut [f32]) {
        assert_eq!(self.cols, x.n, "inner dimension mismatch");
        assert_eq!(y.len(), self.rows);
        let (kw, kx) = (self.k, x.k());
        assert!(kw <= MAX_K && kx <= MAX_K, "bit width beyond MAX_K");
        match (kw, kx) {
            (1, 1) => self.gemv_const::<1, 1>(x, y),
            (2, 2) => self.gemv_const::<2, 2>(x, y),
            (2, 3) => self.gemv_const::<2, 3>(x, y),
            (3, 2) => self.gemv_const::<3, 2>(x, y),
            (3, 3) => self.gemv_const::<3, 3>(x, y),
            (4, 4) => self.gemv_const::<4, 4>(x, y),
            _ => self.gemv_generic(x, y),
        }
    }

    fn gemv_const<const KW: usize, const KX: usize>(&self, x: &Quantized, y: &mut [f32]) {
        let n = self.cols as i32;
        let wpp = self.words_per_plane;
        let xw: [&[u64]; KX] = std::array::from_fn(|s| x.planes[s].words());
        let row_words = KW * wpp;
        for (r, yr) in y.iter_mut().enumerate() {
            let row = &self.data[r * row_words..(r + 1) * row_words];
            let mut counts = [[0u32; KX]; KW];
            for i in 0..wpp {
                for t in 0..KW {
                    let ww = row[t * wpp + i];
                    for s in 0..KX {
                        counts[t][s] += (ww ^ xw[s][i]).count_ones();
                    }
                }
            }
            let mut acc = 0.0f32;
            for (t, row_c) in counts.iter().enumerate() {
                let mut inner = 0.0f32;
                for (s, &c) in row_c.iter().enumerate() {
                    inner += x.alphas[s] * (n - 2 * c as i32) as f32;
                }
                acc += self.alphas[r * KW + t] * inner;
            }
            *yr = acc;
        }
    }

    fn gemv_generic(&self, x: &Quantized, y: &mut [f32]) {
        let (kw, kx) = (self.k, x.k());
        let n = self.cols as i32;
        let wpp = self.words_per_plane;
        let xw: [&[u64]; MAX_K] = {
            let mut a: [&[u64]; MAX_K] = [&[]; MAX_K];
            for (s, p) in x.planes.iter().enumerate() {
                a[s] = p.words();
            }
            a
        };
        let row_words = kw * wpp;
        for (r, yr) in y.iter_mut().enumerate() {
            let row = &self.data[r * row_words..(r + 1) * row_words];
            let mut counts = [[0u32; MAX_K]; MAX_K];
            for i in 0..wpp {
                for (t, cs) in counts.iter_mut().enumerate().take(kw) {
                    let ww = row[t * wpp + i];
                    for (s, c) in cs.iter_mut().enumerate().take(kx) {
                        *c += (ww ^ xw[s][i]).count_ones();
                    }
                }
            }
            let mut acc = 0.0f32;
            for (t, row_c) in counts.iter().enumerate().take(kw) {
                let mut inner = 0.0f32;
                for (s, &c) in row_c.iter().enumerate().take(kx) {
                    inner += x.alphas[s] * (n - 2 * c as i32) as f32;
                }
                acc += self.alphas[r * kw + t] * inner;
            }
            *yr = acc;
        }
    }

    /// Quantize the input online, then run the fused GEMV (the full
    /// request-path operation of Table 6).
    pub fn online_gemv(&self, x: &[f32], k_x: usize, y: &mut [f32]) {
        let xq = quantize_activations(x, k_x);
        self.gemv(&xq, y);
    }

    /// Dense reconstruction (for `Linear::to_dense` and eval paths).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let wpp = self.words_per_plane;
        for r in 0..self.rows {
            for t in 0..self.k {
                let alpha = self.alphas[r * self.k + t];
                let words = &self.data[(r * self.k + t) * wpp..(r * self.k + t + 1) * wpp];
                let o = &mut out[r * self.cols..(r + 1) * self.cols];
                for (j, v) in o.iter_mut().enumerate() {
                    let bit = (words[j / 64] >> (j % 64)) & 1;
                    *v += if bit == 1 { alpha } else { -alpha };
                }
            }
        }
        out
    }

    /// Packed footprint in bytes (planes + coefficients).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8 + self.alphas.len() * 4
    }

    /// Batched XNOR/popcount GEMM: `Y[b] = Ŵ x̂[b]` for every column of the
    /// batch, `y` row-major `batch × rows` (serial engine).
    ///
    /// All batch blocks of a weight row complete before the next row is
    /// touched, so the packed weight planes stream from memory **once per
    /// batch** — the concatenated layout of Fig. 3 (right). Each output is
    /// reduced in exactly the order of [`Self::gemv`], so `gemm` bit-matches
    /// `gemv` column by column.
    pub fn gemm(&self, x: &QuantizedBatch, y: &mut [f32]) {
        self.gemm_exec(x, y, &Exec::serial());
    }

    /// Row-sharded batched GEMM: the output rows are split into disjoint
    /// contiguous ranges, one per worker of `exec`. Every `y[b·rows + r]`
    /// is produced by exactly one task running the identical scalar
    /// reduction as the serial path, so the result is **bit-exact for any
    /// thread count** (pinned by `rust/tests/exec_parity.rs`).
    pub fn gemm_exec(&self, x: &QuantizedBatch, y: &mut [f32], exec: &Exec) {
        assert_eq!(self.cols, x.n, "inner dimension mismatch");
        assert_eq!(y.len(), x.batch * self.rows, "output batch shape mismatch");
        let (kw, kx) = (self.k, x.k);
        assert!(kw <= MAX_K && kx <= MAX_K, "bit width beyond MAX_K");
        let out = SendPtr::new(y);
        let out = &out;
        exec.run_chunks(self.rows, GEMM_MIN_ROWS_PER_TASK, &|r0, r1| match (kw, kx) {
            (1, 1) => self.gemm_rows::<1, 1>(x, out, r0, r1),
            (2, 2) => self.gemm_rows::<2, 2>(x, out, r0, r1),
            (2, 3) => self.gemm_rows::<2, 3>(x, out, r0, r1),
            (3, 2) => self.gemm_rows::<3, 2>(x, out, r0, r1),
            (3, 3) => self.gemm_rows::<3, 3>(x, out, r0, r1),
            (4, 4) => self.gemm_rows::<4, 4>(x, out, r0, r1),
            _ => self.gemm_rows_generic(x, out, r0, r1),
        });
    }

    /// The batched kernel over output rows `r0..r1`. Writes only indices
    /// `y[b·rows + r]` with `r ∈ [r0, r1)` — the disjoint-write contract of
    /// the row sharding.
    fn gemm_rows<const KW: usize, const KX: usize>(
        &self,
        x: &QuantizedBatch,
        out: &SendPtr<f32>,
        r0: usize,
        r1: usize,
    ) {
        let n = self.cols as i32;
        let wpp = self.words_per_plane;
        let row_words = KW * wpp;
        for r in r0..r1 {
            let row = &self.data[r * row_words..(r + 1) * row_words];
            let mut b0 = 0;
            while b0 < x.batch {
                let bb = GEMM_BLOCK.min(x.batch - b0);
                // Per-column plane slices; tail entries beyond `bb` alias
                // column b0 and are never read.
                let xw: [[&[u64]; KX]; GEMM_BLOCK] = std::array::from_fn(|j| {
                    let b = b0 + if j < bb { j } else { 0 };
                    std::array::from_fn(|s| x.plane_words(b, s))
                });
                let mut counts = [[[0u32; KX]; KW]; GEMM_BLOCK];
                for i in 0..wpp {
                    for t in 0..KW {
                        // One load of the weight word serves every column of
                        // the block; the bb·k_x XOR+POPCNT chains pipeline.
                        let ww = row[t * wpp + i];
                        for (j, cj) in counts.iter_mut().enumerate().take(bb) {
                            for s in 0..KX {
                                cj[t][s] += (ww ^ xw[j][s][i]).count_ones();
                            }
                        }
                    }
                }
                for (j, cj) in counts.iter().enumerate().take(bb) {
                    let b = b0 + j;
                    let mut acc = 0.0f32;
                    for (t, row_c) in cj.iter().enumerate() {
                        let mut inner = 0.0f32;
                        for (s, &c) in row_c.iter().enumerate() {
                            inner += x.alpha(b, s) * (n - 2 * c as i32) as f32;
                        }
                        acc += self.alphas[r * KW + t] * inner;
                    }
                    // SAFETY: r ∈ [r0, r1) — this task's disjoint row range.
                    unsafe { out.write(b * self.rows + r, acc) };
                }
                b0 += bb;
            }
        }
    }

    fn gemm_rows_generic(&self, x: &QuantizedBatch, out: &SendPtr<f32>, r0: usize, r1: usize) {
        let (kw, kx) = (self.k, x.k);
        let n = self.cols as i32;
        let wpp = self.words_per_plane;
        let row_words = kw * wpp;
        for r in r0..r1 {
            let row = &self.data[r * row_words..(r + 1) * row_words];
            let mut b0 = 0;
            while b0 < x.batch {
                let bb = GEMM_BLOCK.min(x.batch - b0);
                let xw: [[&[u64]; MAX_K]; GEMM_BLOCK] = std::array::from_fn(|j| {
                    let b = b0 + if j < bb { j } else { 0 };
                    std::array::from_fn(|s| if s < kx { x.plane_words(b, s) } else { &[] })
                });
                let mut counts = [[[0u32; MAX_K]; MAX_K]; GEMM_BLOCK];
                for i in 0..wpp {
                    for t in 0..kw {
                        let ww = row[t * wpp + i];
                        for (j, cj) in counts.iter_mut().enumerate().take(bb) {
                            for (s, c) in cj[t].iter_mut().enumerate().take(kx) {
                                *c += (ww ^ xw[j][s][i]).count_ones();
                            }
                        }
                    }
                }
                for (j, cj) in counts.iter().enumerate().take(bb) {
                    let b = b0 + j;
                    let mut acc = 0.0f32;
                    for (t, row_c) in cj.iter().enumerate().take(kw) {
                        let mut inner = 0.0f32;
                        for (s, &c) in row_c.iter().enumerate().take(kx) {
                            inner += x.alpha(b, s) * (n - 2 * c as i32) as f32;
                        }
                        acc += self.alphas[r * kw + t] * inner;
                    }
                    // SAFETY: r ∈ [r0, r1) — this task's disjoint row range.
                    unsafe { out.write(b * self.rows + r, acc) };
                }
                b0 += bb;
            }
        }
    }

    /// Quantize a row-major `batch × cols` activation matrix online, then
    /// run the batched GEMM (full request path for a timestep batch).
    pub fn online_gemm(&self, x: &[f32], batch: usize, k_x: usize, y: &mut [f32]) {
        self.online_gemm_exec(x, batch, k_x, y, &Exec::serial());
    }

    /// [`Self::online_gemm`] on an execution engine: the per-row online
    /// quantization and the GEMM rows are both sharded across the workers.
    pub fn online_gemm_exec(&self, x: &[f32], batch: usize, k_x: usize, y: &mut [f32], exec: &Exec) {
        let xq = QuantizedBatch::quantize_exec(x, batch, self.cols, k_x, exec);
        self.gemm_exec(&xq, y, exec);
    }
}

/// Fused single-pass kernel for k ≤ 4 (see `quantized_gemv`).
fn fused_gemv(w: &RowQuantized, x: &Quantized, y: &mut [f32]) {
    let kw = w.k;
    let kx = x.k();
    let n = w.cols as i32;
    let nw = w.cols.div_ceil(64);
    let xw: [&[u64]; MAX_K] = {
        let mut a: [&[u64]; MAX_K] = [&[]; MAX_K];
        for (s, p) in x.planes.iter().enumerate() {
            a[s] = p.words();
        }
        a
    };
    for (r, yr) in y.iter_mut().enumerate() {
        let mut wp: [&[u64]; MAX_K] = [&[]; MAX_K];
        for t in 0..kw {
            wp[t] = w.planes[r * kw + t].words();
        }
        let mut counts = [[0u32; MAX_K]; MAX_K];
        for i in 0..nw {
            // One load of each plane word per index; k_w*k_x independent
            // XOR+POPCNT chains.
            for (t, wt) in wp.iter().enumerate().take(kw) {
                let ww = wt[i];
                for s in 0..kx {
                    counts[t][s] += (ww ^ xw[s][i]).count_ones();
                }
            }
        }
        let mut acc = 0.0f32;
        for (t, row) in counts.iter().enumerate().take(kw) {
            let mut inner = 0.0f32;
            for (s, &c) in row.iter().enumerate().take(kx) {
                inner += x.alphas[s] * (n - 2 * c as i32) as f32;
            }
            acc += w.alphas[r * kw + t] * inner;
        }
        *yr = acc;
    }
}

/// The innermost 1-bit dot product. Kept `#[inline]` and word-unrolled —
/// this is the hot loop of the entire serving path.
#[inline]
fn xor_popcount_dot(a: &PackedBits, b: &PackedBits, n: i32) -> i32 {
    let (wa, wb) = (a.words(), b.words());
    debug_assert_eq!(wa.len(), wb.len());
    let mut mism = 0u32;
    let mut i = 0;
    // 4-way unroll: popcount units pipeline across independent words.
    while i + 4 <= wa.len() {
        mism += (wa[i] ^ wb[i]).count_ones()
            + (wa[i + 1] ^ wb[i + 1]).count_ones()
            + (wa[i + 2] ^ wb[i + 2]).count_ones()
            + (wa[i + 3] ^ wb[i + 3]).count_ones();
        i += 4;
    }
    while i < wa.len() {
        mism += (wa[i] ^ wb[i]).count_ones();
        i += 1;
    }
    n - 2 * mism as i32
}

/// Full online path of Table 6: quantize `x` (the "Quant" share), then run
/// the binary GEMV. Returns `(y, quant_fraction_estimate_unused)`.
pub fn online_gemv(w: &RowQuantized, x: &[f32], k_x: usize, y: &mut [f32]) {
    let xq = quantize_activations(x, k_x);
    quantized_gemv(w, &xq, y);
}

/// Batched variant: `Y = Ŵ X̂` for `batch` activation vectors (columns of a
/// row-major `batch × n` matrix). The weight planes are streamed once per
/// batch — the concatenated layout of Fig. 3 (right).
pub fn quantized_gemv_batch(
    w: &RowQuantized,
    xs: &[Quantized],
    y: &mut [f32], // batch * rows, row-major per request
) {
    assert_eq!(y.len(), xs.len() * w.rows);
    for (b, xq) in xs.iter().enumerate() {
        quantized_gemv(w, xq, &mut y[b * w.rows..(b + 1) * w.rows]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense;
    use crate::quant::Method;
    use crate::util::prop;
    use crate::util::Rng;

    /// The core exactness property: the binary kernel must equal the dense
    /// GEMV computed on the *dequantized* operands (the popcount identity is
    /// exact; only float summation order differs).
    #[test]
    fn binary_gemv_equals_dense_on_dequantized_property() {
        prop::check(
            "binary-gemv-exact",
            prop::Config { cases: 60, ..Default::default() },
            |rng| {
                let m = 1 + rng.below(24);
                let n = 1 + rng.below(200);
                let kw = 1 + rng.below(3);
                let kx = 1 + rng.below(3);
                let w: Vec<f32> = (0..m * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let x: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                (m, n, kw, kx, w, x)
            },
            |_| vec![],
            |(m, n, kw, kx, w, x)| {
                let wq = RowQuantized::quantize(w, *m, *n, *kw, Method::Alternating { t: 2 });
                let xq = quantize_activations(x, *kx);
                let mut y = vec![0.0f32; *m];
                quantized_gemv(&wq, &xq, &mut y);

                let wd = wq.dequantize();
                let xd = xq.dequantize();
                let mut yd = vec![0.0f32; *m];
                dense::gemv(&wd, *m, *n, &xd, &mut yd);
                y.iter().zip(&yd).all(|(a, b)| (a - b).abs() < 1e-3 * (1.0 + b.abs()))
            },
        );
    }

    #[test]
    fn approximates_full_precision_gemv() {
        // End-to-end: quantized product should track the FP product within
        // the quantization error budget.
        let mut rng = Rng::new(101);
        let (m, n) = (128, 512);
        let w = rng.normal_vec(m * n, 0.1);
        let x = rng.normal_vec(n, 0.5);
        let wq = RowQuantized::quantize(&w, m, n, 3, Method::Alternating { t: 2 });
        let mut y = vec![0.0; m];
        online_gemv(&wq, &x, 3, &mut y);
        let mut y_fp = vec![0.0; m];
        dense::gemv(&w, m, n, &x, &mut y_fp);
        // Relative output error is bounded by the combined weight+activation
        // quantization error (~4–5% each at 3 bits, compounding in the product).
        let num: f64 = y.iter().zip(&y_fp).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = y_fp.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(num / den < 0.2, "output relative error {}", num / den);
    }

    #[test]
    fn prepared_matches_quantized_gemv() {
        let mut rng = Rng::new(103);
        for (m, n, kw, kx) in [(17, 100, 2, 2), (8, 64, 3, 2), (5, 300, 4, 4)] {
            let w = rng.normal_vec(m * n, 0.3);
            let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
            let prep = PreparedGemm::new(&wq);
            let xq = quantize_activations(&rng.normal_vec(n, 1.0), kx);
            let mut y1 = vec![0.0; m];
            let mut y2 = vec![0.0; m];
            quantized_gemv(&wq, &xq, &mut y1);
            prep.gemv(&xq, &mut y2);
            assert_eq!(y1, y2, "m={m} n={n} kw={kw} kx={kx}");
            // Dequantization also agrees.
            assert_eq!(prep.dequantize(), wq.dequantize());
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let mut rng = Rng::new(102);
        let (m, n, bsz) = (16, 96, 4);
        let w = rng.normal_vec(m * n, 0.2);
        let wq = RowQuantized::quantize(&w, m, n, 2, Method::Greedy);
        let xs: Vec<Quantized> = (0..bsz)
            .map(|_| quantize_activations(&rng.normal_vec(n, 1.0), 2))
            .collect();
        let mut y = vec![0.0; bsz * m];
        quantized_gemv_batch(&wq, &xs, &mut y);
        for (b, xq) in xs.iter().enumerate() {
            let mut yb = vec![0.0; m];
            quantized_gemv(&wq, xq, &mut yb);
            assert_eq!(&y[b * m..(b + 1) * m], &yb[..]);
        }
    }

    #[test]
    fn gemm_bitmatches_gemv_per_column() {
        // The batched kernel must be EXACT against the single-vector kernel
        // for every column — same counts, same reduction order.
        let mut rng = Rng::new(104);
        for (kw, kx) in [(1, 1), (1, 2), (2, 2), (2, 3), (3, 2), (3, 3), (4, 4)] {
            for batch in [1usize, 2, 3, 4, 5, 9] {
                let (m, n) = (13, 130);
                let w = rng.normal_vec(m * n, 0.3);
                let wq = RowQuantized::quantize(&w, m, n, kw, Method::Alternating { t: 2 });
                let prep = PreparedGemm::new(&wq);
                let x = rng.normal_vec(batch * n, 1.0);
                let xq = QuantizedBatch::quantize(&x, batch, n, kx);
                let mut y = vec![0.0f32; batch * m];
                prep.gemm(&xq, &mut y);
                for b in 0..batch {
                    let mut yb = vec![0.0f32; m];
                    prep.gemv(&xq.column(b), &mut yb);
                    assert_eq!(
                        &y[b * m..(b + 1) * m],
                        &yb[..],
                        "kw={kw} kx={kx} batch={batch} col={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn online_gemm_matches_online_gemv_per_column() {
        let mut rng = Rng::new(105);
        let (m, n, batch, k) = (11, 96, 6, 2);
        let w = rng.normal_vec(m * n, 0.2);
        let prep = PreparedGemm::new(&RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 }));
        let x = rng.normal_vec(batch * n, 1.0);
        let mut y = vec![0.0f32; batch * m];
        prep.online_gemm(&x, batch, k, &mut y);
        for b in 0..batch {
            let mut yb = vec![0.0f32; m];
            prep.online_gemv(&x[b * n..(b + 1) * n], k, &mut yb);
            assert_eq!(&y[b * m..(b + 1) * m], &yb[..], "col {b}");
        }
    }

    #[test]
    #[should_panic(expected = "output batch shape mismatch")]
    fn gemm_shape_mismatch_panics() {
        let w = RowQuantized::quantize(&[0.0; 12], 3, 4, 2, Method::Greedy);
        let prep = PreparedGemm::new(&w);
        let xq = QuantizedBatch::quantize(&[0.0; 8], 2, 4, 2);
        let mut y = vec![0.0; 3]; // needs 2*3
        prep.gemm(&xq, &mut y);
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn shape_mismatch_panics() {
        let w = RowQuantized::quantize(&[0.0; 12], 3, 4, 2, Method::Greedy);
        let x = quantize_activations(&[0.0; 5], 2);
        let mut y = vec![0.0; 3];
        quantized_gemv(&w, &x, &mut y);
    }
}
