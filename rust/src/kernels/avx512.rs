//! AVX-512 backend: the fourth kernel, implementing the single fused
//! batch-block primitive ([`block_counts`]) **two ways** behind runtime
//! detection:
//!
//! * **`vpopcntq` arm** (`avx512f + avx512bw + avx512vpopcntdq`, Ice
//!   Lake and later): the hardware 64-bit-lane popcount
//!   (`_mm512_popcnt_epi64`) feeds u64-lane accumulators directly, so the
//!   fused block kernel runs at **every** plane length — u64 lanes never
//!   saturate, so there is no Harley–Seal cutoff, and the `k`-masked load
//!   (`_mm512_maskz_loadu_epi64`) absorbs the word tail with zero scalar
//!   cleanup (XOR of the masked-in zeros counts zero mismatches).
//!
//! * **LUT arm** (`avx512f + avx512bw` only, Skylake-X era): a 512-bit
//!   widening of the AVX2 structure — `vpshufb` nibble-LUT byte popcount
//!   plus `vpsadbw` folds, fused `u8`-lane block kernel below
//!   [`HARLEY_SEAL_MIN_WORDS`], and a Harley–Seal carry-save pairwise
//!   pass (32 words per iteration, CSAs via one `vpternlogq` each) above
//!   it.
//!
//! Both arms size their fused chunks to [`FUSED_MAX_CHAINS`] = 16 chains
//! — the 32-zmm register file holds twice AVX2's accumulator budget, so
//! W2A2 runs a full 4-column GEMM block per chunk.
//!
//! Exactness: popcounts are exact integers whatever the instruction mix,
//! so both arms produce the identical mismatch counts as the scalar
//! kernel and the shared float reduction in `kernels::binary` makes the
//! f32 outputs bit-identical (pinned by `rust/tests/kernel_parity.rs`,
//! which drives each arm separately through
//! [`super::backend::testing::avx512_block_counts_arm`]).
//!
//! This module is normally reached through the [`super::backend`]
//! dispatch with an availability-resolved kernel; as a second line of
//! defense the safe wrapper re-checks the features at runtime (cached
//! atomic loads) and falls back to the scalar kernel — identical counts —
//! so a misused raw `Kernel::Avx512` can never execute EVEX instructions
//! on a CPU without them.

use core::arch::x86_64::*;

use super::backend::MAX_K;
use super::scalar;

/// Plane length (in words) from which the **LUT arm** switches from the
/// fused block kernel to Harley–Seal pairwise passes, shared with AVX2
/// via the cost model's constant so `exp::kernel_tables` predictions can
/// never drift from what the kernel does. The `vpopcntq` arm has no such
/// cutoff (u64-lane accumulators).
const HARLEY_SEAL_MIN_WORDS: usize = super::cost::FUSED_SHORT_PLANE_MAX_WORDS as usize;

/// Chain budget (columns × k_w × k_x) per fused-kernel chunk, derived
/// from [`super::cost::AVX512_FUSED_MAX_CHAINS`]: EVEX exposes 32 zmm
/// registers, so 16 chain accumulators still leave room for the held
/// weight vectors, the activation vector, and (on the LUT arm) the LUT
/// and nibble mask.
const FUSED_MAX_CHAINS: usize = super::cost::AVX512_FUSED_MAX_CHAINS as usize;

/// Accumulator slots the fused kernels allocate: a chunk is capped by the
/// chain budget *or* is a single column of up to `MAX_K²` chains,
/// whichever is larger.
const FUSED_ACC_SLOTS: usize = if FUSED_MAX_CHAINS > MAX_K * MAX_K {
    FUSED_MAX_CHAINS
} else {
    MAX_K * MAX_K
};

/// The LUT arm's fused kernel accumulates ≤ 8 per byte per 512-bit
/// vector in `u8` lanes and must not overflow before the per-chain fold:
/// the short-plane regime must stay under 31 vectors (31 · 8 = 248 < 256).
const _: () = assert!(HARLEY_SEAL_MIN_WORDS <= 31 * 8);

/// Runtime check for the common base of both arms (cached by std in
/// atomics — one load + branch each). `avx512bw` is required even by the
/// `vpopcntq` arm's dispatch contract so a single `--kernel avx512`
/// predicate covers both.
#[inline]
pub(crate) fn have_avx512() -> bool {
    is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
}

/// Runtime check for the native 64-bit-lane popcount extension.
#[inline]
pub(crate) fn have_vpopcntdq() -> bool {
    is_x86_feature_detected!("avx512vpopcntdq")
}

/// Fused batch-block counts (AVX-512) — the backend's one count
/// primitive; contract as in [`scalar::block_counts`]. Picks the
/// `vpopcntq` arm when the hardware has it, the LUT arm otherwise, and
/// scalar (identical counts) if AVX-512 is missing entirely.
#[inline]
pub(crate) fn block_counts(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    if !have_avx512() {
        return scalar::block_counts(w, x_block, counts);
    }
    if have_vpopcntdq() {
        // SAFETY: avx512f+avx512bw+avx512vpopcntdq all detected above.
        unsafe { block_counts_vpopcnt(w, x_block, counts) }
    } else {
        // SAFETY: avx512f+avx512bw detected above.
        unsafe { block_counts_lut(w, x_block, counts) }
    }
}

/// Run one specific arm regardless of what [`block_counts`] would pick:
/// `vpopcnt = true` forces the `vpopcntq` arm, `false` the LUT arm.
/// Returns `false` (leaving `counts` untouched) when this host cannot run
/// the requested arm — the parity suite skips-with-notice on that.
/// Exposed to tests through `backend::testing`.
pub(crate) fn block_counts_arm(
    vpopcnt: bool,
    w: &[&[u64]],
    x_block: &[&[&[u64]]],
    counts: &mut [u32],
) -> bool {
    if !have_avx512() || (vpopcnt && !have_vpopcntdq()) {
        return false;
    }
    if vpopcnt {
        // SAFETY: avx512f+avx512bw+avx512vpopcntdq all detected above.
        unsafe { block_counts_vpopcnt(w, x_block, counts) }
    } else {
        // SAFETY: avx512f+avx512bw detected above.
        unsafe { block_counts_lut(w, x_block, counts) }
    }
    true
}

// ---------------------------------------------------------------------------
// Shared 512-bit helpers. All `unsafe fn`s below require the listed
// target features at runtime; slices are read strictly in-bounds via
// unaligned (or k-masked) loads.
// ---------------------------------------------------------------------------

/// Load words `i..i+8` of both planes and XOR them.
///
/// # Safety
/// Requires AVX-512F; `i + 8` must not exceed the planes' length.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn xor_load_512(a: *const u64, b: *const u64, i: usize) -> __m512i {
    let va = _mm512_loadu_si512(a.add(i) as *const _);
    let vb = _mm512_loadu_si512(b.add(i) as *const _);
    _mm512_xor_si512(va, vb)
}

/// Load the `rem < 8` tail words (`i..i+rem`) of both planes with a
/// k-masked load (missing lanes read as zero) and XOR them. Zero lanes
/// XOR to zero and count zero mismatches, so the tail folds into the
/// vector accumulators with no scalar cleanup.
///
/// # Safety
/// Requires AVX-512F; `i + rem` must not exceed the planes' length and
/// `rem < 8`.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn xor_load_tail_512(a: *const u64, b: *const u64, i: usize, rem: usize) -> __m512i {
    let mask: __mmask8 = (1u8 << rem) - 1;
    let va = _mm512_maskz_loadu_epi64(mask, a.add(i) as *const i64);
    let vb = _mm512_maskz_loadu_epi64(mask, b.add(i) as *const i64);
    _mm512_xor_si512(va, vb)
}

/// Horizontal sum of the eight u64 lanes.
///
/// # Safety
/// Requires AVX-512F.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn hsum_512(v: __m512i) -> u64 {
    _mm512_reduce_add_epi64(v) as u64
}

// ---------------------------------------------------------------------------
// The vpopcntq arm.
// ---------------------------------------------------------------------------

/// One-pair XOR-popcount with the hardware lane popcount — the pairwise
/// fallback of the `vpopcntq` arm for bit widths beyond `MAX_K`.
///
/// # Safety
/// Requires AVX-512F+BW+VPOPCNTDQ; `a.len() == b.len()`.
#[target_feature(enable = "avx512f,avx512bw,avx512vpopcntdq")]
unsafe fn xor_popcount_vpopcnt(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm512_setzero_si512();
    let mut i = 0usize;
    while i + 8 <= n {
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(xor_load_512(pa, pb, i)));
        i += 8;
    }
    if i < n {
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(xor_load_tail_512(pa, pb, i, n - i)));
    }
    hsum_512(acc) as u32
}

/// The `vpopcntq` block primitive: fused at **every** plane length.
/// Each chain's accumulator holds u64 lane sums (cannot saturate), and
/// the masked tail load removes the scalar word tail, so long planes need
/// no separate Harley–Seal arm — `vpopcntq` already pays exactly one
/// popcount per vector. Widths beyond `MAX_K` (no serving shape uses
/// them) take a pairwise pass so the accumulator array stays fixed.
///
/// # Safety
/// Requires AVX-512F+BW+VPOPCNTDQ; contract as in
/// [`scalar::block_counts`].
#[target_feature(enable = "avx512f,avx512bw,avx512vpopcntdq")]
unsafe fn block_counts_vpopcnt(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block.first().map_or(0, |c| c.len());
    debug_assert_eq!(counts.len(), x_block.len() * kw * kx);
    if kw == 0 || kx == 0 {
        return;
    }
    if kw > MAX_K || kx > MAX_K {
        for (j, xj) in x_block.iter().enumerate() {
            for (t, wt) in w.iter().enumerate() {
                for (s, xs) in xj.iter().enumerate() {
                    counts[(j * kw + t) * kx + s] += xor_popcount_vpopcnt(wt, xs);
                }
            }
        }
        return;
    }
    let cols_per_chunk = (FUSED_MAX_CHAINS / (kw * kx)).max(1);
    let mut j0 = 0;
    while j0 < x_block.len() {
        let jb = cols_per_chunk.min(x_block.len() - j0);
        block_counts_vpopcnt_chunk(
            w,
            &x_block[j0..j0 + jb],
            &mut counts[j0 * kw * kx..(j0 + jb) * kw * kx],
        );
        j0 += jb;
    }
}

/// One fused chunk of the `vpopcntq` arm: every (column, w-plane,
/// x-plane) chain gets a dedicated u64-lane accumulator; one pass over
/// the word vectors loads each weight vector once per word index and each
/// activation vector once per column-plane, XORs, lane-popcounts, and
/// accumulates. The horizontal reduce is paid once per chain at the end.
///
/// # Safety
/// Requires AVX-512F+BW+VPOPCNTDQ; contract as in
/// [`scalar::block_counts`], with `x_block.len() · k_w · k_x ≤
/// FUSED_ACC_SLOTS` and widths ≤ `MAX_K`.
#[target_feature(enable = "avx512f,avx512bw,avx512vpopcntdq")]
unsafe fn block_counts_vpopcnt_chunk(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block[0].len();
    let wpp = w[0].len();
    debug_assert!(x_block.len() * kw * kx <= FUSED_ACC_SLOTS);
    let mut acc = [_mm512_setzero_si512(); FUSED_ACC_SLOTS];
    let mut i = 0usize;
    while i + 8 <= wpp {
        let mut wv = [_mm512_setzero_si512(); MAX_K];
        for (t, wt) in w.iter().enumerate() {
            wv[t] = _mm512_loadu_si512(wt.as_ptr().add(i) as *const _);
        }
        for (j, xj) in x_block.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let xv = _mm512_loadu_si512(xs.as_ptr().add(i) as *const _);
                for (t, &wt) in wv.iter().enumerate().take(kw) {
                    let c = (j * kw + t) * kx + s;
                    acc[c] = _mm512_add_epi64(
                        acc[c],
                        _mm512_popcnt_epi64(_mm512_xor_si512(wt, xv)),
                    );
                }
            }
        }
        i += 8;
    }
    if i < wpp {
        let rem = wpp - i;
        let mask: __mmask8 = (1u8 << rem) - 1;
        let mut wv = [_mm512_setzero_si512(); MAX_K];
        for (t, wt) in w.iter().enumerate() {
            wv[t] = _mm512_maskz_loadu_epi64(mask, wt.as_ptr().add(i) as *const i64);
        }
        for (j, xj) in x_block.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let xv = _mm512_maskz_loadu_epi64(mask, xs.as_ptr().add(i) as *const i64);
                for (t, &wt) in wv.iter().enumerate().take(kw) {
                    let c = (j * kw + t) * kx + s;
                    acc[c] = _mm512_add_epi64(
                        acc[c],
                        _mm512_popcnt_epi64(_mm512_xor_si512(wt, xv)),
                    );
                }
            }
        }
    }
    for c in 0..x_block.len() * kw * kx {
        counts[c] += hsum_512(acc[c]) as u32;
    }
}

// ---------------------------------------------------------------------------
// The LUT arm (avx512f + avx512bw, no vpopcntdq).
// ---------------------------------------------------------------------------

/// Byte-wise popcount of a 512-bit vector via the `vpshufb` nibble LUT
/// (the 16-byte table broadcast to all four 128-bit lanes).
///
/// # Safety
/// Requires AVX-512F+BW.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn popcount8_512(v: __m512i) -> __m512i {
    #[rustfmt::skip]
    let lut = _mm512_broadcast_i32x4(_mm_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    ));
    let mask = _mm512_set1_epi8(0x0f);
    let lo = _mm512_and_si512(v, mask);
    let hi = _mm512_and_si512(_mm512_srli_epi16::<4>(v), mask);
    _mm512_add_epi8(_mm512_shuffle_epi8(lut, lo), _mm512_shuffle_epi8(lut, hi))
}

/// Carry-save adder: compresses three bit streams into (carry, sum).
/// One `vpternlogq` per output — majority (imm 0xE8) for the carry,
/// three-way XOR (imm 0x96) for the sum — versus AVX2's five logic ops.
///
/// # Safety
/// Requires AVX-512F.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn csa_512(a: __m512i, b: __m512i, c: __m512i) -> (__m512i, __m512i) {
    let h = _mm512_ternarylogic_epi64::<0xE8>(a, b, c);
    let l = _mm512_ternarylogic_epi64::<0x96>(a, b, c);
    (h, l)
}

/// Popcount the bytes of `v` and add the per-64-bit-lane sums into `acc`.
///
/// # Safety
/// Requires AVX-512F+BW.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn accumulate_sad_512(acc: __m512i, v: __m512i) -> __m512i {
    _mm512_add_epi64(acc, _mm512_sad_epu8(popcount8_512(v), _mm512_setzero_si512()))
}

/// One-pair XOR-popcount of the LUT arm: Harley–Seal carry-save main loop
/// (32 words = 4 zmm per iteration) for long planes, LUT + `vpsadbw` loop
/// for whole 512-bit vectors, masked-load fold for the word tail. The
/// long-plane arm of the LUT block primitive, and its fallback for bit
/// widths beyond `MAX_K`.
///
/// # Safety
/// Requires AVX-512F+BW; `a.len() == b.len()`.
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn xor_popcount_lut(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0usize;
    let mut total_v = _mm512_setzero_si512();
    if n >= HARLEY_SEAL_MIN_WORDS {
        // Main loop: 32 words (4 zmm vectors) per iteration. Two CSA
        // levels fold the four XOR vectors plus the carried ones/twos
        // state so only the `fours` vector is byte-popcounted per
        // iteration (¼ of the popcount work).
        let mut ones = _mm512_setzero_si512();
        let mut twos = _mm512_setzero_si512();
        let mut fours_acc = _mm512_setzero_si512();
        while i + 32 <= n {
            let (twos_a, ones1) =
                csa_512(ones, xor_load_512(pa, pb, i), xor_load_512(pa, pb, i + 8));
            let (twos_b, ones2) =
                csa_512(ones1, xor_load_512(pa, pb, i + 16), xor_load_512(pa, pb, i + 24));
            let (fours, twos1) = csa_512(twos, twos_a, twos_b);
            ones = ones2;
            twos = twos1;
            fours_acc = accumulate_sad_512(fours_acc, fours);
            i += 32;
        }
        // Flush the carried state with its binary weights:
        // 4·fours + 2·twos + 1·ones, all still as u64×8 lane sums.
        let twos_acc = accumulate_sad_512(_mm512_setzero_si512(), twos);
        let ones_acc = accumulate_sad_512(_mm512_setzero_si512(), ones);
        total_v = _mm512_add_epi64(
            _mm512_slli_epi64::<2>(fours_acc),
            _mm512_add_epi64(_mm512_slli_epi64::<1>(twos_acc), ones_acc),
        );
    }
    // Whole vectors (the tail of the HS loop), weight 1.
    while i + 8 <= n {
        total_v = accumulate_sad_512(total_v, xor_load_512(pa, pb, i));
        i += 8;
    }
    // Masked word tail, still in vector form (zero lanes count zero).
    if i < n {
        total_v = accumulate_sad_512(total_v, xor_load_tail_512(pa, pb, i, n - i));
    }
    hsum_512(total_v) as u32
}

/// The LUT-arm block primitive: fused short-plane kernel (columns chunked
/// to the chain budget) or per-pair Harley–Seal passes for long planes,
/// mirroring the AVX2 structure at twice the width. Widths beyond `MAX_K`
/// take the pairwise arm unconditionally so the fused kernel's
/// accumulator array stays fixed.
///
/// # Safety
/// Requires AVX-512F+BW; contract as in [`scalar::block_counts`].
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn block_counts_lut(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block.first().map_or(0, |c| c.len());
    let wpp = w.first().map_or(0, |p| p.len());
    debug_assert_eq!(counts.len(), x_block.len() * kw * kx);
    if kw == 0 || kx == 0 {
        return;
    }
    if wpp >= HARLEY_SEAL_MIN_WORDS || kw > MAX_K || kx > MAX_K {
        for (j, xj) in x_block.iter().enumerate() {
            for (t, wt) in w.iter().enumerate() {
                for (s, xs) in xj.iter().enumerate() {
                    counts[(j * kw + t) * kx + s] += xor_popcount_lut(wt, xs);
                }
            }
        }
        return;
    }
    let cols_per_chunk = (FUSED_MAX_CHAINS / (kw * kx)).max(1);
    let mut j0 = 0;
    while j0 < x_block.len() {
        let jb = cols_per_chunk.min(x_block.len() - j0);
        block_counts_lut_short(
            w,
            &x_block[j0..j0 + jb],
            &mut counts[j0 * kw * kx..(j0 + jb) * kw * kx],
        );
        j0 += jb;
    }
}

/// The LUT arm's fused short-plane block kernel: every (column, w-plane,
/// x-plane) chain gets a dedicated `u8`-lane accumulator; one pass over
/// the word vectors loads each weight vector once per word index, XORs,
/// and byte-accumulates the nibble-LUT popcounts. The `vpsadbw` fold +
/// horizontal sum are paid once per chain at the end, never inside the
/// word loop.
///
/// # Safety
/// Requires AVX-512F+BW; contract as in [`scalar::block_counts`], with
/// `x_block.len() · k_w · k_x ≤ FUSED_ACC_SLOTS`, widths ≤ `MAX_K`, and
/// planes shorter than `HARLEY_SEAL_MIN_WORDS` (u8 lanes must not
/// saturate: ≤ 7 vectors · 8 = 56 < 256).
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn block_counts_lut_short(w: &[&[u64]], x_block: &[&[&[u64]]], counts: &mut [u32]) {
    let kw = w.len();
    let kx = x_block[0].len();
    let wpp = w[0].len();
    debug_assert!(x_block.len() * kw * kx <= FUSED_ACC_SLOTS);
    debug_assert!(wpp < HARLEY_SEAL_MIN_WORDS);
    let mut acc8 = [_mm512_setzero_si512(); FUSED_ACC_SLOTS];
    let mut i = 0usize;
    while i + 8 <= wpp {
        let mut wv = [_mm512_setzero_si512(); MAX_K];
        for (t, wt) in w.iter().enumerate() {
            wv[t] = _mm512_loadu_si512(wt.as_ptr().add(i) as *const _);
        }
        for (j, xj) in x_block.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let xv = _mm512_loadu_si512(xs.as_ptr().add(i) as *const _);
                for (t, &wt) in wv.iter().enumerate().take(kw) {
                    let c = (j * kw + t) * kx + s;
                    acc8[c] = _mm512_add_epi8(acc8[c], popcount8_512(_mm512_xor_si512(wt, xv)));
                }
            }
        }
        i += 8;
    }
    // Per-chain fold (the only vpsadbw + hsum of the whole block) plus
    // the scalar word tail.
    let tail = i;
    for (j, xj) in x_block.iter().enumerate() {
        for (t, wt) in w.iter().enumerate() {
            for (s, xs) in xj.iter().enumerate() {
                let c = (j * kw + t) * kx + s;
                let mut total = hsum_512(_mm512_sad_epu8(acc8[c], _mm512_setzero_si512()));
                for ii in tail..wpp {
                    total += u64::from((wt[ii] ^ xs[ii]).count_ones());
                }
                counts[c] += total as u32;
            }
        }
    }
}
