//! Batch-first activation containers — the currency of the inference API.
//!
//! An [`ActivationBatch`] is `B` row-major activation vectors moving through
//! the model together; an [`OutputBatch`] is the matching result buffer of a
//! batched linear layer. Quantized backends call
//! [`ActivationBatch::quantize`] **once per batch** to produce the shared
//! bit-plane layout ([`QuantizedBatch`]) that the XNOR/popcount GEMM streams
//! against each packed weight plane in a single sweep (Fig. 3 right).
//!
//! The legacy per-vector entry points (`Linear::matvec`, `LstmCell::step`,
//! …) remain as dedicated `B = 1` implementations that share their scalar
//! math and quantizers with the batched path; exact batch-vs-single parity
//! is pinned by tests at every layer (`rust/tests/batch_parity.rs`). The
//! [`ActivationBatch::single`] constructor adapts a lone vector when a
//! caller wants the batched API directly.

use crate::exec::Exec;
use crate::quant::{Method, QuantizedBatch};

/// `B` activation vectors of dimension `n`, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct ActivationBatch {
    batch: usize,
    n: usize,
    data: Vec<f32>, // batch * n
}

impl ActivationBatch {
    /// All-zero batch (recurrent state cold start).
    pub fn zeros(batch: usize, n: usize) -> Self {
        ActivationBatch { batch, n, data: vec![0.0; batch * n] }
    }

    /// Wrap an existing row-major `batch × n` buffer.
    pub fn from_flat(data: Vec<f32>, batch: usize, n: usize) -> Self {
        assert_eq!(data.len(), batch * n, "batch shape mismatch");
        ActivationBatch { batch, n, data }
    }

    /// Gather rows (e.g. per-session hidden states) into one batch.
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        assert!(!rows.is_empty(), "empty batch");
        let n = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * n);
        for r in rows {
            assert_eq!(r.len(), n, "row dimension mismatch");
            data.extend_from_slice(r);
        }
        ActivationBatch { batch: rows.len(), n, data }
    }

    /// A `B = 1` batch holding one vector (the legacy-path wrapper).
    pub fn single(x: &[f32]) -> Self {
        ActivationBatch { batch: 1, n: x.len(), data: x.to_vec() }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn row(&self, b: usize) -> &[f32] {
        &self.data[b * self.n..(b + 1) * self.n]
    }

    #[inline]
    pub fn row_mut(&mut self, b: usize) -> &mut [f32] {
        &mut self.data[b * self.n..(b + 1) * self.n]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Quantize the whole batch in one step (per-row alternating codes in
    /// shared contiguous planes — the "quantized once per batch" of the
    /// serving path).
    pub fn quantize(&self, k: usize) -> QuantizedBatch {
        QuantizedBatch::quantize(&self.data, self.batch, self.n, k)
    }

    /// [`Self::quantize`] on an execution engine: the per-row online
    /// quantization shards across workers, bit-identically.
    pub fn quantize_exec(&self, k: usize, exec: &Exec) -> QuantizedBatch {
        QuantizedBatch::quantize_exec(&self.data, self.batch, self.n, k, exec)
    }

    /// Quantize with an explicit method (ablations).
    pub fn quantize_with(&self, k: usize, method: Method) -> QuantizedBatch {
        QuantizedBatch::quantize_with(&self.data, self.batch, self.n, k, method)
    }

    /// Reshape in place to an all-zero `batch × n` buffer. Capacity is
    /// kept, so a steady-state caller that resets to sizes at or below the
    /// high-water mark allocates nothing — the workspace-reuse primitive of
    /// the `_into` forward APIs. The zero fill is deliberate (a small
    /// memset per step) so no reuse pattern can ever observe stale data,
    /// even after a shrink-then-grow cycle.
    pub fn reset(&mut self, batch: usize, n: usize) {
        self.batch = batch;
        self.n = n;
        self.data.clear();
        self.data.resize(batch * n, 0.0);
    }

    /// Append one row, growing the batch by one — the continuous batcher's
    /// slot-join primitive. O(n); allocation-free once the buffer has
    /// reached its high-water capacity. An empty batch adopts the row's
    /// dimension.
    pub fn push_row(&mut self, row: &[f32]) {
        if self.batch == 0 {
            self.n = row.len();
        }
        assert_eq!(row.len(), self.n, "row dimension mismatch");
        self.data.extend_from_slice(row);
        self.batch += 1;
    }

    /// Remove row `b` by moving the **last** row into its place and
    /// shrinking the batch by one — the continuous batcher's slot-free
    /// primitive. O(n), never shifts the rows in between, never
    /// reallocates.
    pub fn swap_remove_row(&mut self, b: usize) {
        assert!(b < self.batch, "row index out of range");
        let last = self.batch - 1;
        if b != last {
            let (head, tail) = self.data.split_at_mut(last * self.n);
            head[b * self.n..(b + 1) * self.n].copy_from_slice(&tail[..self.n]);
        }
        self.data.truncate(last * self.n);
        self.batch = last;
    }
}

impl Default for ActivationBatch {
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

/// Result buffer of a batched linear layer: `B` rows of `dim` outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct OutputBatch {
    batch: usize,
    dim: usize,
    data: Vec<f32>, // batch * dim
}

impl OutputBatch {
    pub fn zeros(batch: usize, dim: usize) -> Self {
        OutputBatch { batch, dim, data: vec![0.0; batch * dim] }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn row(&self, b: usize) -> &[f32] {
        &self.data[b * self.dim..(b + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, b: usize) -> &mut [f32] {
        &mut self.data[b * self.dim..(b + 1) * self.dim]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Raw mutable buffer (kernel output target).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Reinterpret as the next layer's input without copying.
    pub fn into_activations(self) -> ActivationBatch {
        ActivationBatch { batch: self.batch, n: self.dim, data: self.data }
    }

    /// Reshape in place to an all-zero `batch × dim` buffer (capacity kept;
    /// see [`ActivationBatch::reset`]).
    pub fn reset(&mut self, batch: usize, dim: usize) {
        self.batch = batch;
        self.dim = dim;
        self.data.clear();
        self.data.resize(batch * dim, 0.0);
    }
}

impl Default for OutputBatch {
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_flat_agree() {
        let a = ActivationBatch::from_flat(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(a.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(a.row(1), &[4.0, 5.0, 6.0]);
        let b = ActivationBatch::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a, b);
        assert_eq!(ActivationBatch::single(&[7.0, 8.0]).row(0), &[7.0, 8.0]);
    }

    #[test]
    fn batch_quantize_matches_single_rows() {
        let a = ActivationBatch::from_rows(&[&[0.5, -1.0, 0.25], &[1.5, 0.0, -0.75]]);
        let qb = a.quantize(2);
        for b in 0..2 {
            let single = ActivationBatch::single(a.row(b)).quantize(2);
            assert_eq!(qb.column(b).alphas, single.column(0).alphas);
            assert_eq!(qb.column(b).planes, single.column(0).planes);
        }
    }

    #[test]
    fn output_into_activations_is_zero_copy_shapewise() {
        let mut o = OutputBatch::zeros(2, 4);
        o.row_mut(1)[2] = 9.0;
        let a = o.into_activations();
        assert_eq!(a.batch(), 2);
        assert_eq!(a.dim(), 4);
        assert_eq!(a.row(1)[2], 9.0);
    }

    #[test]
    #[should_panic(expected = "row dimension mismatch")]
    fn ragged_rows_panic() {
        ActivationBatch::from_rows(&[&[1.0, 2.0], &[1.0]]);
    }

    #[test]
    fn push_and_swap_remove_rows() {
        let mut a = ActivationBatch::default();
        a.push_row(&[1.0, 2.0]);
        a.push_row(&[3.0, 4.0]);
        a.push_row(&[5.0, 6.0]);
        assert_eq!((a.batch(), a.dim()), (3, 2));
        // Removing the middle row moves the last row into its place.
        a.swap_remove_row(0);
        assert_eq!(a.batch(), 2);
        assert_eq!(a.row(0), &[5.0, 6.0]);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        // Removing the last row is a pure truncate.
        a.swap_remove_row(1);
        assert_eq!(a.batch(), 1);
        assert_eq!(a.row(0), &[5.0, 6.0]);
        a.swap_remove_row(0);
        assert_eq!(a.batch(), 0);
        // The emptied batch keeps its dimension and accepts new rows
        // without reallocating.
        a.push_row(&[7.0, 8.0]);
        assert_eq!(a.row(0), &[7.0, 8.0]);
    }
}
