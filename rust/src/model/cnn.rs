//! Convolutional substrate for the CIFAR-10 experiment (Table 9): conv2d by
//! im2col + GEMM, 2×2 max-pooling, and a scaled VGG-like network
//! `(2×C3)-MP2-(2×C3)-MP2-(2×C3)-MP2-(2×FC)-SVM` with STE quantized
//! training (2-bit weights / 1-bit activations in the paper's setting).
//!
//! Convolution weights are quantized **per filter** (a filter row of the
//! im2col matrix is the analogue of the paper's matrix row).

use super::mlp::{adam_update, ste_quantize_matrix, QuantSpec};
use crate::kernels::dense;
use crate::util::Rng;

/// Tensor layout: NCHW, row-major.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn numel(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// im2col for 3×3 same-padding convolution: output is
/// `(c_in·9) × (h·w)` per image.
pub fn im2col3x3(x: &[f32], s: Shape, out: &mut [f32]) {
    let (c, h, w) = (s.c, s.h, s.w);
    assert_eq!(x.len(), c * h * w);
    assert_eq!(out.len(), c * 9 * h * w);
    let hw = h * w;
    for ci in 0..c {
        for ky in 0..3usize {
            for kx in 0..3usize {
                let row = (ci * 9 + ky * 3 + kx) * hw;
                for y in 0..h {
                    let sy = y as isize + ky as isize - 1;
                    for xo in 0..w {
                        let sx = xo as isize + kx as isize - 1;
                        out[row + y * w + xo] =
                            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                x[ci * hw + sy as usize * w + sx as usize]
                            } else {
                                0.0
                            };
                    }
                }
            }
        }
    }
}

/// col2im: scatter-add the gradient of the im2col matrix back to the image.
pub fn col2im3x3(cols: &[f32], s: Shape, dx: &mut [f32]) {
    let (c, h, w) = (s.c, s.h, s.w);
    let hw = h * w;
    dx.fill(0.0);
    for ci in 0..c {
        for ky in 0..3usize {
            for kx in 0..3usize {
                let row = (ci * 9 + ky * 3 + kx) * hw;
                for y in 0..h {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for xo in 0..w {
                        let sx = xo as isize + kx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        dx[ci * hw + sy as usize * w + sx as usize] += cols[row + y * w + xo];
                    }
                }
            }
        }
    }
}

/// A 3×3 same-padding conv layer with Adam state.
pub struct Conv3x3 {
    pub w: Vec<f32>, // c_out × (c_in*9)
    pub b: Vec<f32>,
    pub c_in: usize,
    pub c_out: usize,
    mw: Vec<f32>,
    vw: Vec<f32>,
}

pub struct ConvTape {
    pub cols: Vec<f32>, // im2col of the input
    pub in_shape: Shape,
}

impl Conv3x3 {
    pub fn init(c_in: usize, c_out: usize, rng: &mut Rng) -> Self {
        let fan_in = (c_in * 9) as f32;
        Conv3x3 {
            w: rng.normal_vec(c_out * c_in * 9, (2.0 / fan_in).sqrt()),
            b: vec![0.0; c_out],
            c_in,
            c_out,
            mw: vec![0.0; c_out * c_in * 9],
            vw: vec![0.0; c_out * c_in * 9],
        }
    }

    pub fn effective_w(&self, spec: &QuantSpec) -> Vec<f32> {
        match spec.k_w {
            Some(k) => ste_quantize_matrix(&self.w, self.c_out, self.c_in * 9, k, spec.method),
            None => self.w.clone(),
        }
    }

    /// Forward one image; returns activations (c_out×h×w) and the tape.
    pub fn forward(&self, wq: &[f32], x: &[f32], s: Shape) -> (Vec<f32>, ConvTape) {
        assert_eq!(s.c, self.c_in);
        let hw = s.h * s.w;
        let mut cols = vec![0.0f32; self.c_in * 9 * hw];
        im2col3x3(x, s, &mut cols);
        let mut y = vec![0.0f32; self.c_out * hw];
        dense::gemm(wq, &cols, self.c_out, self.c_in * 9, hw, &mut y);
        for co in 0..self.c_out {
            for p in 0..hw {
                y[co * hw + p] += self.b[co];
            }
        }
        (y, ConvTape { cols, in_shape: s })
    }

    /// Backward one image; accumulates grads, returns dx.
    pub fn backward(
        &self,
        wq: &[f32],
        tape: &ConvTape,
        dy: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
    ) -> Vec<f32> {
        let s = tape.in_shape;
        let hw = s.h * s.w;
        let kdim = self.c_in * 9;
        // gw += dy · colsᵀ ; gb += row sums of dy.
        for co in 0..self.c_out {
            let dyr = &dy[co * hw..(co + 1) * hw];
            gb[co] += dyr.iter().sum::<f32>();
            let gwr = &mut gw[co * kdim..(co + 1) * kdim];
            for kd in 0..kdim {
                let colr = &tape.cols[kd * hw..(kd + 1) * hw];
                let mut sum = 0.0f32;
                for (a, b) in dyr.iter().zip(colr) {
                    sum += a * b;
                }
                gwr[kd] += sum;
            }
        }
        // dcols = wqᵀ · dy, then col2im.
        let mut dcols = vec![0.0f32; kdim * hw];
        for co in 0..self.c_out {
            let dyr = &dy[co * hw..(co + 1) * hw];
            let wr = &wq[co * kdim..(co + 1) * kdim];
            for kd in 0..kdim {
                let wv = wr[kd];
                if wv == 0.0 {
                    continue;
                }
                let dc = &mut dcols[kd * hw..(kd + 1) * hw];
                for (d, &dv) in dc.iter_mut().zip(dyr) {
                    *d += wv * dv;
                }
            }
        }
        let mut dx = vec![0.0f32; s.numel()];
        col2im3x3(&dcols, s, &mut dx);
        dx
    }

    pub fn adam_step(&mut self, gw: &[f32], gb: &[f32], lr: f32, t: usize) {
        adam_update(&mut self.w, &mut self.mw, &mut self.vw, gw, lr, t);
        for (b, g) in self.b.iter_mut().zip(gb) {
            *b -= lr * g;
        }
        for v in self.w.iter_mut() {
            *v = v.clamp(-1.0, 1.0);
        }
    }
}

/// 2×2 max pool (stride 2). Returns pooled tensor and argmax indices.
pub fn maxpool2(x: &[f32], s: Shape) -> (Vec<f32>, Vec<usize>, Shape) {
    assert!(s.h % 2 == 0 && s.w % 2 == 0, "pooling needs even dims");
    let os = Shape { c: s.c, h: s.h / 2, w: s.w / 2 };
    let mut y = vec![f32::NEG_INFINITY; os.numel()];
    let mut arg = vec![0usize; os.numel()];
    for c in 0..s.c {
        for oy in 0..os.h {
            for ox in 0..os.w {
                let oi = c * os.h * os.w + oy * os.w + ox;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let ii = c * s.h * s.w + (2 * oy + dy) * s.w + (2 * ox + dx);
                        if x[ii] > y[oi] {
                            y[oi] = x[ii];
                            arg[oi] = ii;
                        }
                    }
                }
            }
        }
    }
    (y, arg, os)
}

/// Backward of maxpool2: route dy to the argmax positions.
pub fn maxpool2_backward(dy: &[f32], arg: &[usize], in_numel: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_numel];
    for (d, &a) in dy.iter().zip(arg) {
        dx[a] += d;
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_identity_kernel() {
        // Conv with a kernel that is 1 at the center must reproduce x.
        let s = Shape { c: 1, h: 4, w: 4 };
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut rng = Rng::new(161);
        let mut conv = Conv3x3::init(1, 1, &mut rng);
        conv.w = vec![0.0; 9];
        conv.w[4] = 1.0; // center tap
        conv.b = vec![0.0];
        let (y, _) = conv.forward(&conv.w.clone(), &x, s);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_grad_check() {
        let s = Shape { c: 2, h: 4, w: 4 };
        let mut rng = Rng::new(162);
        let conv = Conv3x3::init(2, 3, &mut rng);
        let x = rng.normal_vec(s.numel(), 1.0);
        let wq = conv.w.clone();
        let loss = |w: &[f32]| -> f32 {
            let (y, _) = conv.forward(w, &x, s);
            y.iter().map(|v| v * v).sum::<f32>() / 2.0
        };
        let (y, tape) = conv.forward(&wq, &x, s);
        let mut gw = vec![0.0f32; conv.w.len()];
        let mut gb = vec![0.0f32; conv.b.len()];
        let dx = conv.backward(&wq, &tape, &y, &mut gw, &mut gb);
        for idx in [0usize, 10, conv.w.len() - 1] {
            let eps = 1e-3;
            let mut wp = wq.clone();
            wp[idx] += eps;
            let mut wm = wq.clone();
            wm[idx] -= eps;
            let fd = (loss(&wp) - loss(&wm)) / (2.0 * eps);
            assert!((fd - gw[idx]).abs() < 2e-2 * (1.0 + fd.abs()), "{fd} vs {}", gw[idx]);
        }
        // dx check via input perturbation.
        let lossx = |x: &[f32]| -> f32 {
            let (y, _) = conv.forward(&wq, x, s);
            y.iter().map(|v| v * v).sum::<f32>() / 2.0
        };
        for idx in [0usize, 17, s.numel() - 1] {
            let eps = 1e-3;
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (lossx(&xp) - lossx(&xm)) / (2.0 * eps);
            assert!((fd - dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()), "dx {fd} vs {}", dx[idx]);
        }
    }

    #[test]
    fn maxpool_forward_backward() {
        let s = Shape { c: 1, h: 4, w: 4 };
        let x: Vec<f32> = vec![
            1.0, 2.0, 0.0, 0.0, //
            3.0, 4.0, 0.0, 1.0, //
            0.0, 0.0, 5.0, 0.0, //
            0.0, 0.0, 0.0, 0.0,
        ];
        let (y, arg, os) = maxpool2(&x, s);
        assert_eq!(os, Shape { c: 1, h: 2, w: 2 });
        assert_eq!(y, vec![4.0, 1.0, 0.0, 5.0]);
        let dx = maxpool2_backward(&[1.0, 1.0, 1.0, 1.0], &arg, 16);
        assert_eq!(dx[5], 1.0); // position of "4.0"
        assert_eq!(dx[10], 1.0); // position of "5.0"
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }
}
