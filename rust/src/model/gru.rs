//! GRU cell (Cho et al. 2014) with swappable quantized gate products,
//! mirroring [`super::lstm`].
//!
//! Gate layout `[r, z, n]` stacked along rows: `W_x ∈ R^{3h×in}`,
//! `W_h ∈ R^{3h×h}`:
//!
//! ```text
//! r = σ(Wx_r x + Wh_r h + b_r)        z = σ(Wx_z x + Wh_z h + b_z)
//! ñ = tanh(Wx_n x + r ⊙ (Wh_n h) + b_n)
//! h' = (1 − z) ⊙ ñ + z ⊙ h
//! ```

use super::batch::{ActivationBatch, OutputBatch};
use super::linear::{Linear, LinearOp, LinearWorkspace, Precision};
use super::math::sigmoid;
use crate::exec::Exec;
use crate::quant::QuantizedBatch;
use crate::util::Rng;

/// Reusable scratch for one batched GRU step (see
/// [`super::lstm::LstmStepWorkspace`] — same contract: one instance per
/// serving loop, buffers grow once and are reused, a warmed steady-state
/// [`GruCell::step_batch_into_exec`] allocates nothing on the serial
/// engine).
#[derive(Default)]
pub struct GruStepWorkspace {
    gx: OutputBatch,
    gh: OutputBatch,
    wx_ws: LinearWorkspace,
    wh_ws: LinearWorkspace,
}

/// One GRU layer.
pub struct GruCell {
    pub wx: Linear, // 3h × in
    pub wh: Linear, // 3h × h
    pub bias: Vec<f32>, // 3h
    pub hidden: usize,
    pub input: usize,
}

impl GruCell {
    pub fn init(input: usize, hidden: usize, scale: f32, rng: &mut Rng, precision: Precision) -> Self {
        let wx: Vec<f32> = (0..3 * hidden * input).map(|_| rng.range_f32(-scale, scale)).collect();
        let wh: Vec<f32> = (0..3 * hidden * hidden).map(|_| rng.range_f32(-scale, scale)).collect();
        GruCell {
            wx: Linear::new(wx, 3 * hidden, input, precision),
            wh: Linear::new(wh, 3 * hidden, hidden, precision),
            bias: vec![0.0; 3 * hidden],
            hidden,
            input,
        }
    }

    pub fn from_dense(
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        input: usize,
        hidden: usize,
        precision: Precision,
    ) -> Self {
        Self::from_dense_exec(wx, wh, bias, input, hidden, precision, &Exec::serial())
    }

    /// [`Self::from_dense`] with the per-row weight quantization sharded
    /// across `exec`'s workers (bit-identical cell for any thread count).
    pub fn from_dense_exec(
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        input: usize,
        hidden: usize,
        precision: Precision,
        exec: &Exec,
    ) -> Self {
        assert_eq!(wx.len(), 3 * hidden * input);
        assert_eq!(wh.len(), 3 * hidden * hidden);
        assert_eq!(bias.len(), 3 * hidden);
        GruCell {
            wx: Linear::new_exec(wx, 3 * hidden, input, precision, exec),
            wh: Linear::new_exec(wh, 3 * hidden, hidden, precision, exec),
            bias,
            hidden,
            input,
        }
    }

    /// One step: returns the new hidden state.
    pub fn step(&self, x: &[f32], h: &[f32]) -> Vec<f32> {
        let h3 = 3 * self.hidden;
        let mut gx = vec![0.0f32; h3];
        let mut gh = vec![0.0f32; h3];
        self.wx.matvec(x, &mut gx);
        self.wh.matvec(h, &mut gh);
        self.combine(&gx, &gh, h)
    }

    /// One step with a pre-quantized input activation.
    pub fn step_prequant(&self, xq: &crate::quant::Quantized, h: &[f32]) -> Vec<f32> {
        let h3 = 3 * self.hidden;
        let mut gx = vec![0.0f32; h3];
        let mut gh = vec![0.0f32; h3];
        self.wx.matvec_prequant(xq, &mut gx);
        self.wh.matvec(h, &mut gh);
        self.combine(&gx, &gh, h)
    }

    /// One step for a batch of `B` sequences (the GRU's state batch is just
    /// the hidden-row [`ActivationBatch`]). Bit-matches `B` independent
    /// [`Self::step`] calls column by column.
    pub fn step_batch(&self, x: &ActivationBatch, h: &ActivationBatch) -> ActivationBatch {
        self.step_batch_exec(x, h, &Exec::serial())
    }

    /// [`Self::step_batch`] on an execution engine: the `W_x` and `W_h`
    /// gate products run as two independent pooled tasks, each row-sharding
    /// its GEMM across the same workers (nested scopes). Bit-exact vs
    /// [`Self::step_batch`] for any thread count. A thin wrapper over
    /// [`Self::step_batch_into_exec`] with fresh buffers (one code path).
    pub fn step_batch_exec(
        &self,
        x: &ActivationBatch,
        h: &ActivationBatch,
        exec: &Exec,
    ) -> ActivationBatch {
        let mut out = ActivationBatch::default();
        self.step_batch_into_exec(x, h, &mut out, exec, &mut GruStepWorkspace::default());
        out
    }

    /// [`Self::step_batch_exec`] into caller-owned buffers: the next hidden
    /// batch is written into `out` (resized in place — `out` must not alias
    /// `h`: keep two state buffers and swap them between steps) and every
    /// intermediate lives in `ws`, reused across steps. Bit-identical to
    /// [`Self::step_batch_exec`]; once warm, a steady-state call performs
    /// zero heap allocations on the serial engine.
    pub fn step_batch_into_exec(
        &self,
        x: &ActivationBatch,
        h: &ActivationBatch,
        out: &mut ActivationBatch,
        exec: &Exec,
        ws: &mut GruStepWorkspace,
    ) {
        assert_eq!(x.batch(), h.batch(), "batch mismatch");
        let GruStepWorkspace { gx, gh, wx_ws, wh_ws } = ws;
        exec.join(
            || self.wx.forward_into_exec(x, &mut *gx, exec, &mut *wx_ws),
            || self.wh.forward_into_exec(h, &mut *gh, exec, &mut *wh_ws),
        );
        self.combine_batch_into(gx, gh, h, out);
    }

    /// Batched step from pre-quantized inputs.
    pub fn step_batch_prequant(&self, xq: &QuantizedBatch, h: &ActivationBatch) -> ActivationBatch {
        self.step_batch_prequant_exec(xq, h, &Exec::serial())
    }

    /// [`Self::step_batch_prequant`] on an execution engine (see
    /// [`Self::step_batch_exec`]).
    pub fn step_batch_prequant_exec(
        &self,
        xq: &QuantizedBatch,
        h: &ActivationBatch,
        exec: &Exec,
    ) -> ActivationBatch {
        let mut out = ActivationBatch::default();
        let mut ws = GruStepWorkspace::default();
        self.step_batch_prequant_into_exec(xq, h, &mut out, exec, &mut ws);
        out
    }

    /// [`Self::step_batch_prequant_exec`] into caller-owned buffers (see
    /// [`Self::step_batch_into_exec`] for the double-buffer contract).
    pub fn step_batch_prequant_into_exec(
        &self,
        xq: &QuantizedBatch,
        h: &ActivationBatch,
        out: &mut ActivationBatch,
        exec: &Exec,
        ws: &mut GruStepWorkspace,
    ) {
        assert_eq!(xq.batch, h.batch(), "batch mismatch");
        let GruStepWorkspace { gx, gh, wx_ws, wh_ws } = ws;
        exec.join(
            || self.wx.forward_prequant_into_exec(xq, &mut *gx, exec, &mut *wx_ws),
            || self.wh.forward_into_exec(h, &mut *gh, exec, &mut *wh_ws),
        );
        self.combine_batch_into(gx, gh, h, out);
    }

    fn combine(&self, gx: &[f32], gh: &[f32], h: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.hidden];
        combine_row(self.hidden, &self.bias, gx, gh, h, &mut out);
        out
    }

    fn combine_batch_into(
        &self,
        gx: &OutputBatch,
        gh: &OutputBatch,
        h: &ActivationBatch,
        out: &mut ActivationBatch,
    ) {
        out.reset(h.batch(), self.hidden);
        for b in 0..h.batch() {
            combine_row(self.hidden, &self.bias, gx.row(b), gh.row(b), h.row(b), out.row_mut(b));
        }
    }

    pub fn bytes(&self) -> usize {
        self.wx.bytes() + self.wh.bytes() + self.bias.len() * 4
    }
}

/// The scalar gate math of one GRU step for one sequence — shared by the
/// single and batched paths so they are bit-identical by construction.
fn combine_row(hd: usize, bias: &[f32], gx: &[f32], gh: &[f32], h: &[f32], out: &mut [f32]) {
    for j in 0..hd {
        let r = sigmoid(gx[j] + gh[j] + bias[j]);
        let z = sigmoid(gx[hd + j] + gh[hd + j] + bias[hd + j]);
        let n = (gx[2 * hd + j] + r * gh[2 * hd + j] + bias[2 * hd + j]).tanh();
        out[j] = (1.0 - z) * n + z * h[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_bounded_and_shaped() {
        let mut rng = Rng::new(141);
        let cell = GruCell::init(8, 16, 0.4, &mut rng, Precision::Full);
        let x = rng.normal_vec(8, 1.0);
        let mut h = vec![0.0f32; 16];
        for _ in 0..10 {
            h = cell.step(&x, &h);
        }
        assert_eq!(h.len(), 16);
        // h is a convex combination of tanh values and previous h ⇒ |h| ≤ 1.
        assert!(h.iter().all(|&v| v.abs() <= 1.0));
    }

    #[test]
    fn identity_when_update_gate_saturated() {
        // Huge positive z-bias ⇒ z ≈ 1 ⇒ h' ≈ h.
        let mut rng = Rng::new(142);
        let mut cell = GruCell::init(4, 8, 0.2, &mut rng, Precision::Full);
        for j in 0..8 {
            cell.bias[8 + j] = 50.0;
        }
        let h: Vec<f32> = rng.normal_vec(8, 0.3);
        let x = rng.normal_vec(4, 1.0);
        let h2 = cell.step(&x, &h);
        for (a, b) in h.iter().zip(&h2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn step_batch_bitmatches_step_per_column() {
        let mut rng = Rng::new(144);
        for precision in [Precision::Full, Precision::Quantized { k_w: 2, k_a: 2 }] {
            let cell = GruCell::init(9, 14, 0.4, &mut rng, precision);
            for batch in 1..=4 {
                let hs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(14, 0.5)).collect();
                let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(9, 1.0)).collect();
                let hrows: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
                let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
                let next = cell.step_batch(
                    &ActivationBatch::from_rows(&xrows),
                    &ActivationBatch::from_rows(&hrows),
                );
                for b in 0..batch {
                    let expect = cell.step(&xs[b], &hs[b]);
                    assert_eq!(next.row(b), &expect[..], "{precision:?} batch={batch} col={b}");
                }
            }
        }
    }

    #[test]
    fn quantized_tracks_full_precision() {
        let mut rng = Rng::new(143);
        let (input, hidden) = (32, 64);
        let wx: Vec<f32> = (0..3 * hidden * input).map(|_| rng.range_f32(-0.2, 0.2)).collect();
        let wh: Vec<f32> = (0..3 * hidden * hidden).map(|_| rng.range_f32(-0.2, 0.2)).collect();
        let bias = vec![0.0; 3 * hidden];
        let fp = GruCell::from_dense(wx.clone(), wh.clone(), bias.clone(), input, hidden, Precision::Full);
        let q = GruCell::from_dense(wx, wh, bias, input, hidden, Precision::Quantized { k_w: 3, k_a: 3 });
        let x = rng.normal_vec(input, 1.0);
        let mut hf = vec![0.0f32; hidden];
        let mut hq = vec![0.0f32; hidden];
        for _ in 0..5 {
            hf = fp.step(&x, &hf);
            hq = q.step(&x, &hq);
        }
        let err: f32 = hf.iter().zip(&hq).map(|(a, b)| (a - b).abs()).sum::<f32>() / hidden as f32;
        assert!(err < 0.1, "mean |Δh| = {err}");
    }
}
