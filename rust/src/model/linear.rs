//! Batch-first linear layers: the [`LinearOp`] trait and its dense and
//! quantized backends.
//!
//! This is the swap point that turns a full-precision model into the
//! paper's quantized one. The primary entry point is the **batched**
//! [`LinearOp::forward`]: `B` activation vectors are quantized once into
//! shared bit-planes and multiplied in a single sweep over the packed
//! weight planes (`kernels::binary::PreparedGemm`, Fig. 3 right), whose
//! counts all flow through the one fused batch-block primitive of
//! `kernels::backend` on whatever SIMD backend the layer's kernel
//! resolves to. The single-vector `matvec` path remains as the `B = 1`
//! wrapper for the trainer and legacy callers.

use super::batch::{ActivationBatch, OutputBatch};
use crate::exec::{Exec, SendPtr};
use crate::kernels::binary::PreparedGemm;
use crate::kernels::{binary, dense, Kernel};
use crate::quant::{Method, QuantScratch, Quantized, QuantizedBatch, RowQuantized};

/// Precision/bit-width policy for one linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Full,
    /// Weights `k_w` bits, activations `k_a` bits (online).
    Quantized { k_w: usize, k_a: usize },
}

/// Reusable forward scratch for one linear layer: the quantized-activation
/// batch a quantized forward writes into (instead of allocating a fresh
/// [`QuantizedBatch`] per call) plus one quantizer scratch per worker task.
/// Hold one per layer per serving loop; buffers grow to the high-water mark
/// of the shapes they see and are then reused, so a warmed steady-state
/// [`LinearOp::forward_into_exec`] performs zero heap allocations on the
/// serial engine.
#[derive(Default)]
pub struct LinearWorkspace {
    xq: QuantizedBatch,
    scratches: Vec<QuantScratch>,
}

impl LinearWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A batched linear map `y_b = W x_b` for every column `b` of the batch.
///
/// Implementors must be **exact** across batch sizes *and* thread counts:
/// `forward_exec` on a `B`-column batch bit-matches `B` independent
/// single-column calls for any [`Exec`], so neither the server's dynamic
/// batching nor its worker pool ever changes what a session sees.
pub trait LinearOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;

    /// Batched forward on an execution engine: `y.row(b) = W · x.row(b)`.
    /// Quantized backends quantize `x` online once for the whole batch
    /// (sharded per row) and row-shard the GEMM across `exec`'s workers.
    fn forward_exec(&self, x: &ActivationBatch, y: &mut OutputBatch, exec: &Exec);

    /// Batched forward from pre-quantized activations (e.g. rows looked up
    /// from a quantized embedding table — zero online quantization cost).
    fn forward_prequant_exec(&self, x: &QuantizedBatch, y: &mut OutputBatch, exec: &Exec);

    /// Batched forward that reuses caller-owned buffers end to end: `y` is
    /// resized in place (capacity kept) and quantized backends quantize `x`
    /// into `ws` instead of allocating a fresh batch. Bit-identical to
    /// [`Self::forward_exec`] for any engine; a warmed steady-state call
    /// performs zero heap allocations on the serial engine
    /// (`rust/tests/workspace_parity.rs`).
    fn forward_into_exec(
        &self,
        x: &ActivationBatch,
        y: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LinearWorkspace,
    ) {
        let _ = ws;
        y.reset(x.batch(), self.rows());
        self.forward_exec(x, y, exec);
    }

    /// [`Self::forward_prequant_exec`] into a caller-owned (resized in
    /// place) output buffer.
    fn forward_prequant_into_exec(
        &self,
        x: &QuantizedBatch,
        y: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LinearWorkspace,
    ) {
        let _ = ws;
        y.reset(x.batch, self.rows());
        self.forward_prequant_exec(x, y, exec);
    }

    /// Serial batched forward (`B = threads = 1` semantics of old).
    fn forward(&self, x: &ActivationBatch, y: &mut OutputBatch) {
        self.forward_exec(x, y, &Exec::serial());
    }

    /// Serial batched forward from pre-quantized activations.
    fn forward_prequant(&self, x: &QuantizedBatch, y: &mut OutputBatch) {
        self.forward_prequant_exec(x, y, &Exec::serial());
    }
}

fn check_shapes(op: &impl LinearOp, x_batch: usize, x_dim: usize, y: &OutputBatch) {
    assert_eq!(x_dim, op.cols(), "inner dimension mismatch");
    assert_eq!(y.batch(), x_batch, "output batch mismatch");
    assert_eq!(y.dim(), op.rows(), "output dimension mismatch");
}

/// Full-precision backend: blocked f32 GEMV per batch column.
#[derive(Clone, Debug)]
pub struct DenseLinear {
    w: Vec<f32>, // rows × cols, row-major
    rows: usize,
    cols: usize,
}

impl DenseLinear {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(w.len(), rows * cols);
        DenseLinear { w, rows, cols }
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }
}

impl LinearOp for DenseLinear {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn forward_exec(&self, x: &ActivationBatch, y: &mut OutputBatch, exec: &Exec) {
        check_shapes(self, x.batch(), x.dim(), y);
        let rows = self.rows;
        let out = SendPtr::new(y.data_mut());
        let out = &out;
        // Columns are independent f32 GEMVs — shard the batch dimension.
        exec.run_chunks(x.batch(), 1, &|b0, b1| {
            for b in b0..b1 {
                // SAFETY: column b's output row is written only by this task.
                let yb = unsafe { out.slice_mut(b * rows, rows) };
                dense::gemv(&self.w, self.rows, self.cols, x.row(b), yb);
            }
        });
    }

    fn forward_prequant_exec(&self, x: &QuantizedBatch, y: &mut OutputBatch, exec: &Exec) {
        check_shapes(self, x.batch, x.n, y);
        let rows = self.rows;
        let out = SendPtr::new(y.data_mut());
        let out = &out;
        exec.run_chunks(x.batch, 1, &|b0, b1| {
            for b in b0..b1 {
                let xd = x.column(b).dequantize();
                // SAFETY: column b's output row is written only by this task.
                let yb = unsafe { out.slice_mut(b * rows, rows) };
                dense::gemv(&self.w, self.rows, self.cols, &xd, yb);
            }
        });
    }
}

/// Quantized backend: multi-bit weight planes + online multi-bit
/// activations through the batched XNOR/popcount GEMM.
#[derive(Clone, Debug)]
pub struct QuantLinear {
    w: PreparedGemm,
    /// Activation bit width for the online quantization step.
    k_a: usize,
}

impl QuantLinear {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, k_w: usize, k_a: usize, method: Method) -> Self {
        Self::new_exec(w, rows, cols, k_w, k_a, method, &Exec::serial())
    }

    /// Build with the per-row weight quantization sharded across `exec`'s
    /// workers (bit-identical layers for any thread count).
    pub fn new_exec(
        w: Vec<f32>,
        rows: usize,
        cols: usize,
        k_w: usize,
        k_a: usize,
        method: Method,
        exec: &Exec,
    ) -> Self {
        QuantLinear {
            w: PreparedGemm::new(&RowQuantized::quantize_exec(&w, rows, cols, k_w, method, exec)),
            k_a,
        }
    }

    /// Wrap an already-prepared matrix (the `.amqz` load path — the packed
    /// planes come straight off disk, no quantization runs).
    pub fn from_prepared(w: PreparedGemm, k_a: usize) -> Self {
        QuantLinear { w, k_a }
    }

    pub fn k_a(&self) -> usize {
        self.k_a
    }

    pub fn prepared(&self) -> &PreparedGemm {
        &self.w
    }

    /// The kernel backend this layer's GEMM dispatches to.
    pub fn kernel(&self) -> Kernel {
        self.w.kernel()
    }

    /// Override the kernel backend (resolved against availability).
    /// Outputs stay bit-identical — only wall time changes.
    pub fn set_kernel(&mut self, kernel: Kernel) {
        self.w.set_kernel(kernel);
    }
}

impl LinearOp for QuantLinear {
    fn rows(&self) -> usize {
        self.w.rows
    }

    fn cols(&self) -> usize {
        self.w.cols
    }

    fn forward_exec(&self, x: &ActivationBatch, y: &mut OutputBatch, exec: &Exec) {
        check_shapes(self, x.batch(), x.dim(), y);
        let xq = x.quantize_exec(self.k_a, exec);
        self.w.gemm_exec(&xq, y.data_mut(), exec);
    }

    fn forward_prequant_exec(&self, x: &QuantizedBatch, y: &mut OutputBatch, exec: &Exec) {
        check_shapes(self, x.batch, x.n, y);
        self.w.gemm_exec(x, y.data_mut(), exec);
    }

    /// The zero-allocation forward: activations quantize into the
    /// workspace's reused `QuantizedBatch` (one scratch per worker task)
    /// and the GEMM writes into the caller's resized output. Same
    /// quantization method, counts, and reduction order as
    /// [`LinearOp::forward_exec`] — bit-identical output.
    fn forward_into_exec(
        &self,
        x: &ActivationBatch,
        y: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LinearWorkspace,
    ) {
        let LinearWorkspace { xq, scratches } = ws;
        let tasks = exec.threads().min(x.batch()).max(1);
        if scratches.len() < tasks {
            scratches.resize_with(tasks, QuantScratch::default);
        }
        let method = Method::Alternating { t: 2 };
        xq.quantize_into_exec(x.data(), x.batch(), x.dim(), self.k_a, method, exec, scratches);
        self.w.gemm_into_exec(xq, y, exec);
    }
}

/// A (possibly quantized) linear layer `y = W x (+ b)` — the policy-driven
/// wrapper the model layer composes.
#[derive(Clone, Debug)]
pub enum Linear {
    Dense(DenseLinear),
    Quant(QuantLinear),
}

impl Linear {
    /// Build from a dense row-major matrix under the given policy.
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, precision: Precision) -> Self {
        Self::new_exec(w, rows, cols, precision, &Exec::serial())
    }

    /// [`Self::new`] with the per-row weight quantization sharded across
    /// `exec`'s workers (bit-identical layer for any thread count).
    pub fn new_exec(
        w: Vec<f32>,
        rows: usize,
        cols: usize,
        precision: Precision,
        exec: &Exec,
    ) -> Self {
        match precision {
            Precision::Full => Linear::Dense(DenseLinear::new(w, rows, cols)),
            Precision::Quantized { k_w, k_a } => Linear::Quant(QuantLinear::new_exec(
                w,
                rows,
                cols,
                k_w,
                k_a,
                Method::Alternating { t: 2 },
                exec,
            )),
        }
    }

    /// Build a quantized layer with an explicit method (ablations).
    pub fn new_with_method(
        w: Vec<f32>,
        rows: usize,
        cols: usize,
        k_w: usize,
        k_a: usize,
        method: Method,
    ) -> Self {
        Linear::Quant(QuantLinear::new(w, rows, cols, k_w, k_a, method))
    }

    fn op(&self) -> &dyn LinearOp {
        match self {
            Linear::Dense(d) => d,
            Linear::Quant(q) => q,
        }
    }

    pub fn rows(&self) -> usize {
        self.op().rows()
    }

    pub fn cols(&self) -> usize {
        self.op().cols()
    }

    /// Activation bit width the layer quantizes its inputs at online
    /// (`None` for dense layers).
    pub fn a_bits(&self) -> Option<usize> {
        match self {
            Linear::Dense(_) => None,
            Linear::Quant(q) => Some(q.k_a),
        }
    }

    /// `y = W x` for one vector (B = 1 wrapper; the trainer's path). For
    /// quantized layers this quantizes `x` online first.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Linear::Dense(d) => dense::gemv(&d.w, d.rows, d.cols, x, y),
            Linear::Quant(q) => q.w.online_gemv(x, q.k_a, y),
        }
    }

    /// `y = W x̂` with a pre-quantized activation (B = 1 wrapper).
    pub fn matvec_prequant(&self, xq: &Quantized, y: &mut [f32]) {
        match self {
            Linear::Dense(d) => {
                let xd = xq.dequantize();
                dense::gemv(&d.w, d.rows, d.cols, &xd, y)
            }
            Linear::Quant(q) => q.w.gemv(xq, y),
        }
    }

    /// Quantize an activation with this layer's activation policy (identity
    /// wrapper returning `None` for dense layers).
    pub fn quantize_input(&self, x: &[f32]) -> Option<Quantized> {
        match self {
            Linear::Dense(_) => None,
            Linear::Quant(q) => Some(binary::quantize_activations(x, q.k_a)),
        }
    }

    /// The kernel backend of the quantized GEMM (`None` for dense layers).
    pub fn kernel(&self) -> Option<Kernel> {
        match self {
            Linear::Dense(_) => None,
            Linear::Quant(q) => Some(q.kernel()),
        }
    }

    /// Bytes of weight storage.
    pub fn bytes(&self) -> usize {
        match self {
            Linear::Dense(d) => d.w.len() * 4,
            Linear::Quant(q) => q.w.bytes(),
        }
    }

    /// A dense snapshot (dequantized for quantized layers).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Linear::Dense(d) => d.w.clone(),
            Linear::Quant(q) => q.w.dequantize(),
        }
    }
}

impl LinearOp for Linear {
    fn rows(&self) -> usize {
        self.op().rows()
    }

    fn cols(&self) -> usize {
        self.op().cols()
    }

    fn forward_exec(&self, x: &ActivationBatch, y: &mut OutputBatch, exec: &Exec) {
        self.op().forward_exec(x, y, exec)
    }

    fn forward_prequant_exec(&self, x: &QuantizedBatch, y: &mut OutputBatch, exec: &Exec) {
        self.op().forward_prequant_exec(x, y, exec)
    }

    fn forward_into_exec(
        &self,
        x: &ActivationBatch,
        y: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LinearWorkspace,
    ) {
        self.op().forward_into_exec(x, y, exec, ws)
    }

    fn forward_prequant_into_exec(
        &self,
        x: &QuantizedBatch,
        y: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LinearWorkspace,
    ) {
        self.op().forward_prequant_into_exec(x, y, exec, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_and_quant_agree_within_budget() {
        let mut rng = Rng::new(111);
        let (m, n) = (64, 128);
        let wv = rng.normal_vec(m * n, 0.2);
        let x = rng.normal_vec(n, 1.0);
        let d = Linear::new(wv.clone(), m, n, Precision::Full);
        let q = Linear::new(wv, m, n, Precision::Quantized { k_w: 3, k_a: 3 });
        let mut yd = vec![0.0; m];
        let mut yq = vec![0.0; m];
        d.matvec(&x, &mut yd);
        q.matvec(&x, &mut yq);
        let num: f64 = yd.iter().zip(&yq).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = yd.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(num / den < 0.2, "{}", num / den);
    }

    #[test]
    fn prequant_matches_online() {
        let mut rng = Rng::new(112);
        let (m, n) = (16, 64);
        let q = Linear::new(
            rng.normal_vec(m * n, 0.3),
            m,
            n,
            Precision::Quantized { k_w: 2, k_a: 2 },
        );
        let x = rng.normal_vec(n, 1.0);
        let xq = q.quantize_input(&x).unwrap();
        let mut y1 = vec![0.0; m];
        let mut y2 = vec![0.0; m];
        q.matvec(&x, &mut y1);
        q.matvec_prequant(&xq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn forward_bitmatches_matvec_per_column() {
        // The contract of LinearOp: batching never changes values.
        let mut rng = Rng::new(113);
        let (m, n) = (24, 80);
        let wv = rng.normal_vec(m * n, 0.3);
        for layer in [
            Linear::new(wv.clone(), m, n, Precision::Full),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 3, k_a: 2 }),
        ] {
            for batch in 1..=4 {
                let x = rng.normal_vec(batch * n, 1.0);
                let xb = ActivationBatch::from_flat(x.clone(), batch, n);
                let mut y = OutputBatch::zeros(batch, m);
                layer.forward(&xb, &mut y);
                for b in 0..batch {
                    let mut yb = vec![0.0; m];
                    layer.matvec(&x[b * n..(b + 1) * n], &mut yb);
                    assert_eq!(y.row(b), &yb[..], "batch={batch} col={b}");
                }
            }
        }
    }

    #[test]
    fn forward_prequant_bitmatches_matvec_prequant() {
        let mut rng = Rng::new(114);
        let (m, n, batch) = (12, 48, 3);
        for layer in [
            Linear::new(rng.normal_vec(m * n, 0.3), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
            Linear::new(rng.normal_vec(m * n, 0.3), m, n, Precision::Full),
        ] {
            let x = rng.normal_vec(batch * n, 1.0);
            let xq = QuantizedBatch::quantize(&x, batch, n, 2);
            let mut y = OutputBatch::zeros(batch, m);
            layer.forward_prequant(&xq, &mut y);
            for b in 0..batch {
                let mut yb = vec![0.0; m];
                layer.matvec_prequant(&xq.column(b), &mut yb);
                assert_eq!(y.row(b), &yb[..], "col {b}");
            }
        }
    }

    #[test]
    fn forward_exec_bitmatches_serial_forward() {
        use crate::exec::ExecConfig;
        let mut rng = Rng::new(115);
        let (m, n, batch) = (23, 70, 5);
        let wv = rng.normal_vec(m * n, 0.3);
        for layer in [
            Linear::new(wv.clone(), m, n, Precision::Full),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
        ] {
            let x = rng.normal_vec(batch * n, 1.0);
            let xb = ActivationBatch::from_flat(x, batch, n);
            let mut y_serial = OutputBatch::zeros(batch, m);
            layer.forward(&xb, &mut y_serial);
            for threads in [2usize, 3, 8] {
                let exec = Exec::new(ExecConfig::with_threads(threads));
                let mut y = OutputBatch::zeros(batch, m);
                layer.forward_exec(&xb, &mut y, &exec);
                assert_eq!(y.data(), y_serial.data(), "threads={threads}");
            }
        }
    }

    #[test]
    fn quant_layer_bitmatches_across_kernel_backends() {
        // The LinearOp contract extends across kernel backends: a forward
        // on any available SIMD backend is EXACT against scalar.
        let mut rng = Rng::new(116);
        let (m, n, batch) = (18, 1100, 5); // n past the SIMD main loops
        let wv = rng.normal_vec(m * n, 0.3);
        let x = rng.normal_vec(batch * n, 1.0);
        let xb = ActivationBatch::from_flat(x, batch, n);
        let mut scalar_layer = match Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }) {
            Linear::Quant(q) => q,
            Linear::Dense(_) => unreachable!(),
        };
        scalar_layer.set_kernel(Kernel::Scalar);
        assert_eq!(scalar_layer.kernel(), Kernel::Scalar);
        let mut y_ref = OutputBatch::zeros(batch, m);
        scalar_layer.forward(&xb, &mut y_ref);
        for kernel in Kernel::available() {
            let mut layer = scalar_layer.clone();
            layer.set_kernel(kernel);
            let mut y = OutputBatch::zeros(batch, m);
            layer.forward(&xb, &mut y);
            assert_eq!(y.data(), y_ref.data(), "kernel={kernel}");
        }
        // Dense layers report no kernel.
        assert_eq!(Linear::new(wv, m, n, Precision::Full).kernel(), None);
    }

    #[test]
    fn forward_into_bitmatches_forward_with_reused_workspace() {
        use crate::exec::ExecConfig;
        let mut rng = Rng::new(117);
        let (m, n) = (21, 75);
        let wv = rng.normal_vec(m * n, 0.3);
        for layer in [
            Linear::new(wv.clone(), m, n, Precision::Full),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
        ] {
            // One workspace + output reused across batches and engines.
            let mut ws = LinearWorkspace::new();
            let mut y_into = OutputBatch::zeros(0, 0);
            for threads in [1usize, 4] {
                let exec = Exec::new(ExecConfig::with_threads(threads));
                for batch in [3usize, 1, 5] {
                    let x = rng.normal_vec(batch * n, 1.0);
                    let xb = ActivationBatch::from_flat(x, batch, n);
                    let mut y = OutputBatch::zeros(batch, m);
                    layer.forward_exec(&xb, &mut y, &exec);
                    layer.forward_into_exec(&xb, &mut y_into, &exec, &mut ws);
                    assert_eq!(y_into.data(), y.data(), "batch={batch} threads={threads}");
                    // Prequant variant through the same reused output.
                    let xq = xb.quantize(2);
                    let mut p = OutputBatch::zeros(batch, m);
                    layer.forward_prequant_exec(&xq, &mut p, &exec);
                    layer.forward_prequant_into_exec(&xq, &mut y_into, &exec, &mut ws);
                    assert_eq!(y_into.data(), p.data(), "prequant batch={batch}");
                }
            }
        }
    }

    #[test]
    fn quantized_layer_is_smaller() {
        let w = vec![0.1f32; 256 * 512];
        let d = Linear::new(w.clone(), 256, 512, Precision::Full);
        let q = Linear::new(w, 256, 512, Precision::Quantized { k_w: 2, k_a: 2 });
        assert!(q.bytes() * 10 < d.bytes(), "{} vs {}", q.bytes(), d.bytes());
    }
}
