//! Batch-first linear layers: the [`LinearOp`] trait and its dense and
//! quantized backends.
//!
//! This is the swap point that turns a full-precision model into the
//! paper's quantized one. The primary entry point is the **batched**
//! [`LinearOp::forward`]: `B` activation vectors are quantized once into
//! shared bit-planes and multiplied in a single sweep over the packed
//! weight planes (`kernels::binary::PreparedGemm`, Fig. 3 right). The
//! single-vector `matvec` path remains as the `B = 1` wrapper for the
//! trainer and legacy callers.

use super::batch::{ActivationBatch, OutputBatch};
use crate::kernels::binary::PreparedGemm;
use crate::kernels::{binary, dense};
use crate::quant::{Method, Quantized, QuantizedBatch, RowQuantized};

/// Precision/bit-width policy for one linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Full,
    /// Weights `k_w` bits, activations `k_a` bits (online).
    Quantized { k_w: usize, k_a: usize },
}

/// A batched linear map `y_b = W x_b` for every column `b` of the batch.
///
/// Implementors must be **exact** across batch sizes: `forward` on a
/// `B`-column batch bit-matches `B` independent single-column calls, so the
/// server's dynamic batching never changes what a session sees.
pub trait LinearOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;

    /// Batched forward: `y.row(b) = W · x.row(b)`. Quantized backends
    /// quantize `x` online, once for the whole batch.
    fn forward(&self, x: &ActivationBatch, y: &mut OutputBatch);

    /// Batched forward from pre-quantized activations (e.g. rows looked up
    /// from a quantized embedding table — zero online quantization cost).
    fn forward_prequant(&self, x: &QuantizedBatch, y: &mut OutputBatch);
}

fn check_shapes(op: &impl LinearOp, x_batch: usize, x_dim: usize, y: &OutputBatch) {
    assert_eq!(x_dim, op.cols(), "inner dimension mismatch");
    assert_eq!(y.batch(), x_batch, "output batch mismatch");
    assert_eq!(y.dim(), op.rows(), "output dimension mismatch");
}

/// Full-precision backend: blocked f32 GEMV per batch column.
#[derive(Clone, Debug)]
pub struct DenseLinear {
    w: Vec<f32>, // rows × cols, row-major
    rows: usize,
    cols: usize,
}

impl DenseLinear {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(w.len(), rows * cols);
        DenseLinear { w, rows, cols }
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }
}

impl LinearOp for DenseLinear {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn forward(&self, x: &ActivationBatch, y: &mut OutputBatch) {
        check_shapes(self, x.batch(), x.dim(), y);
        for b in 0..x.batch() {
            dense::gemv(&self.w, self.rows, self.cols, x.row(b), y.row_mut(b));
        }
    }

    fn forward_prequant(&self, x: &QuantizedBatch, y: &mut OutputBatch) {
        check_shapes(self, x.batch, x.n, y);
        for b in 0..x.batch {
            let xd = x.column(b).dequantize();
            dense::gemv(&self.w, self.rows, self.cols, &xd, y.row_mut(b));
        }
    }
}

/// Quantized backend: multi-bit weight planes + online multi-bit
/// activations through the batched XNOR/popcount GEMM.
#[derive(Clone, Debug)]
pub struct QuantLinear {
    w: PreparedGemm,
    /// Activation bit width for the online quantization step.
    k_a: usize,
}

impl QuantLinear {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, k_w: usize, k_a: usize, method: Method) -> Self {
        QuantLinear { w: PreparedGemm::new(&RowQuantized::quantize(&w, rows, cols, k_w, method)), k_a }
    }

    pub fn k_a(&self) -> usize {
        self.k_a
    }

    pub fn prepared(&self) -> &PreparedGemm {
        &self.w
    }
}

impl LinearOp for QuantLinear {
    fn rows(&self) -> usize {
        self.w.rows
    }

    fn cols(&self) -> usize {
        self.w.cols
    }

    fn forward(&self, x: &ActivationBatch, y: &mut OutputBatch) {
        check_shapes(self, x.batch(), x.dim(), y);
        let xq = x.quantize(self.k_a);
        self.w.gemm(&xq, y.data_mut());
    }

    fn forward_prequant(&self, x: &QuantizedBatch, y: &mut OutputBatch) {
        check_shapes(self, x.batch, x.n, y);
        self.w.gemm(x, y.data_mut());
    }
}

/// A (possibly quantized) linear layer `y = W x (+ b)` — the policy-driven
/// wrapper the model layer composes.
#[derive(Clone, Debug)]
pub enum Linear {
    Dense(DenseLinear),
    Quant(QuantLinear),
}

impl Linear {
    /// Build from a dense row-major matrix under the given policy.
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, precision: Precision) -> Self {
        match precision {
            Precision::Full => Linear::Dense(DenseLinear::new(w, rows, cols)),
            Precision::Quantized { k_w, k_a } => {
                Linear::Quant(QuantLinear::new(w, rows, cols, k_w, k_a, Method::Alternating { t: 2 }))
            }
        }
    }

    /// Build a quantized layer with an explicit method (ablations).
    pub fn new_with_method(
        w: Vec<f32>,
        rows: usize,
        cols: usize,
        k_w: usize,
        k_a: usize,
        method: Method,
    ) -> Self {
        Linear::Quant(QuantLinear::new(w, rows, cols, k_w, k_a, method))
    }

    fn op(&self) -> &dyn LinearOp {
        match self {
            Linear::Dense(d) => d,
            Linear::Quant(q) => q,
        }
    }

    pub fn rows(&self) -> usize {
        self.op().rows()
    }

    pub fn cols(&self) -> usize {
        self.op().cols()
    }

    /// `y = W x` for one vector (B = 1 wrapper; the trainer's path). For
    /// quantized layers this quantizes `x` online first.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Linear::Dense(d) => dense::gemv(&d.w, d.rows, d.cols, x, y),
            Linear::Quant(q) => q.w.online_gemv(x, q.k_a, y),
        }
    }

    /// `y = W x̂` with a pre-quantized activation (B = 1 wrapper).
    pub fn matvec_prequant(&self, xq: &Quantized, y: &mut [f32]) {
        match self {
            Linear::Dense(d) => {
                let xd = xq.dequantize();
                dense::gemv(&d.w, d.rows, d.cols, &xd, y)
            }
            Linear::Quant(q) => q.w.gemv(xq, y),
        }
    }

    /// Quantize an activation with this layer's activation policy (identity
    /// wrapper returning `None` for dense layers).
    pub fn quantize_input(&self, x: &[f32]) -> Option<Quantized> {
        match self {
            Linear::Dense(_) => None,
            Linear::Quant(q) => Some(binary::quantize_activations(x, q.k_a)),
        }
    }

    /// Bytes of weight storage.
    pub fn bytes(&self) -> usize {
        match self {
            Linear::Dense(d) => d.w.len() * 4,
            Linear::Quant(q) => q.w.bytes(),
        }
    }

    /// A dense snapshot (dequantized for quantized layers).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Linear::Dense(d) => d.w.clone(),
            Linear::Quant(q) => q.w.dequantize(),
        }
    }
}

impl LinearOp for Linear {
    fn rows(&self) -> usize {
        self.op().rows()
    }

    fn cols(&self) -> usize {
        self.op().cols()
    }

    fn forward(&self, x: &ActivationBatch, y: &mut OutputBatch) {
        self.op().forward(x, y)
    }

    fn forward_prequant(&self, x: &QuantizedBatch, y: &mut OutputBatch) {
        self.op().forward_prequant(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_and_quant_agree_within_budget() {
        let mut rng = Rng::new(111);
        let (m, n) = (64, 128);
        let wv = rng.normal_vec(m * n, 0.2);
        let x = rng.normal_vec(n, 1.0);
        let d = Linear::new(wv.clone(), m, n, Precision::Full);
        let q = Linear::new(wv, m, n, Precision::Quantized { k_w: 3, k_a: 3 });
        let mut yd = vec![0.0; m];
        let mut yq = vec![0.0; m];
        d.matvec(&x, &mut yd);
        q.matvec(&x, &mut yq);
        let num: f64 = yd.iter().zip(&yq).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = yd.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(num / den < 0.2, "{}", num / den);
    }

    #[test]
    fn prequant_matches_online() {
        let mut rng = Rng::new(112);
        let (m, n) = (16, 64);
        let q = Linear::new(
            rng.normal_vec(m * n, 0.3),
            m,
            n,
            Precision::Quantized { k_w: 2, k_a: 2 },
        );
        let x = rng.normal_vec(n, 1.0);
        let xq = q.quantize_input(&x).unwrap();
        let mut y1 = vec![0.0; m];
        let mut y2 = vec![0.0; m];
        q.matvec(&x, &mut y1);
        q.matvec_prequant(&xq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn forward_bitmatches_matvec_per_column() {
        // The contract of LinearOp: batching never changes values.
        let mut rng = Rng::new(113);
        let (m, n) = (24, 80);
        let wv = rng.normal_vec(m * n, 0.3);
        for layer in [
            Linear::new(wv.clone(), m, n, Precision::Full),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
            Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 3, k_a: 2 }),
        ] {
            for batch in 1..=4 {
                let x = rng.normal_vec(batch * n, 1.0);
                let xb = ActivationBatch::from_flat(x.clone(), batch, n);
                let mut y = OutputBatch::zeros(batch, m);
                layer.forward(&xb, &mut y);
                for b in 0..batch {
                    let mut yb = vec![0.0; m];
                    layer.matvec(&x[b * n..(b + 1) * n], &mut yb);
                    assert_eq!(y.row(b), &yb[..], "batch={batch} col={b}");
                }
            }
        }
    }

    #[test]
    fn forward_prequant_bitmatches_matvec_prequant() {
        let mut rng = Rng::new(114);
        let (m, n, batch) = (12, 48, 3);
        for layer in [
            Linear::new(rng.normal_vec(m * n, 0.3), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
            Linear::new(rng.normal_vec(m * n, 0.3), m, n, Precision::Full),
        ] {
            let x = rng.normal_vec(batch * n, 1.0);
            let xq = QuantizedBatch::quantize(&x, batch, n, 2);
            let mut y = OutputBatch::zeros(batch, m);
            layer.forward_prequant(&xq, &mut y);
            for b in 0..batch {
                let mut yb = vec![0.0; m];
                layer.matvec_prequant(&xq.column(b), &mut yb);
                assert_eq!(y.row(b), &yb[..], "col {b}");
            }
        }
    }

    #[test]
    fn quantized_layer_is_smaller() {
        let w = vec![0.1f32; 256 * 512];
        let d = Linear::new(w.clone(), 256, 512, Precision::Full);
        let q = Linear::new(w, 256, 512, Precision::Quantized { k_w: 2, k_a: 2 });
        assert!(q.bytes() * 10 < d.bytes(), "{} vs {}", q.bytes(), d.bytes());
    }
}
