//! A linear map that can be dense f32 or multi-bit quantized.
//!
//! This is the swap point that turns a full-precision model into the
//! paper's quantized one: quantized layers run the XNOR/popcount kernel
//! with online activation quantization (§4), dense layers run the blocked
//! f32 GEMV.

use crate::kernels::binary::PreparedGemv;
use crate::kernels::{binary, dense};
use crate::quant::{Method, Quantized, RowQuantized};

/// Precision/bit-width policy for one linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Full,
    /// Weights `k_w` bits, activations `k_a` bits (online).
    Quantized { k_w: usize, k_a: usize },
}

/// A (possibly quantized) linear layer `y = W x (+ b)`.
#[derive(Clone, Debug)]
pub enum Linear {
    Dense {
        w: Vec<f32>,
        rows: usize,
        cols: usize,
    },
    Quant {
        /// Contiguous serving-path layout (Perf iteration 2).
        w: PreparedGemv,
        /// Activation bit width for the online quantization step.
        k_a: usize,
    },
}

impl Linear {
    /// Build from a dense row-major matrix under the given policy.
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, precision: Precision) -> Self {
        assert_eq!(w.len(), rows * cols);
        match precision {
            Precision::Full => Linear::Dense { w, rows, cols },
            Precision::Quantized { k_w, k_a } => Linear::Quant {
                w: PreparedGemv::new(&RowQuantized::quantize(
                    &w,
                    rows,
                    cols,
                    k_w,
                    Method::Alternating { t: 2 },
                )),
                k_a,
            },
        }
    }

    /// Build a quantized layer with an explicit method (ablations).
    pub fn new_with_method(
        w: Vec<f32>,
        rows: usize,
        cols: usize,
        k_w: usize,
        k_a: usize,
        method: Method,
    ) -> Self {
        Linear::Quant { w: PreparedGemv::new(&RowQuantized::quantize(&w, rows, cols, k_w, method)), k_a }
    }

    pub fn rows(&self) -> usize {
        match self {
            Linear::Dense { rows, .. } => *rows,
            Linear::Quant { w, .. } => w.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Linear::Dense { cols, .. } => *cols,
            Linear::Quant { w, .. } => w.cols,
        }
    }

    /// `y = W x`. For quantized layers this quantizes `x` online first.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Linear::Dense { w, rows, cols } => dense::gemv(w, *rows, *cols, x, y),
            Linear::Quant { w, k_a } => w.online_gemv(x, *k_a, y),
        }
    }

    /// `y = W x̂` with a pre-quantized activation (used when the activation
    /// is shared across several layers, e.g. `h_{t-1}` feeding all gates, or
    /// comes straight out of a quantized embedding row).
    pub fn matvec_prequant(&self, xq: &Quantized, y: &mut [f32]) {
        match self {
            Linear::Dense { w, rows, cols } => {
                let xd = xq.dequantize();
                dense::gemv(w, *rows, *cols, &xd, y)
            }
            Linear::Quant { w, .. } => w.gemv(xq, y),
        }
    }

    /// Quantize an activation with this layer's activation policy (identity
    /// wrapper returning `None` for dense layers).
    pub fn quantize_input(&self, x: &[f32]) -> Option<Quantized> {
        match self {
            Linear::Dense { .. } => None,
            Linear::Quant { k_a, .. } => Some(binary::quantize_activations(x, *k_a)),
        }
    }

    /// Bytes of weight storage.
    pub fn bytes(&self) -> usize {
        match self {
            Linear::Dense { w, .. } => w.len() * 4,
            Linear::Quant { w, .. } => w.bytes(),
        }
    }

    /// A dense snapshot (dequantized for quantized layers).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Linear::Dense { w, .. } => w.clone(),
            Linear::Quant { w, .. } => w.dequantize(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_and_quant_agree_within_budget() {
        let mut rng = Rng::new(111);
        let (m, n) = (64, 128);
        let wv = rng.normal_vec(m * n, 0.2);
        let x = rng.normal_vec(n, 1.0);
        let d = Linear::new(wv.clone(), m, n, Precision::Full);
        let q = Linear::new(wv, m, n, Precision::Quantized { k_w: 3, k_a: 3 });
        let mut yd = vec![0.0; m];
        let mut yq = vec![0.0; m];
        d.matvec(&x, &mut yd);
        q.matvec(&x, &mut yq);
        let num: f64 = yd.iter().zip(&yq).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = yd.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(num / den < 0.2, "{}", num / den);
    }

    #[test]
    fn prequant_matches_online() {
        let mut rng = Rng::new(112);
        let (m, n) = (16, 64);
        let q = Linear::new(
            rng.normal_vec(m * n, 0.3),
            m,
            n,
            Precision::Quantized { k_w: 2, k_a: 2 },
        );
        let x = rng.normal_vec(n, 1.0);
        let xq = q.quantize_input(&x).unwrap();
        let mut y1 = vec![0.0; m];
        let mut y2 = vec![0.0; m];
        q.matvec(&x, &mut y1);
        q.matvec_prequant(&xq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn quantized_layer_is_smaller() {
        let w = vec![0.1f32; 256 * 512];
        let d = Linear::new(w.clone(), 256, 512, Precision::Full);
        let q = Linear::new(w, 256, 512, Precision::Quantized { k_w: 2, k_a: 2 });
        assert!(q.bytes() * 10 < d.bytes(), "{} vs {}", q.bytes(), d.bytes());
    }
}
