//! Native model zoo for the request path.
//!
//! Inference runs entirely in Rust (Python is build-time only): LSTM / GRU
//! language models whose weight matrices can be swapped between
//! full-precision and multi-bit quantized forms ([`linear::Linear`]), plus
//! the feed-forward models of Appendix B (MLP, VGG-style CNN) with native
//! STE training for the image-task tables.
//!
//! The forward API is **batch-first**: activations travel as
//! [`batch::ActivationBatch`] (B vectors, quantized once per batch into
//! shared bit-planes), layers implement [`linear::LinearOp`], and the
//! recurrent cells expose `step_batch` over `*StateBatch` state. The
//! per-vector `step`/`matvec` entry points remain as exact `B = 1` paths.
//!
//! It is also **workspace-first** on the serving path: every layer offers a
//! `*_into_exec` variant that writes into caller-owned, resized-in-place
//! buffers ([`linear::LinearWorkspace`], the cell step workspaces,
//! [`lm::LmStepWorkspace`]), so a warmed steady-state decode timestep
//! performs zero heap allocations. The allocating APIs are thin wrappers
//! over the `_into` core — one code path, bit-exact by construction.

pub mod batch;
pub mod cnn;
pub mod embedding;
pub mod gru;
pub mod linear;
pub mod lm;
pub mod lstm;
pub mod math;
pub mod mlp;

pub use batch::{ActivationBatch, OutputBatch};
pub use linear::{Linear, LinearOp, LinearWorkspace};
pub use lm::{LmConfig, LmStepWorkspace, PackedLayer, PackedLmParts, RnnKind, RnnLm};
