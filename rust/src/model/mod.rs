//! Native model zoo for the request path.
//!
//! Inference runs entirely in Rust (Python is build-time only): LSTM / GRU
//! language models whose weight matrices can be swapped between
//! full-precision and multi-bit quantized forms ([`linear::Linear`]), plus
//! the feed-forward models of Appendix B (MLP, VGG-style CNN) with native
//! STE training for the image-task tables.

pub mod cnn;
pub mod embedding;
pub mod gru;
pub mod linear;
pub mod lm;
pub mod lstm;
pub mod math;
pub mod mlp;

pub use linear::Linear;
pub use lm::{LmConfig, RnnKind, RnnLm};
