//! The RNN language model of §4–§5: embedding → (LSTM | GRU) stack →
//! softmax head, with a per-matrix precision policy.
//!
//! The model works both as the native inference engine behind the serving
//! coordinator and as the evaluation harness for the paper's PPW tables
//! (Tables 1–5): quantize a trained checkpoint's matrices and measure
//! perplexity-per-word on a held-out stream.

use anyhow::{bail, ensure, Result};

use super::batch::{ActivationBatch, OutputBatch};
use super::embedding::{Embedded, EmbeddedBatchBuf, EmbeddedBatchView, Embedding};
use super::gru::{GruCell, GruStepWorkspace};
use super::linear::{Linear, LinearOp, LinearWorkspace, Precision, QuantLinear};
use super::lstm::{LstmCell, LstmState, LstmStateBatch, LstmStepWorkspace};
use super::math::log_softmax_at;
use crate::exec::Exec;
use crate::kernels::binary::PreparedGemm;
use crate::quant::RowQuantized;
use crate::util::Rng;

/// Which recurrent cell to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RnnKind {
    Lstm,
    Gru,
}

impl RnnKind {
    pub fn gates(&self) -> usize {
        match self {
            RnnKind::Lstm => 4,
            RnnKind::Gru => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RnnKind::Lstm => "LSTM",
            RnnKind::Gru => "GRU",
        }
    }
}

/// Model hyper-parameters (paper §5: PTB h=300, WikiText-2 h=512,
/// Text8 h=1024; one hidden layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LmConfig {
    pub kind: RnnKind,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
}

impl LmConfig {
    pub fn ptb_lstm() -> Self {
        LmConfig { kind: RnnKind::Lstm, vocab: 10_000, hidden: 300, layers: 1 }
    }

    pub fn ptb_gru() -> Self {
        LmConfig { kind: RnnKind::Gru, vocab: 10_000, hidden: 300, layers: 1 }
    }
}

/// Per-matrix precision policy: the paper quantizes the gate products, the
/// softmax layer and the embedding; biases stay full precision.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionPolicy {
    pub rnn: Precision,
    pub softmax: Precision,
    /// Embedding bits (`None` = dense). Rows are quantized offline; lookups
    /// then feed the gate product pre-quantized at zero online cost (§4).
    pub embedding_bits: Option<usize>,
}

impl PrecisionPolicy {
    pub fn full() -> Self {
        PrecisionPolicy { rnn: Precision::Full, softmax: Precision::Full, embedding_bits: None }
    }

    /// The paper's W/A setting: all weight matrices k_w bits, activations
    /// k_a bits.
    pub fn quantized(k_w: usize, k_a: usize) -> Self {
        PrecisionPolicy {
            rnn: Precision::Quantized { k_w, k_a },
            softmax: Precision::Quantized { k_w, k_a },
            embedding_bits: Some(k_w),
        }
    }
}

enum Cell {
    Lstm(LstmCell),
    Gru(GruCell),
}

/// Recurrent state for the whole stack.
#[derive(Clone, Debug, PartialEq)]
pub enum LmState {
    Lstm(Vec<LstmState>),
    Gru(Vec<Vec<f32>>),
}

impl LmState {
    /// Flatten to the session-snapshot layout: LSTM emits per layer `h`
    /// then `c`, GRU per layer `h`. The inverse is [`LmState::from_flat`];
    /// both are straight copies, so a snapshot/restore cycle is bit-exact.
    pub fn flatten(&self) -> Vec<f32> {
        match self {
            LmState::Lstm(layers) => {
                let mut out = Vec::with_capacity(layers.iter().map(|l| 2 * l.h.len()).sum());
                for l in layers {
                    out.extend_from_slice(&l.h);
                    out.extend_from_slice(&l.c);
                }
                out
            }
            LmState::Gru(layers) => layers.concat(),
        }
    }

    /// Rebuild a state from its [`LmState::flatten`] layout. Refuses a
    /// buffer whose length disagrees with the config.
    pub fn from_flat(
        kind: RnnKind,
        layers: usize,
        hidden: usize,
        data: &[f32],
    ) -> Result<LmState, String> {
        let per_layer = match kind {
            RnnKind::Lstm => 2 * hidden,
            RnnKind::Gru => hidden,
        };
        if data.len() != layers * per_layer {
            return Err(format!(
                "state length {} != {layers} layers x {per_layer} ({} {hidden}-wide)",
                data.len(),
                kind.name()
            ));
        }
        Ok(match kind {
            RnnKind::Lstm => LmState::Lstm(
                data.chunks_exact(per_layer)
                    .map(|ch| LstmState { h: ch[..hidden].to_vec(), c: ch[hidden..].to_vec() })
                    .collect(),
            ),
            RnnKind::Gru => {
                LmState::Gru(data.chunks_exact(per_layer).map(<[f32]>::to_vec).collect())
            }
        })
    }
}

/// Recurrent state for a batch of `B` independent sessions, one entry per
/// layer. Built from per-session [`LmState`]s at the batching boundary
/// ([`RnnLm::gather_states`]) and split back after the batched step
/// ([`RnnLm::scatter_states`]).
#[derive(Clone, Debug, PartialEq)]
pub enum LmStateBatch {
    Lstm(Vec<LstmStateBatch>),
    Gru(Vec<ActivationBatch>),
}

impl LmStateBatch {
    /// Number of sessions in the batch.
    pub fn batch(&self) -> usize {
        match self {
            LmStateBatch::Lstm(layers) => layers.first().map_or(0, |l| l.batch),
            LmStateBatch::Gru(layers) => layers.first().map_or(0, |l| l.batch()),
        }
    }
}

/// Reusable scratch threaded through [`RnnLm::step_batch_into_exec`]: the
/// embedding-lookup buffer, one cell-step workspace (layers run
/// sequentially, so one is enough), the spare state batch that double-
/// buffers each layer's update (compute into the spare, swap it with the
/// layer's live state), and the softmax workspace. Hold one per serving
/// loop: buffers grow to the high-water batch size once, after which a
/// warmed steady-state timestep performs **zero heap allocations** on the
/// serial engine (`rust/tests/workspace_parity.rs` pins this with a
/// counting global allocator).
#[derive(Default)]
pub struct LmStepWorkspace {
    emb: EmbeddedBatchBuf,
    lstm: LstmStepWorkspace,
    gru: GruStepWorkspace,
    spare_lstm: LstmStateBatch,
    spare_gru: ActivationBatch,
    softmax_ws: LinearWorkspace,
}

impl LmStepWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The language model.
pub struct RnnLm {
    pub config: LmConfig,
    embedding: Embedding,
    cells: Vec<Cell>,
    softmax: Linear,
    softmax_bias: Vec<f32>,
}

/// One recurrent layer of a fully quantized model, disassembled into the
/// buffers the `.amqz` on-disk format stores (packed planes + alphas in
/// [`PreparedGemm`]'s serving layout, biases dense f32).
pub struct PackedLayer {
    pub wx: PreparedGemm,
    pub wh: PreparedGemm,
    pub bias: Vec<f32>,
}

/// A fully quantized model as flat packed buffers — the interchange type
/// between [`RnnLm`] and `data::amqz`. [`RnnLm::to_packed`] produces it at
/// publish time; [`RnnLm::from_packed`] adopts the buffers with **no
/// requantization**, which is what makes `.amqz` cold loads O(file size).
pub struct PackedLmParts {
    pub config: LmConfig,
    /// Weight bit width `k` shared by every matrix.
    pub w_bits: usize,
    /// Activation bit width the gate/softmax products quantize online at.
    pub a_bits: usize,
    pub embedding: RowQuantized,
    pub layers: Vec<PackedLayer>,
    pub softmax: PreparedGemm,
    pub softmax_bias: Vec<f32>,
}

/// Dense parameter bundle (interchange with the Layer-2 JAX model and the
/// checkpoint format).
#[derive(Clone, Debug, Default)]
pub struct LmWeights {
    pub embedding: Vec<f32>,          // vocab × hidden
    pub wx: Vec<Vec<f32>>,            // per layer: gates*h × in
    pub wh: Vec<Vec<f32>>,            // per layer: gates*h × h
    pub bias: Vec<Vec<f32>>,          // per layer: gates*h
    pub softmax_w: Vec<f32>,          // vocab × hidden
    pub softmax_b: Vec<f32>,          // vocab
}

impl LmWeights {
    /// Random init with the standard `U(−0.1, 0.1)` LM scaling.
    pub fn random(config: &LmConfig, rng: &mut Rng) -> Self {
        let g = config.kind.gates();
        let (v, h) = (config.vocab, config.hidden);
        let mut wx = Vec::new();
        let mut wh = Vec::new();
        let mut bias = Vec::new();
        for l in 0..config.layers {
            let input = if l == 0 { h } else { h };
            wx.push((0..g * h * input).map(|_| rng.range_f32(-0.1, 0.1)).collect());
            wh.push((0..g * h * h).map(|_| rng.range_f32(-0.1, 0.1)).collect());
            bias.push(vec![0.0; g * h]);
        }
        LmWeights {
            embedding: (0..v * h).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
            wx,
            wh,
            bias,
            softmax_w: (0..v * h).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
            softmax_b: vec![0.0; v],
        }
    }
}

impl RnnLm {
    /// Assemble a model from dense weights under a precision policy.
    pub fn from_weights(config: LmConfig, w: &LmWeights, policy: PrecisionPolicy) -> Self {
        Self::from_weights_exec(config, w, policy, &Exec::serial())
    }

    /// [`Self::from_weights`] with every per-row weight quantization
    /// (embedding, gate products, softmax) sharded across `exec`'s workers.
    /// The built model is bit-identical for any thread count.
    pub fn from_weights_exec(
        config: LmConfig,
        w: &LmWeights,
        policy: PrecisionPolicy,
        exec: &Exec,
    ) -> Self {
        let (v, h) = (config.vocab, config.hidden);
        let embedding = match policy.embedding_bits {
            None => Embedding::new_dense(w.embedding.clone(), v, h),
            Some(k) => Embedding::new_quantized_exec(w.embedding.clone(), v, h, k, exec),
        };
        let mut cells = Vec::new();
        for l in 0..config.layers {
            let input = h;
            let cell = match config.kind {
                RnnKind::Lstm => Cell::Lstm(LstmCell::from_dense_exec(
                    w.wx[l].clone(),
                    w.wh[l].clone(),
                    w.bias[l].clone(),
                    input,
                    h,
                    policy.rnn,
                    exec,
                )),
                RnnKind::Gru => Cell::Gru(GruCell::from_dense_exec(
                    w.wx[l].clone(),
                    w.wh[l].clone(),
                    w.bias[l].clone(),
                    input,
                    h,
                    policy.rnn,
                    exec,
                )),
            };
            cells.push(cell);
        }
        RnnLm {
            config,
            embedding,
            cells,
            softmax: Linear::new_exec(w.softmax_w.clone(), v, h, policy.softmax, exec),
            softmax_bias: w.softmax_b.clone(),
        }
    }

    /// Random model (tests, cold starts).
    pub fn random(config: LmConfig, seed: u64, policy: PrecisionPolicy) -> Self {
        Self::random_exec(config, seed, policy, &Exec::serial())
    }

    /// [`Self::random`] built on an execution engine (see
    /// [`Self::from_weights_exec`]).
    pub fn random_exec(config: LmConfig, seed: u64, policy: PrecisionPolicy, exec: &Exec) -> Self {
        let mut rng = Rng::new(seed);
        let w = LmWeights::random(&config, &mut rng);
        Self::from_weights_exec(config, &w, policy, exec)
    }

    /// Disassemble a fully quantized model into [`PackedLmParts`] — the
    /// buffers `data::amqz` writes verbatim. Errors if any matrix is dense
    /// (the `.amqz` format only stores packed planes + alphas; publish a
    /// quantized policy).
    pub fn to_packed(&self) -> Result<PackedLmParts> {
        let embedding = match &self.embedding {
            Embedding::Quant { w } => w.clone(),
            Embedding::Dense { .. } => {
                bail!("embedding is dense — publishing requires a fully quantized model")
            }
        };
        let take = |lin: &Linear, what: &str| -> Result<(PreparedGemm, usize)> {
            match lin {
                Linear::Quant(q) => Ok((q.prepared().clone(), q.k_a())),
                Linear::Dense(_) => {
                    bail!("{what} is dense — publishing requires a fully quantized model")
                }
            }
        };
        let mut layers = Vec::with_capacity(self.cells.len());
        let mut a_bits = 0;
        for (l, cell) in self.cells.iter().enumerate() {
            let (wx, wh, bias) = match cell {
                Cell::Lstm(c) => (&c.wx, &c.wh, &c.bias),
                Cell::Gru(c) => (&c.wx, &c.wh, &c.bias),
            };
            let (wx, ka) = take(wx, &format!("layer {l} wx"))?;
            let (wh, _) = take(wh, &format!("layer {l} wh"))?;
            a_bits = ka;
            layers.push(PackedLayer { wx, wh, bias: bias.clone() });
        }
        let (softmax, softmax_ka) = take(&self.softmax, "softmax")?;
        if a_bits == 0 {
            a_bits = softmax_ka;
        }
        Ok(PackedLmParts {
            config: self.config,
            w_bits: embedding.k,
            a_bits,
            embedding,
            layers,
            softmax,
            softmax_bias: self.softmax_bias.clone(),
        })
    }

    /// Reassemble a model from [`PackedLmParts`] — the `.amqz` load path.
    /// No quantization runs: the prepared matrices are adopted as-is, so
    /// the result is bit-identical to the model that was published
    /// (pinned by `rust/tests/amqz_roundtrip.rs`). Shapes are validated so
    /// a corrupt or mismatched file errors instead of panicking later.
    pub fn from_packed(parts: PackedLmParts) -> Result<Self> {
        let PackedLmParts { config, w_bits, a_bits, embedding, layers, softmax, softmax_bias } =
            parts;
        let (v, h, g) = (config.vocab, config.hidden, config.kind.gates());
        ensure!(w_bits >= 1 && a_bits >= 1, "bit widths must be at least 1");
        ensure!(
            layers.len() == config.layers,
            "expected {} layers, got {}",
            config.layers,
            layers.len()
        );
        ensure!(
            embedding.rows == v && embedding.cols == h && embedding.k == w_bits,
            "embedding shape {}x{} k={} does not match config {v}x{h} k={w_bits}",
            embedding.rows,
            embedding.cols,
            embedding.k
        );
        ensure!(
            softmax.rows == v && softmax.cols == h && softmax.k == w_bits,
            "softmax shape {}x{} k={} does not match config {v}x{h} k={w_bits}",
            softmax.rows,
            softmax.cols,
            softmax.k
        );
        ensure!(softmax_bias.len() == v, "softmax bias length {} != vocab {v}", softmax_bias.len());
        let mut cells = Vec::with_capacity(layers.len());
        for (l, layer) in layers.into_iter().enumerate() {
            for (m, what) in [(&layer.wx, "wx"), (&layer.wh, "wh")] {
                ensure!(
                    m.rows == g * h && m.cols == h && m.k == w_bits,
                    "layer {l} {what} shape {}x{} k={} does not match config {}x{h} k={w_bits}",
                    m.rows,
                    m.cols,
                    m.k,
                    g * h
                );
            }
            ensure!(
                layer.bias.len() == g * h,
                "layer {l} bias length {} != {}",
                layer.bias.len(),
                g * h
            );
            let wx = Linear::Quant(QuantLinear::from_prepared(layer.wx, a_bits));
            let wh = Linear::Quant(QuantLinear::from_prepared(layer.wh, a_bits));
            cells.push(match config.kind {
                RnnKind::Lstm => {
                    Cell::Lstm(LstmCell { wx, wh, bias: layer.bias, hidden: h, input: h })
                }
                RnnKind::Gru => {
                    Cell::Gru(GruCell { wx, wh, bias: layer.bias, hidden: h, input: h })
                }
            });
        }
        Ok(RnnLm {
            config,
            embedding: Embedding::Quant { w: embedding },
            cells,
            softmax: Linear::Quant(QuantLinear::from_prepared(softmax, a_bits)),
            softmax_bias,
        })
    }

    pub fn zero_state(&self) -> LmState {
        match self.config.kind {
            RnnKind::Lstm => {
                LmState::Lstm(vec![LstmState::zeros(self.config.hidden); self.config.layers])
            }
            RnnKind::Gru => {
                LmState::Gru(vec![vec![0.0; self.config.hidden]; self.config.layers])
            }
        }
    }

    /// Zero state for a batch of `batch` fresh sessions.
    pub fn zero_state_batch(&self, batch: usize) -> LmStateBatch {
        let h = self.config.hidden;
        match self.config.kind {
            RnnKind::Lstm => LmStateBatch::Lstm(
                (0..self.config.layers).map(|_| LstmStateBatch::zeros(batch, h)).collect(),
            ),
            RnnKind::Gru => LmStateBatch::Gru(
                (0..self.config.layers).map(|_| ActivationBatch::zeros(batch, h)).collect(),
            ),
        }
    }

    /// Gather per-session states into one batch (the server's batching
    /// boundary). All states must match this model's kind and shape. A thin
    /// wrapper over [`Self::gather_states_into`] (one code path).
    pub fn gather_states(&self, states: &[&LmState]) -> LmStateBatch {
        let mut out = match self.config.kind {
            RnnKind::Lstm => LmStateBatch::Lstm(Vec::new()),
            RnnKind::Gru => LmStateBatch::Gru(Vec::new()),
        };
        self.gather_states_into(states, &mut out);
        out
    }

    /// [`Self::gather_states`] into a reused batch-state buffer (resized in
    /// place, capacity kept): the server gathers every timestep group with
    /// zero steady-state heap allocation. Identical values to
    /// [`Self::gather_states`].
    pub fn gather_states_into(&self, states: &[&LmState], out: &mut LmStateBatch) {
        assert!(!states.is_empty(), "empty state batch");
        let (batch, h) = (states.len(), self.config.hidden);
        match self.config.kind {
            RnnKind::Lstm => {
                if !matches!(out, LmStateBatch::Lstm(_)) {
                    *out = LmStateBatch::Lstm(Vec::new());
                }
                let LmStateBatch::Lstm(layers) = out else { unreachable!() };
                layers.resize_with(self.config.layers, LstmStateBatch::default);
                for (l, lb) in layers.iter_mut().enumerate() {
                    lb.reset(batch, h);
                    for (b, s) in states.iter().enumerate() {
                        let LmState::Lstm(v) = &**s else { panic!("GRU state in an LSTM model") };
                        assert_eq!(v[l].h.len(), h, "state dimension mismatch");
                        assert_eq!(v[l].c.len(), h, "state dimension mismatch");
                        lb.h.row_mut(b).copy_from_slice(&v[l].h);
                        lb.c[b * h..(b + 1) * h].copy_from_slice(&v[l].c);
                    }
                }
            }
            RnnKind::Gru => {
                if !matches!(out, LmStateBatch::Gru(_)) {
                    *out = LmStateBatch::Gru(Vec::new());
                }
                let LmStateBatch::Gru(layers) = out else { unreachable!() };
                layers.resize_with(self.config.layers, ActivationBatch::default);
                for (l, lb) in layers.iter_mut().enumerate() {
                    lb.reset(batch, h);
                    for (b, s) in states.iter().enumerate() {
                        let LmState::Gru(v) = &**s else { panic!("LSTM state in a GRU model") };
                        assert_eq!(v[l].len(), h, "state dimension mismatch");
                        lb.row_mut(b).copy_from_slice(&v[l]);
                    }
                }
            }
        }
    }

    /// Append one session's state as a new column of a batched state — the
    /// continuous batcher's **slot join**: a sequence arriving mid-decode
    /// enters the running batch at the next timestep boundary without
    /// re-gathering the columns already resident. `out` must be a batch of
    /// this model's kind and layer count (an empty one from
    /// [`Self::zero_state_batch`]`(0)` qualifies, and any kind/shape
    /// mismatch on an empty batch is normalized in place). O(layers ·
    /// hidden); allocation-free once the batch has reached its high-water
    /// capacity. Column values are bit-identical to a full
    /// [`Self::gather_states_into`] of the same composition.
    pub fn push_state_column(&self, s: &LmState, out: &mut LmStateBatch) {
        let layers_ok = match &*out {
            LmStateBatch::Lstm(layers) => {
                self.config.kind == RnnKind::Lstm && layers.len() == self.config.layers
            }
            LmStateBatch::Gru(layers) => {
                self.config.kind == RnnKind::Gru && layers.len() == self.config.layers
            }
        };
        if !layers_ok {
            assert_eq!(out.batch(), 0, "state-batch kind/shape mismatch on a non-empty batch");
            *out = self.zero_state_batch(0);
        }
        match (s, out) {
            (LmState::Lstm(v), LmStateBatch::Lstm(layers)) => {
                assert_eq!(v.len(), layers.len(), "layer count mismatch");
                for (sv, lb) in v.iter().zip(layers.iter_mut()) {
                    lb.push_state(sv);
                }
            }
            (LmState::Gru(v), LmStateBatch::Gru(layers)) => {
                assert_eq!(v.len(), layers.len(), "layer count mismatch");
                for (sv, lb) in v.iter().zip(layers.iter_mut()) {
                    lb.push_row(sv);
                }
            }
            _ => panic!("session state kind does not match the model"),
        }
    }

    /// Free column `b` of a batched state by moving the **last** column
    /// into its place — the continuous batcher's **slot free**: a finished
    /// sequence leaves the running batch in O(layers · hidden) without
    /// disturbing any other resident column's values. Extract the column
    /// first ([`Self::scatter_state_into`]) if it is still needed. The
    /// caller owns the index remap (the sequence that lived in the last
    /// column now answers to index `b`).
    pub fn swap_remove_state_column(&self, state: &mut LmStateBatch, b: usize) {
        match state {
            LmStateBatch::Lstm(layers) => {
                for lb in layers.iter_mut() {
                    lb.swap_remove(b);
                }
            }
            LmStateBatch::Gru(layers) => {
                for lb in layers.iter_mut() {
                    lb.swap_remove_row(b);
                }
            }
        }
    }

    /// Split a batched state back into per-session states (inverse of
    /// [`Self::gather_states`]). A thin wrapper over
    /// [`Self::scatter_state_into`].
    pub fn scatter_states(&self, state: &LmStateBatch) -> Vec<LmState> {
        (0..state.batch())
            .map(|b| {
                let mut out = self.zero_state();
                self.scatter_state_into(state, b, &mut out);
                out
            })
            .collect()
    }

    /// Copy column `b` of a batched state into an existing per-session
    /// state in place — the zero-allocation inverse of one column of
    /// [`Self::gather_states_into`] (the session buffers keep their
    /// capacity across timestep groups). Identical values to
    /// `scatter_states(state)[b]`.
    pub fn scatter_state_into(&self, state: &LmStateBatch, b: usize, out: &mut LmState) {
        let h = self.config.hidden;
        let kind_matches = matches!(
            (state, &*out),
            (LmStateBatch::Lstm(_), LmState::Lstm(_)) | (LmStateBatch::Gru(_), LmState::Gru(_))
        );
        if !kind_matches {
            *out = self.zero_state();
        }
        match (state, out) {
            (LmStateBatch::Lstm(layers), LmState::Lstm(v)) => {
                v.resize_with(layers.len(), || LstmState::zeros(h));
                for (l, lb) in layers.iter().enumerate() {
                    v[l].h.clear();
                    v[l].h.extend_from_slice(lb.h.row(b));
                    v[l].c.clear();
                    v[l].c.extend_from_slice(&lb.c[b * lb.hidden..(b + 1) * lb.hidden]);
                }
            }
            (LmStateBatch::Gru(layers), LmState::Gru(v)) => {
                v.resize_with(layers.len(), || vec![0.0; h]);
                for (l, lb) in layers.iter().enumerate() {
                    v[l].clear();
                    v[l].extend_from_slice(lb.row(b));
                }
            }
            _ => unreachable!("state kind normalized above"),
        }
    }

    /// One batched inference step: consume one token per session, update the
    /// batched `state`, and return a `batch × vocab` logit matrix. Each
    /// weight matrix is swept **once for the whole batch** (Fig. 3 right);
    /// results bit-match `batch` independent [`Self::step`] calls.
    pub fn step_batch(&self, tokens: &[usize], state: &mut LmStateBatch) -> OutputBatch {
        self.step_batch_exec(tokens, state, &Exec::serial())
    }

    /// [`Self::step_batch`] on an execution engine: the gate products of
    /// every cell and the softmax GEMM are row-sharded across `exec`'s
    /// workers. Bit-exact vs the serial [`Self::step_batch`] (and hence vs
    /// per-session [`Self::step`]) for any thread count — the worker pool
    /// is invisible to clients. A thin wrapper over
    /// [`Self::step_batch_into_exec`] with fresh buffers (one code path).
    pub fn step_batch_exec(
        &self,
        tokens: &[usize],
        state: &mut LmStateBatch,
        exec: &Exec,
    ) -> OutputBatch {
        let mut logits = OutputBatch::default();
        self.step_batch_into_exec(tokens, state, &mut logits, exec, &mut LmStepWorkspace::new());
        logits
    }

    /// [`Self::step_batch_exec`] through caller-owned buffers end to end —
    /// the steady-state serving step. The logit matrix is written into
    /// `logits` (resized in place), the embedding rows, quantized
    /// activations, gate products, and softmax scratch all live in `ws`,
    /// and each layer's state updates by double buffer: the new state is
    /// computed into `ws`'s spare and swapped with the layer's live state —
    /// no buffer is ever allocated or cloned. Bit-identical to
    /// [`Self::step_batch_exec`] for any engine; once `ws`, `state`, and
    /// `logits` are warm (one call at the high-water batch size), a
    /// steady-state timestep performs **zero heap allocations** on the
    /// serial engine (`rust/tests/workspace_parity.rs`).
    pub fn step_batch_into_exec(
        &self,
        tokens: &[usize],
        state: &mut LmStateBatch,
        logits: &mut OutputBatch,
        exec: &Exec,
        ws: &mut LmStepWorkspace,
    ) {
        let batch = tokens.len();
        assert!(batch > 0, "empty token batch");
        assert_eq!(batch, state.batch(), "token/state batch mismatch");
        self.embedding.lookup_batch_into(tokens, &mut ws.emb);
        for (l, cell) in self.cells.iter().enumerate() {
            match (cell, &mut *state) {
                (Cell::Lstm(c), LmStateBatch::Lstm(states)) => {
                    if l == 0 {
                        match ws.emb.view() {
                            EmbeddedBatchView::Quant(q) => c.step_batch_prequant_into_exec(
                                q,
                                &states[0],
                                &mut ws.spare_lstm,
                                exec,
                                &mut ws.lstm,
                            ),
                            EmbeddedBatchView::Dense(a) => c.step_batch_into_exec(
                                a,
                                &states[0],
                                &mut ws.spare_lstm,
                                exec,
                                &mut ws.lstm,
                            ),
                        }
                    } else {
                        // The previous layer's state already holds its NEW
                        // hidden batch (swapped below) — it is this layer's
                        // input, borrowed without a clone.
                        let (done, rest) = states.split_at_mut(l);
                        c.step_batch_into_exec(
                            &done[l - 1].h,
                            &rest[0],
                            &mut ws.spare_lstm,
                            exec,
                            &mut ws.lstm,
                        );
                    }
                    std::mem::swap(&mut states[l], &mut ws.spare_lstm);
                }
                (Cell::Gru(c), LmStateBatch::Gru(states)) => {
                    if l == 0 {
                        match ws.emb.view() {
                            EmbeddedBatchView::Quant(q) => c.step_batch_prequant_into_exec(
                                q,
                                &states[0],
                                &mut ws.spare_gru,
                                exec,
                                &mut ws.gru,
                            ),
                            EmbeddedBatchView::Dense(a) => c.step_batch_into_exec(
                                a,
                                &states[0],
                                &mut ws.spare_gru,
                                exec,
                                &mut ws.gru,
                            ),
                        }
                    } else {
                        let (done, rest) = states.split_at_mut(l);
                        c.step_batch_into_exec(
                            &done[l - 1],
                            &rest[0],
                            &mut ws.spare_gru,
                            exec,
                            &mut ws.gru,
                        );
                    }
                    std::mem::swap(&mut states[l], &mut ws.spare_gru);
                }
                _ => unreachable!("state kind matches cell kind by construction"),
            }
        }
        let top: &ActivationBatch = match &*state {
            LmStateBatch::Lstm(states) => &states.last().expect("at least one layer").h,
            LmStateBatch::Gru(states) => states.last().expect("at least one layer"),
        };
        self.softmax.forward_into_exec(top, logits, exec, &mut ws.softmax_ws);
        for b in 0..batch {
            for (lg, &bias) in logits.row_mut(b).iter_mut().zip(&self.softmax_bias) {
                *lg += bias;
            }
        }
    }

    /// One inference step: consume `token`, update `state`, return logits
    /// over the vocabulary.
    pub fn step(&self, token: usize, state: &mut LmState) -> Vec<f32> {
        let emb = self.embedding.lookup(token);
        let mut x: Vec<f32> = Vec::new();
        let mut x_prequant: Option<crate::quant::Quantized> = None;
        match emb {
            Embedded::Dense(v) => x = v,
            Embedded::Quant(q) => x_prequant = Some(q),
        }
        for (l, cell) in self.cells.iter().enumerate() {
            match (cell, &mut *state) {
                (Cell::Lstm(c), LmState::Lstm(states)) => {
                    let s = if l == 0 {
                        if let Some(q) = &x_prequant {
                            c.step_prequant(q, &states[l])
                        } else {
                            c.step(&x, &states[l])
                        }
                    } else {
                        c.step(&x, &states[l])
                    };
                    x = s.h.clone();
                    states[l] = s;
                }
                (Cell::Gru(c), LmState::Gru(states)) => {
                    let s = if l == 0 {
                        if let Some(q) = &x_prequant {
                            c.step_prequant(q, &states[l])
                        } else {
                            c.step(&x, &states[l])
                        }
                    } else {
                        c.step(&x, &states[l])
                    };
                    x = s.clone();
                    states[l] = s;
                }
                _ => unreachable!("state kind matches cell kind by construction"),
            }
        }
        let mut logits = self.softmax_bias.clone();
        let mut y = vec![0.0f32; self.config.vocab];
        self.softmax.matvec(&x, &mut y);
        for (l, v) in logits.iter_mut().zip(&y) {
            *l += v;
        }
        logits
    }

    /// Perplexity per word over a token stream (the paper's metric):
    /// `exp( −1/(N−1) Σ log p(tokenᵢ₊₁ | …) )`.
    pub fn ppw(&self, tokens: &[usize]) -> f64 {
        assert!(tokens.len() >= 2, "need at least two tokens");
        let mut state = self.zero_state();
        let mut nll = 0.0f64;
        for i in 0..tokens.len() - 1 {
            let logits = self.step(tokens[i], &mut state);
            nll -= log_softmax_at(&logits, tokens[i + 1]) as f64;
        }
        (nll / (tokens.len() - 1) as f64).exp()
    }

    /// Total weight bytes (the memory-saving claims of the abstract).
    pub fn bytes(&self) -> usize {
        let cell_bytes: usize = self
            .cells
            .iter()
            .map(|c| match c {
                Cell::Lstm(c) => c.bytes(),
                Cell::Gru(c) => c.bytes(),
            })
            .sum();
        self.embedding.bytes() + cell_bytes + self.softmax.bytes() + self.softmax_bias.len() * 4
    }

    /// Activation bit width of the quantized serving path (`None` when
    /// the model serves full precision) — what the startup line and STATS
    /// resolve the batch-tile width against.
    pub fn a_bits(&self) -> Option<usize> {
        self.softmax.a_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(kind: RnnKind) -> LmConfig {
        LmConfig { kind, vocab: 50, hidden: 32, layers: 1 }
    }

    #[test]
    fn step_produces_vocab_logits() {
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            let lm = RnnLm::random(tiny(kind), 1, PrecisionPolicy::full());
            let mut st = lm.zero_state();
            let logits = lm.step(3, &mut st);
            assert_eq!(logits.len(), 50);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn state_evolves() {
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 2, PrecisionPolicy::full());
        let mut st = lm.zero_state();
        lm.step(1, &mut st);
        assert_ne!(st, lm.zero_state());
    }

    #[test]
    fn random_model_ppw_near_vocab_size() {
        // An untrained model is ~uniform ⇒ PPW ≈ |V|.
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 3, PrecisionPolicy::full());
        let tokens: Vec<usize> = (0..300).map(|i| (i * 7) % 50).collect();
        let ppw = lm.ppw(&tokens);
        assert!((25.0..100.0).contains(&ppw), "ppw={ppw}");
    }

    #[test]
    fn step_batch_bitmatches_step_per_session() {
        // The whole-model batching contract: embedding (incl. prequant rows),
        // both cells, and the softmax head are exact under batching.
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            for policy in [PrecisionPolicy::full(), PrecisionPolicy::quantized(2, 2)] {
                let lm = RnnLm::random(tiny(kind), 11, policy);
                for batch in 1..=4 {
                    let mut singles: Vec<LmState> =
                        (0..batch).map(|_| lm.zero_state()).collect();
                    let mut batched = lm.zero_state_batch(batch);
                    for round in 0..3 {
                        let tokens: Vec<usize> =
                            (0..batch).map(|b| (7 * b + 13 * round + 1) % 50).collect();
                        let logits = lm.step_batch(&tokens, &mut batched);
                        for b in 0..batch {
                            let expect = lm.step(tokens[b], &mut singles[b]);
                            assert_eq!(
                                logits.row(b),
                                &expect[..],
                                "{kind:?} batch={batch} round={round} col={b}"
                            );
                        }
                        let scattered = lm.scatter_states(&batched);
                        assert_eq!(scattered, singles, "{kind:?} batch={batch} round={round}");
                    }
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 12, PrecisionPolicy::full());
        let mut singles: Vec<LmState> = (0..3).map(|_| lm.zero_state()).collect();
        for (i, s) in singles.iter_mut().enumerate() {
            lm.step(i + 1, s);
        }
        let refs: Vec<&LmState> = singles.iter().collect();
        let gathered = lm.gather_states(&refs);
        assert_eq!(lm.scatter_states(&gathered), singles);
    }

    #[test]
    fn push_and_swap_remove_columns_match_gather() {
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            let lm = RnnLm::random(tiny(kind), 21, PrecisionPolicy::quantized(2, 2));
            let mut singles: Vec<LmState> = (0..4).map(|_| lm.zero_state()).collect();
            for (i, s) in singles.iter_mut().enumerate() {
                lm.step(2 * i + 1, s);
                lm.step(3 * i + 2, s);
            }
            // Joining columns one by one builds the same batch as a gather.
            let mut batch = lm.zero_state_batch(0);
            for s in &singles {
                lm.push_state_column(s, &mut batch);
            }
            let refs: Vec<&LmState> = singles.iter().collect();
            assert_eq!(batch, lm.gather_states(&refs));
            // Freeing column 1 moves column 3 into its place: the result
            // equals a gather of [0, 3, 2].
            lm.swap_remove_state_column(&mut batch, 1);
            let expect = lm.gather_states(&[&singles[0], &singles[3], &singles[2]]);
            assert_eq!(batch, expect);
            // Drain to empty, then re-join into the kept capacity.
            lm.swap_remove_state_column(&mut batch, 2);
            lm.swap_remove_state_column(&mut batch, 0);
            lm.swap_remove_state_column(&mut batch, 0);
            assert_eq!(batch.batch(), 0);
            lm.push_state_column(&singles[2], &mut batch);
            assert_eq!(batch, lm.gather_states(&[&singles[2]]));
        }
    }

    #[test]
    fn quantized_model_is_much_smaller_and_close_in_ppw() {
        let config = tiny(RnnKind::Gru);
        let mut rng = Rng::new(4);
        let w = LmWeights::random(&config, &mut rng);
        let fp = RnnLm::from_weights(config, &w, PrecisionPolicy::full());
        let q3 = RnnLm::from_weights(config, &w, PrecisionPolicy::quantized(3, 3));
        // At this toy size packing overhead dims the ratio; the realistic
        // ~10.5× (3-bit) figure is asserted in quant::matrix at 4096×1024.
        assert!(q3.bytes() * 3 < fp.bytes(), "{} vs {}", q3.bytes(), fp.bytes());
        let tokens: Vec<usize> = (0..200).map(|i| (i * 13 + 5) % 50).collect();
        let (p_fp, p_q) = (fp.ppw(&tokens), q3.ppw(&tokens));
        let rel = (p_q - p_fp).abs() / p_fp;
        assert!(rel < 0.25, "fp={p_fp} q={p_q}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = RnnLm::random(tiny(RnnKind::Lstm), 7, PrecisionPolicy::full());
        let b = RnnLm::random(tiny(RnnKind::Lstm), 7, PrecisionPolicy::full());
        let t: Vec<usize> = (0..50).map(|i| i % 50).collect();
        assert_eq!(a.ppw(&t), b.ppw(&t));
    }
}
