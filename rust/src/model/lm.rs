//! The RNN language model of §4–§5: embedding → (LSTM | GRU) stack →
//! softmax head, with a per-matrix precision policy.
//!
//! The model works both as the native inference engine behind the serving
//! coordinator and as the evaluation harness for the paper's PPW tables
//! (Tables 1–5): quantize a trained checkpoint's matrices and measure
//! perplexity-per-word on a held-out stream.

use super::batch::{ActivationBatch, OutputBatch};
use super::embedding::{Embedded, EmbeddedBatch, Embedding};
use super::gru::GruCell;
use super::linear::{Linear, LinearOp, Precision};
use super::lstm::{LstmCell, LstmState, LstmStateBatch};
use super::math::log_softmax_at;
use crate::exec::Exec;
use crate::quant::QuantizedBatch;
use crate::util::Rng;

/// Which recurrent cell to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RnnKind {
    Lstm,
    Gru,
}

impl RnnKind {
    pub fn gates(&self) -> usize {
        match self {
            RnnKind::Lstm => 4,
            RnnKind::Gru => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RnnKind::Lstm => "LSTM",
            RnnKind::Gru => "GRU",
        }
    }
}

/// Model hyper-parameters (paper §5: PTB h=300, WikiText-2 h=512,
/// Text8 h=1024; one hidden layer).
#[derive(Clone, Copy, Debug)]
pub struct LmConfig {
    pub kind: RnnKind,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
}

impl LmConfig {
    pub fn ptb_lstm() -> Self {
        LmConfig { kind: RnnKind::Lstm, vocab: 10_000, hidden: 300, layers: 1 }
    }

    pub fn ptb_gru() -> Self {
        LmConfig { kind: RnnKind::Gru, vocab: 10_000, hidden: 300, layers: 1 }
    }
}

/// Per-matrix precision policy: the paper quantizes the gate products, the
/// softmax layer and the embedding; biases stay full precision.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionPolicy {
    pub rnn: Precision,
    pub softmax: Precision,
    /// Embedding bits (`None` = dense). Rows are quantized offline; lookups
    /// then feed the gate product pre-quantized at zero online cost (§4).
    pub embedding_bits: Option<usize>,
}

impl PrecisionPolicy {
    pub fn full() -> Self {
        PrecisionPolicy { rnn: Precision::Full, softmax: Precision::Full, embedding_bits: None }
    }

    /// The paper's W/A setting: all weight matrices k_w bits, activations
    /// k_a bits.
    pub fn quantized(k_w: usize, k_a: usize) -> Self {
        PrecisionPolicy {
            rnn: Precision::Quantized { k_w, k_a },
            softmax: Precision::Quantized { k_w, k_a },
            embedding_bits: Some(k_w),
        }
    }
}

enum Cell {
    Lstm(LstmCell),
    Gru(GruCell),
}

/// Recurrent state for the whole stack.
#[derive(Clone, Debug, PartialEq)]
pub enum LmState {
    Lstm(Vec<LstmState>),
    Gru(Vec<Vec<f32>>),
}

/// Recurrent state for a batch of `B` independent sessions, one entry per
/// layer. Built from per-session [`LmState`]s at the batching boundary
/// ([`RnnLm::gather_states`]) and split back after the batched step
/// ([`RnnLm::scatter_states`]).
#[derive(Clone, Debug, PartialEq)]
pub enum LmStateBatch {
    Lstm(Vec<LstmStateBatch>),
    Gru(Vec<ActivationBatch>),
}

impl LmStateBatch {
    /// Number of sessions in the batch.
    pub fn batch(&self) -> usize {
        match self {
            LmStateBatch::Lstm(layers) => layers.first().map_or(0, |l| l.batch),
            LmStateBatch::Gru(layers) => layers.first().map_or(0, |l| l.batch()),
        }
    }
}

/// The language model.
pub struct RnnLm {
    pub config: LmConfig,
    embedding: Embedding,
    cells: Vec<Cell>,
    softmax: Linear,
    softmax_bias: Vec<f32>,
}

/// Dense parameter bundle (interchange with the Layer-2 JAX model and the
/// checkpoint format).
#[derive(Clone, Debug, Default)]
pub struct LmWeights {
    pub embedding: Vec<f32>,          // vocab × hidden
    pub wx: Vec<Vec<f32>>,            // per layer: gates*h × in
    pub wh: Vec<Vec<f32>>,            // per layer: gates*h × h
    pub bias: Vec<Vec<f32>>,          // per layer: gates*h
    pub softmax_w: Vec<f32>,          // vocab × hidden
    pub softmax_b: Vec<f32>,          // vocab
}

impl LmWeights {
    /// Random init with the standard `U(−0.1, 0.1)` LM scaling.
    pub fn random(config: &LmConfig, rng: &mut Rng) -> Self {
        let g = config.kind.gates();
        let (v, h) = (config.vocab, config.hidden);
        let mut wx = Vec::new();
        let mut wh = Vec::new();
        let mut bias = Vec::new();
        for l in 0..config.layers {
            let input = if l == 0 { h } else { h };
            wx.push((0..g * h * input).map(|_| rng.range_f32(-0.1, 0.1)).collect());
            wh.push((0..g * h * h).map(|_| rng.range_f32(-0.1, 0.1)).collect());
            bias.push(vec![0.0; g * h]);
        }
        LmWeights {
            embedding: (0..v * h).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
            wx,
            wh,
            bias,
            softmax_w: (0..v * h).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
            softmax_b: vec![0.0; v],
        }
    }
}

impl RnnLm {
    /// Assemble a model from dense weights under a precision policy.
    pub fn from_weights(config: LmConfig, w: &LmWeights, policy: PrecisionPolicy) -> Self {
        Self::from_weights_exec(config, w, policy, &Exec::serial())
    }

    /// [`Self::from_weights`] with every per-row weight quantization
    /// (embedding, gate products, softmax) sharded across `exec`'s workers.
    /// The built model is bit-identical for any thread count.
    pub fn from_weights_exec(
        config: LmConfig,
        w: &LmWeights,
        policy: PrecisionPolicy,
        exec: &Exec,
    ) -> Self {
        let (v, h) = (config.vocab, config.hidden);
        let embedding = match policy.embedding_bits {
            None => Embedding::new_dense(w.embedding.clone(), v, h),
            Some(k) => Embedding::new_quantized_exec(w.embedding.clone(), v, h, k, exec),
        };
        let mut cells = Vec::new();
        for l in 0..config.layers {
            let input = h;
            let cell = match config.kind {
                RnnKind::Lstm => Cell::Lstm(LstmCell::from_dense_exec(
                    w.wx[l].clone(),
                    w.wh[l].clone(),
                    w.bias[l].clone(),
                    input,
                    h,
                    policy.rnn,
                    exec,
                )),
                RnnKind::Gru => Cell::Gru(GruCell::from_dense_exec(
                    w.wx[l].clone(),
                    w.wh[l].clone(),
                    w.bias[l].clone(),
                    input,
                    h,
                    policy.rnn,
                    exec,
                )),
            };
            cells.push(cell);
        }
        RnnLm {
            config,
            embedding,
            cells,
            softmax: Linear::new_exec(w.softmax_w.clone(), v, h, policy.softmax, exec),
            softmax_bias: w.softmax_b.clone(),
        }
    }

    /// Random model (tests, cold starts).
    pub fn random(config: LmConfig, seed: u64, policy: PrecisionPolicy) -> Self {
        Self::random_exec(config, seed, policy, &Exec::serial())
    }

    /// [`Self::random`] built on an execution engine (see
    /// [`Self::from_weights_exec`]).
    pub fn random_exec(config: LmConfig, seed: u64, policy: PrecisionPolicy, exec: &Exec) -> Self {
        let mut rng = Rng::new(seed);
        let w = LmWeights::random(&config, &mut rng);
        Self::from_weights_exec(config, &w, policy, exec)
    }

    pub fn zero_state(&self) -> LmState {
        match self.config.kind {
            RnnKind::Lstm => {
                LmState::Lstm(vec![LstmState::zeros(self.config.hidden); self.config.layers])
            }
            RnnKind::Gru => {
                LmState::Gru(vec![vec![0.0; self.config.hidden]; self.config.layers])
            }
        }
    }

    /// Zero state for a batch of `batch` fresh sessions.
    pub fn zero_state_batch(&self, batch: usize) -> LmStateBatch {
        let h = self.config.hidden;
        match self.config.kind {
            RnnKind::Lstm => LmStateBatch::Lstm(
                (0..self.config.layers).map(|_| LstmStateBatch::zeros(batch, h)).collect(),
            ),
            RnnKind::Gru => LmStateBatch::Gru(
                (0..self.config.layers).map(|_| ActivationBatch::zeros(batch, h)).collect(),
            ),
        }
    }

    /// Gather per-session states into one batch (the server's batching
    /// boundary). All states must match this model's kind and shape.
    pub fn gather_states(&self, states: &[&LmState]) -> LmStateBatch {
        assert!(!states.is_empty(), "empty state batch");
        match self.config.kind {
            RnnKind::Lstm => LmStateBatch::Lstm(
                (0..self.config.layers)
                    .map(|l| {
                        let layer: Vec<&LstmState> = states
                            .iter()
                            .map(|s| match s {
                                LmState::Lstm(v) => &v[l],
                                LmState::Gru(_) => panic!("GRU state in an LSTM model"),
                            })
                            .collect();
                        LstmStateBatch::from_states(&layer)
                    })
                    .collect(),
            ),
            RnnKind::Gru => LmStateBatch::Gru(
                (0..self.config.layers)
                    .map(|l| {
                        let layer: Vec<&[f32]> = states
                            .iter()
                            .map(|s| match s {
                                LmState::Gru(v) => v[l].as_slice(),
                                LmState::Lstm(_) => panic!("LSTM state in a GRU model"),
                            })
                            .collect();
                        ActivationBatch::from_rows(&layer)
                    })
                    .collect(),
            ),
        }
    }

    /// Split a batched state back into per-session states (inverse of
    /// [`Self::gather_states`]).
    pub fn scatter_states(&self, state: &LmStateBatch) -> Vec<LmState> {
        let batch = state.batch();
        (0..batch)
            .map(|b| match state {
                LmStateBatch::Lstm(layers) => {
                    LmState::Lstm(layers.iter().map(|l| l.state(b)).collect())
                }
                LmStateBatch::Gru(layers) => {
                    LmState::Gru(layers.iter().map(|l| l.row(b).to_vec()).collect())
                }
            })
            .collect()
    }

    /// One batched inference step: consume one token per session, update the
    /// batched `state`, and return a `batch × vocab` logit matrix. Each
    /// weight matrix is swept **once for the whole batch** (Fig. 3 right);
    /// results bit-match `batch` independent [`Self::step`] calls.
    pub fn step_batch(&self, tokens: &[usize], state: &mut LmStateBatch) -> OutputBatch {
        self.step_batch_exec(tokens, state, &Exec::serial())
    }

    /// [`Self::step_batch`] on an execution engine: the gate products of
    /// every cell and the softmax GEMM are row-sharded across `exec`'s
    /// workers. Bit-exact vs the serial [`Self::step_batch`] (and hence vs
    /// per-session [`Self::step`]) for any thread count — the worker pool
    /// is invisible to clients.
    pub fn step_batch_exec(
        &self,
        tokens: &[usize],
        state: &mut LmStateBatch,
        exec: &Exec,
    ) -> OutputBatch {
        let batch = tokens.len();
        assert!(batch > 0, "empty token batch");
        assert_eq!(batch, state.batch(), "token/state batch mismatch");
        let (mut x, x_prequant): (Option<ActivationBatch>, Option<QuantizedBatch>) =
            match self.embedding.lookup_batch(tokens) {
                EmbeddedBatch::Dense(a) => (Some(a), None),
                EmbeddedBatch::Quant(q) => (None, Some(q)),
            };
        for (l, cell) in self.cells.iter().enumerate() {
            match (cell, &mut *state) {
                (Cell::Lstm(c), LmStateBatch::Lstm(states)) => {
                    let s = match (&x, &x_prequant) {
                        (None, Some(q)) if l == 0 => c.step_batch_prequant_exec(q, &states[l], exec),
                        _ => c.step_batch_exec(x.as_ref().expect("dense input"), &states[l], exec),
                    };
                    x = Some(s.h.clone());
                    states[l] = s;
                }
                (Cell::Gru(c), LmStateBatch::Gru(states)) => {
                    let s = match (&x, &x_prequant) {
                        (None, Some(q)) if l == 0 => c.step_batch_prequant_exec(q, &states[l], exec),
                        _ => c.step_batch_exec(x.as_ref().expect("dense input"), &states[l], exec),
                    };
                    x = Some(s.clone());
                    states[l] = s;
                }
                _ => unreachable!("state kind matches cell kind by construction"),
            }
        }
        let top = x.expect("at least one layer");
        let mut logits = OutputBatch::zeros(batch, self.config.vocab);
        self.softmax.forward_exec(&top, &mut logits, exec);
        for b in 0..batch {
            for (l, &bias) in logits.row_mut(b).iter_mut().zip(&self.softmax_bias) {
                *l += bias;
            }
        }
        logits
    }

    /// One inference step: consume `token`, update `state`, return logits
    /// over the vocabulary.
    pub fn step(&self, token: usize, state: &mut LmState) -> Vec<f32> {
        let emb = self.embedding.lookup(token);
        let mut x: Vec<f32> = Vec::new();
        let mut x_prequant: Option<crate::quant::Quantized> = None;
        match emb {
            Embedded::Dense(v) => x = v,
            Embedded::Quant(q) => x_prequant = Some(q),
        }
        for (l, cell) in self.cells.iter().enumerate() {
            match (cell, &mut *state) {
                (Cell::Lstm(c), LmState::Lstm(states)) => {
                    let s = if l == 0 {
                        if let Some(q) = &x_prequant {
                            c.step_prequant(q, &states[l])
                        } else {
                            c.step(&x, &states[l])
                        }
                    } else {
                        c.step(&x, &states[l])
                    };
                    x = s.h.clone();
                    states[l] = s;
                }
                (Cell::Gru(c), LmState::Gru(states)) => {
                    let s = if l == 0 {
                        if let Some(q) = &x_prequant {
                            c.step_prequant(q, &states[l])
                        } else {
                            c.step(&x, &states[l])
                        }
                    } else {
                        c.step(&x, &states[l])
                    };
                    x = s.clone();
                    states[l] = s;
                }
                _ => unreachable!("state kind matches cell kind by construction"),
            }
        }
        let mut logits = self.softmax_bias.clone();
        let mut y = vec![0.0f32; self.config.vocab];
        self.softmax.matvec(&x, &mut y);
        for (l, v) in logits.iter_mut().zip(&y) {
            *l += v;
        }
        logits
    }

    /// Perplexity per word over a token stream (the paper's metric):
    /// `exp( −1/(N−1) Σ log p(tokenᵢ₊₁ | …) )`.
    pub fn ppw(&self, tokens: &[usize]) -> f64 {
        assert!(tokens.len() >= 2, "need at least two tokens");
        let mut state = self.zero_state();
        let mut nll = 0.0f64;
        for i in 0..tokens.len() - 1 {
            let logits = self.step(tokens[i], &mut state);
            nll -= log_softmax_at(&logits, tokens[i + 1]) as f64;
        }
        (nll / (tokens.len() - 1) as f64).exp()
    }

    /// Total weight bytes (the memory-saving claims of the abstract).
    pub fn bytes(&self) -> usize {
        let cell_bytes: usize = self
            .cells
            .iter()
            .map(|c| match c {
                Cell::Lstm(c) => c.bytes(),
                Cell::Gru(c) => c.bytes(),
            })
            .sum();
        self.embedding.bytes() + cell_bytes + self.softmax.bytes() + self.softmax_bias.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(kind: RnnKind) -> LmConfig {
        LmConfig { kind, vocab: 50, hidden: 32, layers: 1 }
    }

    #[test]
    fn step_produces_vocab_logits() {
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            let lm = RnnLm::random(tiny(kind), 1, PrecisionPolicy::full());
            let mut st = lm.zero_state();
            let logits = lm.step(3, &mut st);
            assert_eq!(logits.len(), 50);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn state_evolves() {
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 2, PrecisionPolicy::full());
        let mut st = lm.zero_state();
        lm.step(1, &mut st);
        assert_ne!(st, lm.zero_state());
    }

    #[test]
    fn random_model_ppw_near_vocab_size() {
        // An untrained model is ~uniform ⇒ PPW ≈ |V|.
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 3, PrecisionPolicy::full());
        let tokens: Vec<usize> = (0..300).map(|i| (i * 7) % 50).collect();
        let ppw = lm.ppw(&tokens);
        assert!((25.0..100.0).contains(&ppw), "ppw={ppw}");
    }

    #[test]
    fn step_batch_bitmatches_step_per_session() {
        // The whole-model batching contract: embedding (incl. prequant rows),
        // both cells, and the softmax head are exact under batching.
        for kind in [RnnKind::Lstm, RnnKind::Gru] {
            for policy in [PrecisionPolicy::full(), PrecisionPolicy::quantized(2, 2)] {
                let lm = RnnLm::random(tiny(kind), 11, policy);
                for batch in 1..=4 {
                    let mut singles: Vec<LmState> =
                        (0..batch).map(|_| lm.zero_state()).collect();
                    let mut batched = lm.zero_state_batch(batch);
                    for round in 0..3 {
                        let tokens: Vec<usize> =
                            (0..batch).map(|b| (7 * b + 13 * round + 1) % 50).collect();
                        let logits = lm.step_batch(&tokens, &mut batched);
                        for b in 0..batch {
                            let expect = lm.step(tokens[b], &mut singles[b]);
                            assert_eq!(
                                logits.row(b),
                                &expect[..],
                                "{kind:?} batch={batch} round={round} col={b}"
                            );
                        }
                        let scattered = lm.scatter_states(&batched);
                        assert_eq!(scattered, singles, "{kind:?} batch={batch} round={round}");
                    }
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let lm = RnnLm::random(tiny(RnnKind::Lstm), 12, PrecisionPolicy::full());
        let mut singles: Vec<LmState> = (0..3).map(|_| lm.zero_state()).collect();
        for (i, s) in singles.iter_mut().enumerate() {
            lm.step(i + 1, s);
        }
        let refs: Vec<&LmState> = singles.iter().collect();
        let gathered = lm.gather_states(&refs);
        assert_eq!(lm.scatter_states(&gathered), singles);
    }

    #[test]
    fn quantized_model_is_much_smaller_and_close_in_ppw() {
        let config = tiny(RnnKind::Gru);
        let mut rng = Rng::new(4);
        let w = LmWeights::random(&config, &mut rng);
        let fp = RnnLm::from_weights(config, &w, PrecisionPolicy::full());
        let q3 = RnnLm::from_weights(config, &w, PrecisionPolicy::quantized(3, 3));
        // At this toy size packing overhead dims the ratio; the realistic
        // ~10.5× (3-bit) figure is asserted in quant::matrix at 4096×1024.
        assert!(q3.bytes() * 3 < fp.bytes(), "{} vs {}", q3.bytes(), fp.bytes());
        let tokens: Vec<usize> = (0..200).map(|i| (i * 13 + 5) % 50).collect();
        let (p_fp, p_q) = (fp.ppw(&tokens), q3.ppw(&tokens));
        let rel = (p_q - p_fp).abs() / p_fp;
        assert!(rel < 0.25, "fp={p_fp} q={p_q}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = RnnLm::random(tiny(RnnKind::Lstm), 7, PrecisionPolicy::full());
        let b = RnnLm::random(tiny(RnnKind::Lstm), 7, PrecisionPolicy::full());
        let t: Vec<usize> = (0..50).map(|i| i % 50).collect();
        assert_eq!(a.ppw(&t), b.ppw(&t));
    }
}
