//! Token embedding table, optionally row-quantized.
//!
//! Paper §4: because inputs are one-hot, `x_t = W_eᵀ y*_{t−1}` is a row
//! lookup — when `W_e` is row-quantized the looked-up row is *already* in
//! multi-bit form, so it feeds the quantized gate products with **no online
//! quantization cost**.

use super::batch::ActivationBatch;
use crate::exec::Exec;
use crate::quant::{Method, Quantized, QuantizedBatch, RowQuantized};

/// Embedding lookup result: dense, or a ready-made multi-bit activation.
pub enum Embedded {
    Dense(Vec<f32>),
    Quant(Quantized),
}

impl Embedded {
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Embedded::Dense(v) => v.clone(),
            Embedded::Quant(q) => q.dequantize(),
        }
    }
}

/// Batched lookup result: a dense activation batch, or the looked-up rows
/// repacked as a [`QuantizedBatch`] that feeds the gate products with zero
/// online quantization cost (§4).
pub enum EmbeddedBatch {
    Dense(ActivationBatch),
    Quant(QuantizedBatch),
}

/// Reusable batched-lookup buffer: holds whichever variant the table
/// produces without reallocating across timesteps (the embedding leg of
/// the serving workspaces). Fill with [`Embedding::lookup_batch_into`],
/// read through [`Self::view`].
#[derive(Default)]
pub struct EmbeddedBatchBuf {
    dense: ActivationBatch,
    quant: QuantizedBatch,
    is_quant: bool,
}

/// Borrowed view of a batched lookup result held in an [`EmbeddedBatchBuf`].
pub enum EmbeddedBatchView<'a> {
    Dense(&'a ActivationBatch),
    Quant(&'a QuantizedBatch),
}

impl EmbeddedBatchBuf {
    /// The variant the last [`Embedding::lookup_batch_into`] produced.
    pub fn view(&self) -> EmbeddedBatchView<'_> {
        if self.is_quant {
            EmbeddedBatchView::Quant(&self.quant)
        } else {
            EmbeddedBatchView::Dense(&self.dense)
        }
    }
}

/// `vocab × dim` embedding table.
#[derive(Clone, Debug)]
pub enum Embedding {
    Dense { w: Vec<f32>, vocab: usize, dim: usize },
    Quant { w: RowQuantized },
}

impl Embedding {
    pub fn new_dense(w: Vec<f32>, vocab: usize, dim: usize) -> Self {
        assert_eq!(w.len(), vocab * dim);
        Embedding::Dense { w, vocab, dim }
    }

    /// Quantize each embedding row to `k` bits with the alternating method.
    pub fn new_quantized(w: Vec<f32>, vocab: usize, dim: usize, k: usize) -> Self {
        Self::new_quantized_exec(w, vocab, dim, k, &Exec::serial())
    }

    /// [`Self::new_quantized`] with the per-row quantization sharded across
    /// `exec`'s workers (bit-identical table for any thread count).
    pub fn new_quantized_exec(w: Vec<f32>, vocab: usize, dim: usize, k: usize, exec: &Exec) -> Self {
        assert_eq!(w.len(), vocab * dim);
        Embedding::Quant {
            w: RowQuantized::quantize_exec(&w, vocab, dim, k, Method::Alternating { t: 2 }, exec),
        }
    }

    pub fn vocab(&self) -> usize {
        match self {
            Embedding::Dense { vocab, .. } => *vocab,
            Embedding::Quant { w } => w.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Embedding::Dense { dim, .. } => *dim,
            Embedding::Quant { w } => w.cols,
        }
    }

    /// Row lookup for token `id`.
    pub fn lookup(&self, id: usize) -> Embedded {
        match self {
            Embedding::Dense { w, dim, vocab } => {
                assert!(id < *vocab, "token {id} out of vocab {vocab}");
                Embedded::Dense(w[id * dim..(id + 1) * dim].to_vec())
            }
            Embedding::Quant { w } => {
                assert!(id < w.rows, "token {id} out of vocab {}", w.rows);
                Embedded::Quant(w.row(id))
            }
        }
    }

    /// Row lookup for a whole token batch. Quantized tables hand back the
    /// packed rows directly (bit-identical to per-token [`Self::lookup`]).
    /// A thin wrapper over [`Self::lookup_batch_into`] (one code path).
    pub fn lookup_batch(&self, ids: &[usize]) -> EmbeddedBatch {
        let mut buf = EmbeddedBatchBuf::default();
        self.lookup_batch_into(ids, &mut buf);
        if buf.is_quant {
            EmbeddedBatch::Quant(buf.quant)
        } else {
            EmbeddedBatch::Dense(buf.dense)
        }
    }

    /// [`Self::lookup_batch`] into a reused buffer — bit-identical rows,
    /// zero steady-state heap allocation (both variants reuse capacity).
    pub fn lookup_batch_into(&self, ids: &[usize], out: &mut EmbeddedBatchBuf) {
        assert!(!ids.is_empty(), "empty token batch");
        match self {
            Embedding::Dense { w, dim, vocab } => {
                out.dense.reset(ids.len(), *dim);
                for (b, &id) in ids.iter().enumerate() {
                    assert!(id < *vocab, "token {id} out of vocab {vocab}");
                    out.dense.row_mut(b).copy_from_slice(&w[id * dim..(id + 1) * dim]);
                }
                out.is_quant = false;
            }
            Embedding::Quant { w } => {
                for &id in ids {
                    assert!(id < w.rows, "token {id} out of vocab {}", w.rows);
                }
                out.quant.gather_rows_into(w, ids);
                out.is_quant = true;
            }
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Embedding::Dense { w, .. } => w.len() * 4,
            Embedding::Quant { w } => w.packed_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_lookup_returns_row() {
        let w: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let e = Embedding::new_dense(w, 4, 3);
        assert_eq!(e.lookup(2).to_dense(), vec![6.0, 7.0, 8.0]);
    }

    #[test]
    fn quantized_lookup_matches_row_quantization() {
        let mut rng = Rng::new(121);
        let (v, d) = (10, 32);
        let w = rng.normal_vec(v * d, 0.5);
        let e = Embedding::new_quantized(w.clone(), v, d, 2);
        let rq = RowQuantized::quantize(&w, v, d, 2, Method::Alternating { t: 2 });
        for id in 0..v {
            assert_eq!(e.lookup(id).to_dense(), rq.row(id).dequantize());
        }
    }

    #[test]
    fn batch_lookup_matches_single() {
        let mut rng = Rng::new(122);
        let (v, d) = (12, 48);
        let w = rng.normal_vec(v * d, 0.5);
        let ids = [3usize, 0, 3, 11];
        // Dense table.
        let e = Embedding::new_dense(w.clone(), v, d);
        match e.lookup_batch(&ids) {
            EmbeddedBatch::Dense(a) => {
                for (b, &id) in ids.iter().enumerate() {
                    assert_eq!(a.row(b), &e.lookup(id).to_dense()[..]);
                }
            }
            _ => panic!("dense table must return a dense batch"),
        }
        // Quantized table: packed rows bit-match the single lookups.
        let eq = Embedding::new_quantized(w, v, d, 2);
        match eq.lookup_batch(&ids) {
            EmbeddedBatch::Quant(qb) => {
                for (b, &id) in ids.iter().enumerate() {
                    match eq.lookup(id) {
                        Embedded::Quant(q) => {
                            assert_eq!(qb.column(b).alphas, q.alphas);
                            assert_eq!(qb.column(b).planes, q.planes);
                        }
                        _ => unreachable!(),
                    }
                }
            }
            _ => panic!("quantized table must return a quantized batch"),
        }
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn oov_panics() {
        let e = Embedding::new_dense(vec![0.0; 6], 2, 3);
        e.lookup(2);
    }
}
