//! Token embedding table, optionally row-quantized.
//!
//! Paper §4: because inputs are one-hot, `x_t = W_eᵀ y*_{t−1}` is a row
//! lookup — when `W_e` is row-quantized the looked-up row is *already* in
//! multi-bit form, so it feeds the quantized gate products with **no online
//! quantization cost**.

use crate::quant::{Method, Quantized, RowQuantized};

/// Embedding lookup result: dense, or a ready-made multi-bit activation.
pub enum Embedded {
    Dense(Vec<f32>),
    Quant(Quantized),
}

impl Embedded {
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Embedded::Dense(v) => v.clone(),
            Embedded::Quant(q) => q.dequantize(),
        }
    }
}

/// `vocab × dim` embedding table.
#[derive(Clone, Debug)]
pub enum Embedding {
    Dense { w: Vec<f32>, vocab: usize, dim: usize },
    Quant { w: RowQuantized },
}

impl Embedding {
    pub fn new_dense(w: Vec<f32>, vocab: usize, dim: usize) -> Self {
        assert_eq!(w.len(), vocab * dim);
        Embedding::Dense { w, vocab, dim }
    }

    /// Quantize each embedding row to `k` bits with the alternating method.
    pub fn new_quantized(w: Vec<f32>, vocab: usize, dim: usize, k: usize) -> Self {
        assert_eq!(w.len(), vocab * dim);
        Embedding::Quant {
            w: RowQuantized::quantize(&w, vocab, dim, k, Method::Alternating { t: 2 }),
        }
    }

    pub fn vocab(&self) -> usize {
        match self {
            Embedding::Dense { vocab, .. } => *vocab,
            Embedding::Quant { w } => w.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Embedding::Dense { dim, .. } => *dim,
            Embedding::Quant { w } => w.cols,
        }
    }

    /// Row lookup for token `id`.
    pub fn lookup(&self, id: usize) -> Embedded {
        match self {
            Embedding::Dense { w, dim, vocab } => {
                assert!(id < *vocab, "token {id} out of vocab {vocab}");
                Embedded::Dense(w[id * dim..(id + 1) * dim].to_vec())
            }
            Embedding::Quant { w } => {
                assert!(id < w.rows, "token {id} out of vocab {}", w.rows);
                Embedded::Quant(w.row(id))
            }
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Embedding::Dense { w, .. } => w.len() * 4,
            Embedding::Quant { w } => w.packed_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_lookup_returns_row() {
        let w: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let e = Embedding::new_dense(w, 4, 3);
        assert_eq!(e.lookup(2).to_dense(), vec![6.0, 7.0, 8.0]);
    }

    #[test]
    fn quantized_lookup_matches_row_quantization() {
        let mut rng = Rng::new(121);
        let (v, d) = (10, 32);
        let w = rng.normal_vec(v * d, 0.5);
        let e = Embedding::new_quantized(w.clone(), v, d, 2);
        let rq = RowQuantized::quantize(&w, v, d, 2, Method::Alternating { t: 2 });
        for id in 0..v {
            assert_eq!(e.lookup(id).to_dense(), rq.row(id).dequantize());
        }
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn oov_panics() {
        let e = Embedding::new_dense(vec![0.0; 6], 2, 3);
        e.lookup(2);
    }
}
