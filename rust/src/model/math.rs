//! Elementwise math shared by the cells and heads.

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[inline]
pub fn dsigmoid(y: f32) -> f32 {
    // derivative in terms of the output y = σ(x)
    y * (1.0 - y)
}

#[inline]
pub fn dtanh(y: f32) -> f32 {
    // derivative in terms of the output y = tanh(x)
    1.0 - y * y
}

/// In-place softmax with max-subtraction.
pub fn softmax(x: &mut [f32]) {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// `log(softmax(x)[target])` without materializing the softmax — the
/// negative of the per-token cross-entropy used for PPW.
pub fn log_softmax_at(x: &[f32], target: usize) -> f32 {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f32 = x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
    x[target] - lse
}

/// Argmax index (first on ties).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in x.iter().enumerate().skip(1) {
        if v > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(30.0) > 0.999_999);
        assert!(sigmoid(-30.0) < 1e-6);
        // Stability at extremes.
        assert!(sigmoid(-1e4).is_finite() && sigmoid(1e4).is_finite());
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 1000.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(x[3] > 0.99);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let x = vec![0.3f32, -1.2, 2.0, 0.0];
        let mut s = x.clone();
        softmax(&mut s);
        for t in 0..x.len() {
            assert!((log_softmax_at(&x, t) - s[t].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
