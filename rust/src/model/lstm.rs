//! LSTM cell (Hochreiter & Schmidhuber 1997), the paper's Eq. 6, with the
//! two gate products `W_i x_t` and `W_h h_{t−1}` as swappable [`Linear`]s —
//! quantizing those two matrices (plus the softmax and embedding) is
//! exactly where the paper applies its method.
//!
//! Gate layout follows the paper's order `[i, f, o, g]` stacked along rows:
//! `W_x ∈ R^{4h×in}`, `W_h ∈ R^{4h×h}`.

use super::batch::{ActivationBatch, OutputBatch};
use super::linear::{Linear, LinearOp, LinearWorkspace, Precision};
use super::math::{sigmoid, dtanh};
use crate::exec::Exec;
use crate::quant::QuantizedBatch;
use crate::util::Rng;

/// LSTM recurrent state.
#[derive(Clone, Debug, PartialEq)]
pub struct LstmState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

impl LstmState {
    pub fn zeros(hidden: usize) -> Self {
        LstmState { h: vec![0.0; hidden], c: vec![0.0; hidden] }
    }
}

/// LSTM state for a batch of `B` independent sequences; `h` doubles as the
/// next step's recurrent [`ActivationBatch`].
#[derive(Clone, Debug, PartialEq)]
pub struct LstmStateBatch {
    pub batch: usize,
    pub hidden: usize,
    pub h: ActivationBatch,
    /// Cell states, row-major `batch × hidden` (never fed to a linear).
    pub c: Vec<f32>,
}

impl LstmStateBatch {
    pub fn zeros(batch: usize, hidden: usize) -> Self {
        LstmStateBatch {
            batch,
            hidden,
            h: ActivationBatch::zeros(batch, hidden),
            c: vec![0.0; batch * hidden],
        }
    }

    /// Gather per-session states into one batch (the server's scatter/gather
    /// boundary).
    pub fn from_states(states: &[&LstmState]) -> Self {
        assert!(!states.is_empty(), "empty batch");
        let hidden = states[0].h.len();
        let hs: Vec<&[f32]> = states
            .iter()
            .map(|s| {
                assert_eq!(s.h.len(), hidden, "state dimension mismatch");
                assert_eq!(s.c.len(), hidden, "state dimension mismatch");
                s.h.as_slice()
            })
            .collect();
        let mut c = Vec::with_capacity(states.len() * hidden);
        for s in states {
            c.extend_from_slice(&s.c);
        }
        LstmStateBatch { batch: states.len(), hidden, h: ActivationBatch::from_rows(&hs), c }
    }

    /// Column `b` as a standalone per-session state.
    pub fn state(&self, b: usize) -> LstmState {
        LstmState {
            h: self.h.row(b).to_vec(),
            c: self.c[b * self.hidden..(b + 1) * self.hidden].to_vec(),
        }
    }

    /// Append one session's `(h, c)` as a new batch column — the continuous
    /// batcher's slot-join primitive. O(hidden); allocation-free once the
    /// buffers are at their high-water capacity.
    pub fn push_state(&mut self, s: &LstmState) {
        if self.batch == 0 {
            self.hidden = s.h.len();
        }
        assert_eq!(s.h.len(), self.hidden, "state dimension mismatch");
        assert_eq!(s.c.len(), self.hidden, "state dimension mismatch");
        self.h.push_row(&s.h);
        self.c.extend_from_slice(&s.c);
        self.batch += 1;
    }

    /// Free column `b` by moving the **last** column into its place — the
    /// continuous batcher's slot-free primitive. Extract the column first
    /// (e.g. [`Self::state`]) if its values are still needed.
    pub fn swap_remove(&mut self, b: usize) {
        assert!(b < self.batch, "column index out of range");
        self.h.swap_remove_row(b);
        let last = self.batch - 1;
        let h = self.hidden;
        if b != last {
            let (head, tail) = self.c.split_at_mut(last * h);
            head[b * h..(b + 1) * h].copy_from_slice(&tail[..h]);
        }
        self.c.truncate(last * h);
        self.batch = last;
    }

    /// Reshape in place to an all-zero `batch × hidden` state (capacity
    /// kept — the double-buffer primitive of the `_into` step path).
    pub fn reset(&mut self, batch: usize, hidden: usize) {
        self.batch = batch;
        self.hidden = hidden;
        self.h.reset(batch, hidden);
        self.c.clear();
        self.c.resize(batch * hidden, 0.0);
    }
}

impl Default for LstmStateBatch {
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

/// Reusable scratch for one batched LSTM step: the two gate-product output
/// buffers and one [`LinearWorkspace`] per gate product. One instance
/// serves any batch size — buffers grow to the high-water mark and are
/// reused, so a warmed steady-state [`LstmCell::step_batch_into_exec`]
/// performs zero heap allocations on the serial engine.
#[derive(Default)]
pub struct LstmStepWorkspace {
    gx: OutputBatch,
    gh: OutputBatch,
    wx_ws: LinearWorkspace,
    wh_ws: LinearWorkspace,
}

/// One LSTM layer.
pub struct LstmCell {
    pub wx: Linear, // 4h × in
    pub wh: Linear, // 4h × h
    pub bias: Vec<f32>, // 4h
    pub hidden: usize,
    pub input: usize,
}

impl LstmCell {
    /// Random initialization in `U(-scale, scale)` (the standard LM init).
    pub fn init(input: usize, hidden: usize, scale: f32, rng: &mut Rng, precision: Precision) -> Self {
        let wx: Vec<f32> = (0..4 * hidden * input).map(|_| rng.range_f32(-scale, scale)).collect();
        let wh: Vec<f32> = (0..4 * hidden * hidden).map(|_| rng.range_f32(-scale, scale)).collect();
        LstmCell {
            wx: Linear::new(wx, 4 * hidden, input, precision),
            wh: Linear::new(wh, 4 * hidden, hidden, precision),
            bias: vec![0.0; 4 * hidden],
            hidden,
            input,
        }
    }

    /// Build from dense weights (e.g. loaded from a Layer-2 checkpoint).
    pub fn from_dense(
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        input: usize,
        hidden: usize,
        precision: Precision,
    ) -> Self {
        Self::from_dense_exec(wx, wh, bias, input, hidden, precision, &Exec::serial())
    }

    /// [`Self::from_dense`] with the per-row weight quantization sharded
    /// across `exec`'s workers (bit-identical cell for any thread count).
    pub fn from_dense_exec(
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        input: usize,
        hidden: usize,
        precision: Precision,
        exec: &Exec,
    ) -> Self {
        assert_eq!(wx.len(), 4 * hidden * input);
        assert_eq!(wh.len(), 4 * hidden * hidden);
        assert_eq!(bias.len(), 4 * hidden);
        LstmCell {
            wx: Linear::new_exec(wx, 4 * hidden, input, precision, exec),
            wh: Linear::new_exec(wh, 4 * hidden, hidden, precision, exec),
            bias,
            hidden,
            input,
        }
    }

    /// One step: gates `i,f,o,g`; `c' = f⊙c + i⊙g`, `h' = o⊙tanh(c')`.
    pub fn step(&self, x: &[f32], state: &LstmState) -> LstmState {
        let h4 = 4 * self.hidden;
        let mut gx = vec![0.0f32; h4];
        let mut gh = vec![0.0f32; h4];
        self.wx.matvec(x, &mut gx);
        self.wh.matvec(&state.h, &mut gh);
        self.combine(&gx, &gh, state)
    }

    /// One step with a pre-quantized input activation (embedding rows are
    /// already multi-bit; see [`super::embedding`]).
    pub fn step_prequant(&self, xq: &crate::quant::Quantized, state: &LstmState) -> LstmState {
        let h4 = 4 * self.hidden;
        let mut gx = vec![0.0f32; h4];
        let mut gh = vec![0.0f32; h4];
        self.wx.matvec_prequant(xq, &mut gx);
        self.wh.matvec(&state.h, &mut gh);
        self.combine(&gx, &gh, state)
    }

    /// One step for a batch of `B` sequences: both gate products run as one
    /// batched forward each (the weight planes are swept once per batch).
    /// Bit-matches `B` independent [`Self::step`] calls column by column.
    pub fn step_batch(&self, x: &ActivationBatch, state: &LstmStateBatch) -> LstmStateBatch {
        self.step_batch_exec(x, state, &Exec::serial())
    }

    /// [`Self::step_batch`] on an execution engine: the `W_x` and `W_h`
    /// gate products run as two independent pooled tasks, and each one
    /// row-shards its GEMM across the same workers (nested scopes). The
    /// result is bit-exact vs [`Self::step_batch`] for any thread count.
    /// A thin wrapper over [`Self::step_batch_into_exec`] with fresh
    /// buffers (one code path).
    pub fn step_batch_exec(
        &self,
        x: &ActivationBatch,
        state: &LstmStateBatch,
        exec: &Exec,
    ) -> LstmStateBatch {
        let mut out = LstmStateBatch::default();
        self.step_batch_into_exec(x, state, &mut out, exec, &mut LstmStepWorkspace::default());
        out
    }

    /// [`Self::step_batch_exec`] into caller-owned state and workspace
    /// buffers: the next state is written into `out` (resized in place —
    /// `out` must not alias `state`: keep two state buffers and swap them
    /// between steps) and every intermediate lives in `ws`, reused across
    /// steps. Bit-identical to [`Self::step_batch_exec`]; once warm, a
    /// steady-state call performs zero heap allocations on the serial
    /// engine (`rust/tests/workspace_parity.rs`).
    pub fn step_batch_into_exec(
        &self,
        x: &ActivationBatch,
        state: &LstmStateBatch,
        out: &mut LstmStateBatch,
        exec: &Exec,
        ws: &mut LstmStepWorkspace,
    ) {
        assert_eq!(x.batch(), state.batch, "batch mismatch");
        let LstmStepWorkspace { gx, gh, wx_ws, wh_ws } = ws;
        exec.join(
            || self.wx.forward_into_exec(x, &mut *gx, exec, &mut *wx_ws),
            || self.wh.forward_into_exec(&state.h, &mut *gh, exec, &mut *wh_ws),
        );
        self.combine_batch_into(gx, gh, state, out);
    }

    /// Batched step from pre-quantized inputs (a quantized embedding's token
    /// batch).
    pub fn step_batch_prequant(&self, xq: &QuantizedBatch, state: &LstmStateBatch) -> LstmStateBatch {
        self.step_batch_prequant_exec(xq, state, &Exec::serial())
    }

    /// [`Self::step_batch_prequant`] on an execution engine (see
    /// [`Self::step_batch_exec`]).
    pub fn step_batch_prequant_exec(
        &self,
        xq: &QuantizedBatch,
        state: &LstmStateBatch,
        exec: &Exec,
    ) -> LstmStateBatch {
        let mut out = LstmStateBatch::default();
        let mut ws = LstmStepWorkspace::default();
        self.step_batch_prequant_into_exec(xq, state, &mut out, exec, &mut ws);
        out
    }

    /// [`Self::step_batch_prequant_exec`] into caller-owned buffers (see
    /// [`Self::step_batch_into_exec`] for the double-buffer contract).
    pub fn step_batch_prequant_into_exec(
        &self,
        xq: &QuantizedBatch,
        state: &LstmStateBatch,
        out: &mut LstmStateBatch,
        exec: &Exec,
        ws: &mut LstmStepWorkspace,
    ) {
        assert_eq!(xq.batch, state.batch, "batch mismatch");
        let LstmStepWorkspace { gx, gh, wx_ws, wh_ws } = ws;
        exec.join(
            || self.wx.forward_prequant_into_exec(xq, &mut *gx, exec, &mut *wx_ws),
            || self.wh.forward_into_exec(&state.h, &mut *gh, exec, &mut *wh_ws),
        );
        self.combine_batch_into(gx, gh, state, out);
    }

    fn combine(&self, gx: &[f32], gh: &[f32], state: &LstmState) -> LstmState {
        let mut out = LstmState::zeros(self.hidden);
        combine_row(self.hidden, &self.bias, gx, gh, &state.c, &mut out.h, &mut out.c);
        out
    }

    fn combine_batch_into(
        &self,
        gx: &OutputBatch,
        gh: &OutputBatch,
        state: &LstmStateBatch,
        out: &mut LstmStateBatch,
    ) {
        let h = self.hidden;
        out.reset(state.batch, h);
        for b in 0..state.batch {
            combine_row(
                h,
                &self.bias,
                gx.row(b),
                gh.row(b),
                &state.c[b * h..(b + 1) * h],
                out.h.row_mut(b),
                &mut out.c[b * h..(b + 1) * h],
            );
        }
    }

    pub fn bytes(&self) -> usize {
        self.wx.bytes() + self.wh.bytes() + self.bias.len() * 4
    }
}

/// The scalar gate math of one LSTM step for one sequence — shared by the
/// single and batched paths so they are bit-identical by construction.
fn combine_row(
    h: usize,
    bias: &[f32],
    gx: &[f32],
    gh: &[f32],
    prev_c: &[f32],
    out_h: &mut [f32],
    out_c: &mut [f32],
) {
    for j in 0..h {
        let pre_i = gx[j] + gh[j] + bias[j];
        let pre_f = gx[h + j] + gh[h + j] + bias[h + j];
        let pre_o = gx[2 * h + j] + gh[2 * h + j] + bias[2 * h + j];
        let pre_g = gx[3 * h + j] + gh[3 * h + j] + bias[3 * h + j];
        let i = sigmoid(pre_i);
        let f = sigmoid(pre_f);
        let o = sigmoid(pre_o);
        let g = pre_g.tanh();
        let c = f * prev_c[j] + i * g;
        out_c[j] = c;
        out_h[j] = o * c.tanh();
    }
}

/// Gradient-friendly dense LSTM step used by the native trainers
/// (sequential-MNIST, Table 7): returns intermediate activations for BPTT.
pub struct LstmTape {
    pub i: Vec<f32>,
    pub f: Vec<f32>,
    pub o: Vec<f32>,
    pub g: Vec<f32>,
    pub c: Vec<f32>,
    pub tanh_c: Vec<f32>,
    pub h: Vec<f32>,
}

/// Dense forward with tape (weights given as raw slices, layout as above).
pub fn step_dense_tape(
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    input: usize,
    hidden: usize,
    x: &[f32],
    prev_h: &[f32],
    prev_c: &[f32],
) -> LstmTape {
    let h4 = 4 * hidden;
    let mut pre = bias.to_vec();
    for r in 0..h4 {
        let mut s = 0.0f32;
        let row = &wx[r * input..(r + 1) * input];
        for (a, b) in row.iter().zip(x) {
            s += a * b;
        }
        let rowh = &wh[r * hidden..(r + 1) * hidden];
        for (a, b) in rowh.iter().zip(prev_h) {
            s += a * b;
        }
        pre[r] += s;
    }
    let mut t = LstmTape {
        i: vec![0.0; hidden],
        f: vec![0.0; hidden],
        o: vec![0.0; hidden],
        g: vec![0.0; hidden],
        c: vec![0.0; hidden],
        tanh_c: vec![0.0; hidden],
        h: vec![0.0; hidden],
    };
    for j in 0..hidden {
        t.i[j] = sigmoid(pre[j]);
        t.f[j] = sigmoid(pre[hidden + j]);
        t.o[j] = sigmoid(pre[2 * hidden + j]);
        t.g[j] = pre[3 * hidden + j].tanh();
        t.c[j] = t.f[j] * prev_c[j] + t.i[j] * t.g[j];
        t.tanh_c[j] = t.c[j].tanh();
        t.h[j] = t.o[j] * t.tanh_c[j];
    }
    t
}

/// Backward through one dense step; accumulates weight grads and returns
/// `(dx, dh_prev, dc_prev)`.
#[allow(clippy::too_many_arguments)]
pub fn step_dense_backward(
    wx: &[f32],
    wh: &[f32],
    input: usize,
    hidden: usize,
    x: &[f32],
    prev_h: &[f32],
    prev_c: &[f32],
    tape: &LstmTape,
    dh: &[f32],
    dc_in: &[f32],
    gwx: &mut [f32],
    gwh: &mut [f32],
    gbias: &mut [f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dpre = vec![0.0f32; 4 * hidden];
    let mut dc_prev = vec![0.0f32; hidden];
    for j in 0..hidden {
        let dho = dh[j];
        let dc = dc_in[j] + dho * tape.o[j] * dtanh(tape.tanh_c[j]);
        let do_ = dho * tape.tanh_c[j];
        let di = dc * tape.g[j];
        let dg = dc * tape.i[j];
        let df = dc * prev_c[j];
        dc_prev[j] = dc * tape.f[j];
        dpre[j] = di * super::math::dsigmoid(tape.i[j]);
        dpre[hidden + j] = df * super::math::dsigmoid(tape.f[j]);
        dpre[2 * hidden + j] = do_ * super::math::dsigmoid(tape.o[j]);
        dpre[3 * hidden + j] = dg * dtanh(tape.g[j]);
    }
    let mut dx = vec![0.0f32; input];
    let mut dh_prev = vec![0.0f32; hidden];
    for r in 0..4 * hidden {
        let d = dpre[r];
        if d == 0.0 {
            continue;
        }
        gbias[r] += d;
        let rowx = &wx[r * input..(r + 1) * input];
        let growx = &mut gwx[r * input..(r + 1) * input];
        for c in 0..input {
            growx[c] += d * x[c];
            dx[c] += d * rowx[c];
        }
        let rowh = &wh[r * hidden..(r + 1) * hidden];
        let growh = &mut gwh[r * hidden..(r + 1) * hidden];
        for c in 0..hidden {
            growh[c] += d * prev_h[c];
            dh_prev[c] += d * rowh[c];
        }
    }
    (dx, dh_prev, dc_prev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::Precision;

    fn cell(precision: Precision, seed: u64) -> LstmCell {
        let mut rng = Rng::new(seed);
        LstmCell::init(8, 16, 0.4, &mut rng, precision)
    }

    #[test]
    fn step_shapes_and_bounds() {
        let c = cell(Precision::Full, 131);
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(8, 1.0);
        let s = c.step(&x, &LstmState::zeros(16));
        assert_eq!(s.h.len(), 16);
        assert_eq!(s.c.len(), 16);
        // h = o * tanh(c) is bounded by 1 in magnitude.
        assert!(s.h.iter().all(|&v| v.abs() <= 1.0));
    }

    #[test]
    fn zero_input_zero_state_gives_bias_driven_output() {
        let c = cell(Precision::Full, 132);
        let s = c.step(&vec![0.0; 8], &LstmState::zeros(16));
        // With zero bias, gates are at 0.5/0.0 ⇒ c = 0.5*0 + 0.5*tanh(0) = 0.
        assert!(s.c.iter().all(|&v| v.abs() < 1e-6));
        assert!(s.h.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn quantized_cell_tracks_full_precision() {
        let mut rng = Rng::new(133);
        let (input, hidden) = (32, 64);
        let wx: Vec<f32> = (0..4 * hidden * input).map(|_| rng.range_f32(-0.2, 0.2)).collect();
        let wh: Vec<f32> = (0..4 * hidden * hidden).map(|_| rng.range_f32(-0.2, 0.2)).collect();
        let bias = vec![0.0; 4 * hidden];
        let fp = LstmCell::from_dense(wx.clone(), wh.clone(), bias.clone(), input, hidden, Precision::Full);
        let q = LstmCell::from_dense(wx, wh, bias, input, hidden, Precision::Quantized { k_w: 3, k_a: 3 });
        let x = rng.normal_vec(input, 1.0);
        let mut sf = LstmState::zeros(hidden);
        let mut sq = LstmState::zeros(hidden);
        for _ in 0..5 {
            sf = fp.step(&x, &sf);
            sq = q.step(&x, &sq);
        }
        let err: f32 = sf.h.iter().zip(&sq.h).map(|(a, b)| (a - b).abs()).sum::<f32>() / hidden as f32;
        assert!(err < 0.1, "mean |Δh| over 5 steps = {err}");
    }

    #[test]
    fn step_batch_bitmatches_step_per_column() {
        let mut rng = Rng::new(136);
        for precision in [Precision::Full, Precision::Quantized { k_w: 2, k_a: 2 }] {
            let cell = LstmCell::init(10, 12, 0.4, &mut rng, precision);
            for batch in 1..=4 {
                let singles: Vec<LstmState> = (0..batch)
                    .map(|_| LstmState {
                        h: rng.normal_vec(12, 0.5),
                        c: rng.normal_vec(12, 0.5),
                    })
                    .collect();
                let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(10, 1.0)).collect();
                let refs: Vec<&LstmState> = singles.iter().collect();
                let sb = LstmStateBatch::from_states(&refs);
                let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
                let next = cell.step_batch(&ActivationBatch::from_rows(&xrows), &sb);
                for b in 0..batch {
                    let expect = cell.step(&xs[b], &singles[b]);
                    assert_eq!(next.state(b), expect, "{precision:?} batch={batch} col={b}");
                }
            }
        }
    }

    #[test]
    fn dense_tape_matches_cell_step() {
        let mut rng = Rng::new(134);
        let (input, hidden) = (8, 12);
        let wx: Vec<f32> = (0..4 * hidden * input).map(|_| rng.range_f32(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..4 * hidden * hidden).map(|_| rng.range_f32(-0.3, 0.3)).collect();
        let bias: Vec<f32> = (0..4 * hidden).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let cell = LstmCell::from_dense(wx.clone(), wh.clone(), bias.clone(), input, hidden, Precision::Full);
        let x = rng.normal_vec(input, 1.0);
        let h0 = rng.normal_vec(hidden, 0.5);
        let c0 = rng.normal_vec(hidden, 0.5);
        let s = cell.step(&x, &LstmState { h: h0.clone(), c: c0.clone() });
        let tape = step_dense_tape(&wx, &wh, &bias, input, hidden, &x, &h0, &c0);
        for j in 0..hidden {
            assert!((s.h[j] - tape.h[j]).abs() < 1e-5);
            assert!((s.c[j] - tape.c[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::new(135);
        let (input, hidden) = (3, 4);
        let mut wx: Vec<f32> = (0..4 * hidden * input).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let wh: Vec<f32> = (0..4 * hidden * hidden).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let bias: Vec<f32> = (0..4 * hidden).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let x = rng.normal_vec(input, 1.0);
        let h0 = rng.normal_vec(hidden, 0.5);
        let c0 = rng.normal_vec(hidden, 0.5);
        // Loss = sum(h).
        let loss = |wx: &[f32]| -> f32 {
            let t = step_dense_tape(wx, &wh, &bias, input, hidden, &x, &h0, &c0);
            t.h.iter().sum()
        };
        let tape = step_dense_tape(&wx, &wh, &bias, input, hidden, &x, &h0, &c0);
        let dh = vec![1.0f32; hidden];
        let dc = vec![0.0f32; hidden];
        let mut gwx = vec![0.0f32; wx.len()];
        let mut gwh = vec![0.0f32; wh.len()];
        let mut gb = vec![0.0f32; bias.len()];
        step_dense_backward(
            &wx, &wh, input, hidden, &x, &h0, &c0, &tape, &dh, &dc, &mut gwx, &mut gwh, &mut gb,
        );
        for idx in [0usize, 5, 11, wx.len() - 1] {
            let eps = 1e-3;
            let orig = wx[idx];
            wx[idx] = orig + eps;
            let lp = loss(&wx);
            wx[idx] = orig - eps;
            let lm = loss(&wx);
            wx[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - gwx[idx]).abs() < 2e-2 * (1.0 + fd.abs()), "idx {idx}: fd {fd} vs {}", gwx[idx]);
        }
    }
}
