//! Feed-forward MLP with quantization-aware (STE) training — the substrate
//! for Table 8 (MNIST MLP) and the dense layers of the CNN (Table 9).
//!
//! Training follows the paper's bi-level scheme (Eq. 7): full-precision
//! master weights accumulate gradients; the forward pass re-quantizes every
//! mini-batch; the backward pass applies the straight-through estimator
//! `∂f/∂w = ∂f/∂ŵ`. Optimizer is Adam (Appendix B setting), with optional
//! batch normalization between layers.

use crate::quant::{self, Method};
use crate::util::Rng;

/// Quantization spec for the forward pass of a layer (`None` = full
/// precision). Activations are quantized with `k_a` bits after the
/// nonlinearity; `k_a = 1` means pure sign binarization (Appendix B runs
/// 1-bit activations).
#[derive(Clone, Copy, Debug)]
pub struct QuantSpec {
    pub k_w: Option<usize>,
    pub k_a: Option<usize>,
    pub method: Method,
}

impl QuantSpec {
    pub fn full() -> Self {
        QuantSpec { k_w: None, k_a: None, method: Method::Alternating { t: 2 } }
    }

    pub fn wa(k_w: usize, k_a: usize, method: Method) -> Self {
        QuantSpec { k_w: Some(k_w), k_a: Some(k_a), method }
    }
}

/// Quantize a weight matrix row-wise for the forward pass (returns the
/// dequantized dense matrix — the STE makes the packed form unnecessary
/// during training; inference uses [`crate::model::linear::Linear`]).
pub fn ste_quantize_matrix(w: &[f32], rows: usize, cols: usize, k: usize, method: Method) -> Vec<f32> {
    quant::RowQuantized::quantize(w, rows, cols, k, method).dequantize()
}

/// Quantize an activation batch in place (per-sample, the online path).
pub fn ste_quantize_activations(a: &mut [f32], batch: usize, dim: usize, k: usize, method: Method) {
    for b in 0..batch {
        let row = &mut a[b * dim..(b + 1) * dim];
        let q = quant::quantize(row, k, method);
        row.copy_from_slice(&q.dequantize());
    }
}

/// One dense layer with master weights + Adam state.
pub struct DenseLayer {
    pub w: Vec<f32>, // rows × cols master (full precision)
    pub b: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    // Adam moments.
    mw: Vec<f32>,
    vw: Vec<f32>,
    mb: Vec<f32>,
    vb: Vec<f32>,
}

impl DenseLayer {
    pub fn init(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = (2.0 / cols as f32).sqrt();
        DenseLayer {
            w: rng.normal_vec(rows * cols, scale),
            b: vec![0.0; rows],
            rows,
            cols,
            mw: vec![0.0; rows * cols],
            vw: vec![0.0; rows * cols],
            mb: vec![0.0; rows],
            vb: vec![0.0; rows],
        }
    }

    /// Forward-pass weights under the spec (quantized or master).
    pub fn effective_w(&self, spec: &QuantSpec) -> Vec<f32> {
        match spec.k_w {
            Some(k) => ste_quantize_matrix(&self.w, self.rows, self.cols, k, spec.method),
            None => self.w.clone(),
        }
    }

    /// `y[b] = W x[b] + bias` for a batch (row-major `batch × cols`).
    pub fn forward(&self, wq: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; batch * self.rows];
        for bi in 0..batch {
            let xb = &x[bi * self.cols..(bi + 1) * self.cols];
            let yb = &mut y[bi * self.rows..(bi + 1) * self.rows];
            for r in 0..self.rows {
                let row = &wq[r * self.cols..(r + 1) * self.cols];
                let mut s = self.b[r];
                for (a, v) in row.iter().zip(xb) {
                    s += a * v;
                }
                yb[r] = s;
            }
        }
        y
    }

    /// Backward: given `dy`, accumulate `(gw, gb)` and return `dx`.
    /// Gradients flow through the *quantized* weights (STE on the weights
    /// themselves: `∂f/∂w := ∂f/∂ŵ`, but `dx` uses `ŵ`).
    pub fn backward(
        &self,
        wq: &[f32],
        x: &[f32],
        dy: &[f32],
        batch: usize,
        gw: &mut [f32],
        gb: &mut [f32],
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; batch * self.cols];
        for bi in 0..batch {
            let xb = &x[bi * self.cols..(bi + 1) * self.cols];
            let dyb = &dy[bi * self.rows..(bi + 1) * self.rows];
            let dxb = &mut dx[bi * self.cols..(bi + 1) * self.cols];
            for r in 0..self.rows {
                let d = dyb[r];
                if d == 0.0 {
                    continue;
                }
                gb[r] += d;
                let row = &wq[r * self.cols..(r + 1) * self.cols];
                let grow = &mut gw[r * self.cols..(r + 1) * self.cols];
                for c in 0..self.cols {
                    grow[c] += d * xb[c];
                    dxb[c] += d * row[c];
                }
            }
        }
        dx
    }

    /// Adam update on the master weights (STE), with weight clipping to
    /// `[-1, 1]` as the paper does to control outliers.
    pub fn adam_step(&mut self, gw: &[f32], gb: &[f32], lr: f32, t: usize) {
        adam_update(&mut self.w, &mut self.mw, &mut self.vw, gw, lr, t);
        adam_update(&mut self.b, &mut self.mb, &mut self.vb, gb, lr, t);
        for v in self.w.iter_mut() {
            *v = v.clamp(-1.0, 1.0);
        }
    }
}

/// Adam with the standard (β₁, β₂, ε) = (0.9, 0.999, 1e-8).
pub fn adam_update(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, t: usize) {
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    for i in 0..p.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= lr * mhat / (vhat.sqrt() + eps);
    }
}

/// Batch normalization (Ioffe & Szegedy 2015) over a `batch × dim` tensor,
/// with running statistics for inference.
pub struct BatchNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub dim: usize,
    pub momentum: f32,
}

pub struct BnTape {
    pub xhat: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

impl BatchNorm {
    pub fn new(dim: usize) -> Self {
        BatchNorm {
            gamma: vec![1.0; dim],
            beta: vec![0.0; dim],
            running_mean: vec![0.0; dim],
            running_var: vec![1.0; dim],
            dim,
            momentum: 0.1,
        }
    }

    pub fn forward_train(&mut self, x: &[f32], batch: usize) -> (Vec<f32>, BnTape) {
        let d = self.dim;
        let mut mean = vec![0.0f32; d];
        let mut var = vec![0.0f32; d];
        for bi in 0..batch {
            for j in 0..d {
                mean[j] += x[bi * d + j];
            }
        }
        for mj in mean.iter_mut() {
            *mj /= batch as f32;
        }
        for bi in 0..batch {
            for j in 0..d {
                let c = x[bi * d + j] - mean[j];
                var[j] += c * c;
            }
        }
        for vj in var.iter_mut() {
            *vj /= batch as f32;
        }
        let mut y = vec![0.0f32; x.len()];
        let mut xhat = vec![0.0f32; x.len()];
        for bi in 0..batch {
            for j in 0..d {
                let xh = (x[bi * d + j] - mean[j]) / (var[j] + 1e-5).sqrt();
                xhat[bi * d + j] = xh;
                y[bi * d + j] = self.gamma[j] * xh + self.beta[j];
            }
        }
        for j in 0..d {
            self.running_mean[j] =
                (1.0 - self.momentum) * self.running_mean[j] + self.momentum * mean[j];
            self.running_var[j] =
                (1.0 - self.momentum) * self.running_var[j] + self.momentum * var[j];
        }
        (y, BnTape { xhat, mean, var })
    }

    pub fn forward_eval(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let d = self.dim;
        let mut y = vec![0.0f32; x.len()];
        for bi in 0..batch {
            for j in 0..d {
                let xh = (x[bi * d + j] - self.running_mean[j])
                    / (self.running_var[j] + 1e-5).sqrt();
                y[bi * d + j] = self.gamma[j] * xh + self.beta[j];
            }
        }
        y
    }

    /// Backward; updates gamma/beta in place with plain SGD (lr) and returns dx.
    pub fn backward(&mut self, tape: &BnTape, dy: &[f32], batch: usize, lr: f32) -> Vec<f32> {
        let d = self.dim;
        let n = batch as f32;
        let mut dgamma = vec![0.0f32; d];
        let mut dbeta = vec![0.0f32; d];
        for bi in 0..batch {
            for j in 0..d {
                dgamma[j] += dy[bi * d + j] * tape.xhat[bi * d + j];
                dbeta[j] += dy[bi * d + j];
            }
        }
        // dx = (1/n)·inv_std·(n·dxhat − Σdxhat − x̂·Σ(dxhat·x̂)).
        let mut dx = vec![0.0f32; dy.len()];
        let mut sum_dxhat = vec![0.0f32; d];
        let mut sum_dxhat_xhat = vec![0.0f32; d];
        for bi in 0..batch {
            for j in 0..d {
                let dxhat = dy[bi * d + j] * self.gamma[j];
                sum_dxhat[j] += dxhat;
                sum_dxhat_xhat[j] += dxhat * tape.xhat[bi * d + j];
            }
        }
        for j in 0..d {
            let inv_std = 1.0 / (tape.var[j] + 1e-5).sqrt();
            for bi in 0..batch {
                let dxhat = dy[bi * d + j] * self.gamma[j];
                dx[bi * d + j] = inv_std / n
                    * (n * dxhat - sum_dxhat[j] - tape.xhat[bi * d + j] * sum_dxhat_xhat[j]);
            }
        }
        for j in 0..d {
            self.gamma[j] -= lr * dgamma[j];
            self.beta[j] -= lr * dbeta[j];
        }
        dx
    }
}

/// ReLU forward (returns mask for backward).
pub fn relu(x: &mut [f32]) -> Vec<bool> {
    x.iter_mut()
        .map(|v| {
            if *v > 0.0 {
                true
            } else {
                *v = 0.0;
                false
            }
        })
        .collect()
}

/// Squared-hinge (L2-SVM) loss over one-vs-all margins — the output layer
/// the paper uses for the MNIST MLP and the CIFAR CNN. Returns (loss, dlogits).
pub fn l2svm_loss(logits: &[f32], labels: &[usize], batch: usize, classes: usize) -> (f32, Vec<f32>) {
    let mut loss = 0.0f32;
    let mut dl = vec![0.0f32; logits.len()];
    for bi in 0..batch {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let y = labels[bi];
        for c in 0..classes {
            let t = if c == y { 1.0 } else { -1.0 };
            let margin = 1.0 - t * row[c];
            if margin > 0.0 {
                loss += margin * margin;
                dl[bi * classes + c] = -2.0 * t * margin;
            }
        }
    }
    let n = (batch * classes) as f32;
    for d in dl.iter_mut() {
        *d /= n;
    }
    (loss / n, dl)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_layer_grad_check() {
        let mut rng = Rng::new(151);
        let layer = DenseLayer::init(3, 4, &mut rng);
        let x = rng.normal_vec(2 * 4, 1.0);
        let spec = QuantSpec::full();
        let wq = layer.effective_w(&spec);
        let y = layer.forward(&wq, &x, 2);
        // Loss = sum(y²)/2, dy = y.
        let mut gw = vec![0.0f32; 12];
        let mut gb = vec![0.0f32; 3];
        layer.backward(&wq, &x, &y, 2, &mut gw, &mut gb);
        // Finite differences on a few weights.
        for idx in [0usize, 5, 11] {
            let eps = 1e-3;
            let mut lp = layer.w.clone();
            lp[idx] += eps;
            let mut lm = layer.w.clone();
            lm[idx] -= eps;
            let f = |w: &[f32]| -> f32 {
                let y = layer.forward(w, &x, 2);
                y.iter().map(|v| v * v).sum::<f32>() / 2.0
            };
            let fd = (f(&lp) - f(&lm)) / (2.0 * eps);
            assert!((fd - gw[idx]).abs() < 1e-2 * (1.0 + fd.abs()), "{fd} vs {}", gw[idx]);
        }
    }

    #[test]
    fn bn_normalizes_batch() {
        let mut bn = BatchNorm::new(3);
        let mut rng = Rng::new(152);
        let x: Vec<f32> = (0..30).map(|_| rng.range_f32(5.0, 9.0)).collect();
        let (y, _) = bn.forward_train(&x, 10);
        for j in 0..3 {
            let col: Vec<f32> = (0..10).map(|b| y[b * 3 + j]).collect();
            let mean: f32 = col.iter().sum::<f32>() / 10.0;
            let var: f32 = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 10.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn bn_backward_grad_check() {
        let mut rng = Rng::new(153);
        let x = rng.normal_vec(8 * 2, 1.5);
        let f = |x: &[f32]| -> f32 {
            let mut bn = BatchNorm::new(2);
            let (y, _) = bn.forward_train(x, 8);
            y.iter().map(|v| v * v).sum::<f32>() / 2.0
        };
        let mut bn = BatchNorm::new(2);
        let (y, tape) = bn.forward_train(&x, 8);
        let dx = bn.backward(&tape, &y, 8, 0.0);
        for idx in [0usize, 7, 15] {
            let eps = 1e-3;
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((fd - dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()), "idx {idx}: {fd} vs {}", dx[idx]);
        }
    }

    #[test]
    fn l2svm_zero_loss_when_margins_met() {
        let logits = vec![2.0, -2.0, -2.0, 2.0]; // batch 2, classes 2
        let (loss, d) = l2svm_loss(&logits, &[0, 1], 2, 2);
        assert_eq!(loss, 0.0);
        assert!(d.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn adam_decreases_quadratic() {
        let mut p = vec![5.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        for t in 1..=500 {
            let g = vec![2.0 * p[0]];
            adam_update(&mut p, &mut m, &mut v, &g, 0.05, t);
        }
        assert!(p[0].abs() < 0.5, "{}", p[0]);
    }

    #[test]
    fn ste_quantize_matrix_is_rowwise() {
        let mut rng = Rng::new(154);
        let w = rng.normal_vec(4 * 16, 1.0);
        let q = ste_quantize_matrix(&w, 4, 16, 2, Method::Greedy);
        let rq = crate::quant::RowQuantized::quantize(&w, 4, 16, 2, Method::Greedy);
        assert_eq!(q, rq.dequantize());
    }
}
