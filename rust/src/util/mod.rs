//! Small self-contained utilities: a deterministic PRNG, timing helpers,
//! simple statistics, and a property-testing harness.
//!
//! The workspace builds fully offline against a minimal vendored crate set,
//! so these substrates are implemented in-tree instead of pulling `rand`,
//! `criterion`, or `proptest`.

pub mod crc;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
