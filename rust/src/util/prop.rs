//! A minimal property-testing harness (`proptest` is not in the vendored
//! crate set). `check` runs a property over `cases` seeded random inputs and,
//! on failure, greedily shrinks the failing input before panicking.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xA17E_55ED }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. On failure, attempts up to
/// 64 shrink steps via `shrink` (return candidate smaller inputs), then
/// panics with the minimal counterexample's `Debug` output.
pub fn check<T, G, P, S>(name: &str, cfg: Config, mut gen: G, mut shrink: S, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
    S: FnMut(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink greedily.
        let mut minimal = input.clone();
        'outer: for _ in 0..64 {
            for cand in shrink(&minimal) {
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!("property '{name}' failed at case {case}\nminimal counterexample: {minimal:?}");
    }
}

/// Convenience: property over a random f32 vector with random length in
/// `[1, max_len]`, values in `[-scale, scale]`. Shrinks by halving length.
pub fn check_f32_vec(name: &str, max_len: usize, scale: f32, mut prop: impl FnMut(&Vec<f32>) -> bool) {
    check(
        name,
        Config::default(),
        |rng| {
            let n = 1 + rng.below(max_len);
            (0..n).map(|_| rng.range_f32(-scale, scale)).collect::<Vec<f32>>()
        },
        |v| {
            let mut out = Vec::new();
            if v.len() > 1 {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[v.len() / 2..].to_vec());
            }
            // Also try zeroing entries (often exposes degenerate cases).
            if v.iter().any(|&x| x != 0.0) {
                out.push(v.iter().map(|_| 0.0).collect());
            }
            out
        },
        |v| prop(v),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_f32_vec("len>0", 64, 1.0, |v| !v.is_empty());
    }

    #[test]
    #[should_panic(expected = "all_positive")]
    fn failing_property_fails() {
        check_f32_vec("all_positive", 64, 1.0, |v| v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen = Vec::new();
        for _ in 0..2 {
            let mut first = None;
            check(
                "capture",
                Config { cases: 1, seed: 42 },
                |rng| rng.next_u64(),
                |_| vec![],
                |x| {
                    first = Some(*x);
                    true
                },
            );
            seen.push(first.unwrap());
        }
        assert_eq!(seen[0], seen[1]);
    }
}
