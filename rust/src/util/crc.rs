//! CRC32C (Castagnoli, reflected polynomial `0x82F63B78`) — the checksum
//! guarding every `.amqz` section and session-snapshot file. Software
//! slice-by-8, std-only: eight 256-entry tables built at compile time, the
//! hot loop folds 8 input bytes per iteration with no data-dependent
//! branches. iSCSI/RFC 3720 test vectors pin the exact bit order below.
//!
//! Why CRC32C and not a cryptographic hash: the threat model is torn
//! writes, truncation, and bit rot — not an adversary forging a model file
//! — and a 4-byte checksum per section keeps the format overhead
//! negligible while detecting every burst error a crash can plausibly
//! produce.

const POLY: u32 = 0x82F6_3B78;

/// `TABLES[k][b]`: the CRC contribution of byte value `b` seen `k` bytes
/// before the end of an 8-byte group.
static TABLES: [[u32; 256]; 8] = build_tables();

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            j += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xff) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

/// CRC32C of `data` in one call.
pub fn crc32c(data: &[u8]) -> u32 {
    crc32c_append(0, data)
}

/// Continue a CRC32C over more bytes: `crc32c_append(crc32c(a), b) ==
/// crc32c(a ++ b)`. Lets writers checksum sections as they stream them out
/// and readers verify ranges of a larger arena without copying.
pub fn crc32c_append(crc: u32, data: &[u8]) -> u32 {
    let mut crc = !crc;
    let mut groups = data.chunks_exact(8);
    for g in groups.by_ref() {
        let low = crc ^ u32::from_le_bytes([g[0], g[1], g[2], g[3]]);
        crc = TABLES[7][(low & 0xff) as usize]
            ^ TABLES[6][((low >> 8) & 0xff) as usize]
            ^ TABLES[5][((low >> 16) & 0xff) as usize]
            ^ TABLES[4][(low >> 24) as usize]
            ^ TABLES[3][g[4] as usize]
            ^ TABLES[2][g[5] as usize]
            ^ TABLES[1][g[6] as usize]
            ^ TABLES[0][g[7] as usize];
    }
    for &b in groups.remainder() {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

/// Streaming hasher over the same function (writers that produce a file in
/// several `write` calls).
#[derive(Clone, Copy, Debug, Default)]
pub struct Crc32c {
    state: u32,
}

impl Crc32c {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, data: &[u8]) {
        self.state = crc32c_append(self.state, data);
    }

    pub fn finish(&self) -> u32 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bit-at-a-time reference — the definition the tables must match.
    fn reference(data: &[u8]) -> u32 {
        let mut crc = !0u32;
        for &b in data {
            crc ^= b as u32;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
        }
        !crc
    }

    #[test]
    fn known_answer_vectors() {
        // The canonical check value plus the RFC 3720 (iSCSI) vectors.
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(b""), 0);
        assert_eq!(crc32c(b"a"), 0xC1D0_4330);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xffu8; 32]), 0x62A8_AB43);
        let ascending: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32c(&ascending), 0x46DD_794E);
        let descending: Vec<u8> = (0u8..32).rev().collect();
        assert_eq!(crc32c(&descending), 0x113F_DB5C);
    }

    #[test]
    fn slice_by_8_matches_bitwise_reference_at_every_length() {
        // Lengths straddling the 8-byte grouping, pseudo-random content.
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let data: Vec<u8> = (0..257)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 56) as u8
            })
            .collect();
        for len in 0..data.len() {
            assert_eq!(crc32c(&data[..len]), reference(&data[..len]), "length {len}");
        }
    }

    #[test]
    fn append_composes_and_streaming_hasher_agrees() {
        let data = b"alternating multi-bit quantization for recurrent neural networks";
        for split in 0..data.len() {
            let (a, b) = data.split_at(split);
            assert_eq!(crc32c_append(crc32c(a), b), crc32c(data), "split {split}");
        }
        let mut h = Crc32c::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), crc32c(data));
    }
}
