//! Deterministic xoshiro256** PRNG (public-domain algorithm by Blackman &
//! Vigna) seeded via SplitMix64. All experiments in this repo are seeded, so
//! every table regenerates bit-identically.

/// xoshiro256** generator. Not cryptographic; statistical quality is more
/// than sufficient for synthetic corpora and weight init.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (uses two uniforms; drops the pair).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// A vector of iid normals with the given std.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Standard Laplace (double-exponential) sample — the classical model
    /// for *trained* network weights (heavier tails than gaussian), which is
    /// what makes rule-based uniform quantization degrade in the paper.
    pub fn laplace(&mut self) -> f32 {
        let e1 = -(1.0 - self.f64()).max(f64::MIN_POSITIVE).ln();
        let e2 = -(1.0 - self.f64()).max(f64::MIN_POSITIVE).ln();
        (e1 - e2) as f32
    }

    /// A vector of iid Laplace samples with the given scale `b`.
    pub fn laplace_vec(&mut self, n: usize, b: f32) -> Vec<f32> {
        (0..n).map(|_| self.laplace() * b).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..200_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
