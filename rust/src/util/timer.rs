//! Minimal wall-clock timing + a criterion-style micro-bench loop.
//!
//! `criterion` is not in the vendored crate set, so `bench_fn` implements the
//! essentials: warmup, batched timing, and a robust (median-based) report.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// A simple start/elapsed timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Result of a micro-benchmark run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median time per iteration, in nanoseconds.
    pub median_ns: f64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>10.3} ms  mean {:>10.3} ms ± {:>7.3}  ({} iters)",
            self.name,
            self.median_ns / 1e6,
            self.mean_ns / 1e6,
            self.std_ns / 1e6,
            self.iters
        )
    }
}

/// Time `f` with warmup. Chooses the batch size so each sample is >=~1ms,
/// takes `samples` samples, reports median/mean/std per iteration.
pub fn bench_fn<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // Warmup + calibration: find iters per sample targeting ~2 ms.
    let t = Instant::now();
    let mut calib_iters = 0u64;
    while t.elapsed() < Duration::from_millis(20) {
        f();
        calib_iters += 1;
    }
    let per_iter = t.elapsed().as_secs_f64() / calib_iters as f64;
    let batch = ((2e-3 / per_iter).ceil() as u64).max(1);

    let mut stats = Summary::new();
    let mut total_iters = 0u64;
    for _ in 0..samples.max(3) {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let ns = t.elapsed().as_secs_f64() * 1e9 / batch as f64;
        stats.add(ns);
        total_iters += batch;
    }
    BenchResult {
        name: name.to_string(),
        median_ns: stats.median(),
        mean_ns: stats.mean(),
        std_ns: stats.std(),
        iters: total_iters,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_time() {
        let mut acc = 0u64;
        let r = bench_fn("noop-ish", 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
