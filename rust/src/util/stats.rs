//! Summary statistics used by the bench harness and the serving metrics.

/// Streaming summary of a sample: count/mean/min/max plus stored values for
/// exact percentiles (benches are small enough to keep every observation).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile by nearest-rank (`p` in `[0, 100]`).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.values.len() - 1) as f64).round() as usize;
        self.values[rank.min(self.values.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.add(v);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 0..101 {
            s.add(v as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(90.0), 90.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }
}
