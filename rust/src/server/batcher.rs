//! The batching inference loop: fixed timestep groups or continuous
//! batching, one code path for the actual decode — now multi-tenant.
//!
//! **Grouped mode** (the classic [`Self::run`] loop with
//! `continuous = false`): requests queue on a channel; the batcher drains
//! up to `max_batch` of them (waiting at most `batch_wait` to fill a batch
//! — the throughput/latency knob), then runs the whole group to completion
//! before looking at the queue again.
//!
//! **Continuous mode** (`continuous = true`, the event-loop front end's
//! default): there is no group barrier. The decode batch is a set of
//! **slots** over a state batch that stays resident across timesteps; a
//! new request joins at the next timestep boundary
//! ([`RnnLm::push_state_column`]) and a finished sequence frees its slot
//! immediately ([`RnnLm::swap_remove_state_column`]) — a short request
//! never waits for a long one it happens to share a batch with.
//! Slot bookkeeping is swap-remove in O(joins + leaves) per timestep;
//! the steady-state timestep itself is the zero-allocation
//! [`RnnLm::step_batch_into_exec`] on the server's persistent workspace.
//! Admission control backs the loop: at most `max_slots` sequences decode
//! concurrently (summed across models), at most `queue_depth` wait behind
//! them, and anything beyond that is shed instantly with [`Reply::Busy`]
//! (`ERR BUSY` on the wire) instead of building unbounded latency.
//! Generations for a session already decoding are held until its slot
//! leaves (per-session serialization — pipelined requests continue state
//! exactly as if sent one at a time; unrelated sessions admit past them).
//!
//! **Multi-tenancy**: the server holds a [`ModelRegistry`] and one
//! [`ModelLane`] per *resident* model — each lane owns its model's
//! sessions, decode slots, and step workspaces, so sequences of different
//! models batch among themselves and never cross-contaminate state. A
//! request's `MODEL <name>` field (default: the registry's default model)
//! is resolved at admission, which is also where the zero-copy `.amqz`
//! load happens on a cold name and where LRU eviction past the memory
//! budget drops idle lanes. Admission also validates every request token
//! against the target model's vocab — an out-of-vocab token answers
//! `ERR token <t> out of vocab <v>` instead of reaching the
//! `Embedding::lookup` assert and panicking the batcher thread.
//!
//! Both modes run every batched timestep on the server's [`Exec`] worker
//! pool (`config.exec`), which row-shards every GEMM across cores —
//! bit-exactly, so neither batching mode nor threading is observable to
//! clients: the tokens equal a serial `max_batch = 1` run, always.
//!
//! **Failure containment**: every lane timestep runs under
//! `catch_unwind`, so a panic inside the model/kernel path poisons only
//! that lane — its in-flight sessions answer `ERR INTERNAL`, the model's
//! registry entry is quarantined (`ERR MODEL_POISONED` until an operator
//! `RELOAD` succeeds), and every other lane keeps decoding bit-exactly on
//! the same thread. Requests additionally carry an optional wall-clock
//! deadline (`request_deadline`), checked at timestep boundaries: an
//! expired request leaves its slot with `ERR DEADLINE` and its session
//! drops as if `END` arrived, while the surviving co-batched slots emit
//! exactly the tokens they would have without it (column swap-remove is
//! already invisible to decoding). Idle sessions are reaped after
//! `session_ttl`; both run loops tick on that interval even when idle.
//! All of it is `Option`-gated — with the knobs off, the steady-state
//! decode path is byte-for-byte the zero-allocation one.
//!
//! **Graceful drain** ([`Work::Drain`], wire `DRAIN`, or SIGTERM in
//! `main`): admission stops (`ERR DRAINING`), in-flight decodes run to
//! completion up to `drain_deadline` (stragglers answer `ERR DRAINING`
//! like a deadline expiry), then every saved session — state plus its
//! recent token history — is serialized to `snapshot_path` as a
//! checksummed, atomically-published `.amqs` file
//! ([`crate::data::checkpoint::SessionSnapshot`]). A restarted server
//! passes that file to [`InferenceServer::restore_sessions`] and every
//! revived session continues **bit-exactly** where it stopped; the
//! snapshot/restore pair is a no-op on the decode path itself. The server
//! keeps answering non-generation verbs after a drain, so operators can
//! poll `STATS` while the load balancer bleeds connections.
//!
//! **Liveness** ([`HealthMonitor`]): the loop beats once per scheduling
//! pass and each lane once per timestep. Front ends answer `HEALTH` from
//! the shared monitor without touching the work channel, so a wedged
//! batcher is exactly what the probe can still report.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::checkpoint::{ModelSessions, SessionRecord, SessionSnapshot};
use crate::exec::{Exec, ExecConfig};
use crate::metrics::{Counters, LatencyRing};
use crate::model::lm::{LmState, LmStateBatch, LmStepWorkspace};
use crate::model::math::argmax;
use crate::model::OutputBatch;
use crate::model::RnnLm;
use crate::server::faults::FaultPlan;
use crate::server::health::HealthMonitor;
use crate::server::registry::ModelRegistry;
use crate::server::session::SessionStore;

/// Name the single-model constructors register their model under.
pub const DEFAULT_MODEL: &str = "default";

/// Batching knobs ([server] config section).
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub batch_wait: Duration,
    /// Per-model session cap (each lane gets its own store).
    pub max_sessions: usize,
    /// Continuous batching: join/leave at timestep boundaries instead of
    /// fixed prime+decode groups. The event-loop front end's mode.
    pub continuous: bool,
    /// Max sequences decoding concurrently in continuous mode, summed
    /// across models (`0` ⇒ `max_batch`).
    pub max_slots: usize,
    /// Bounded pending queue in continuous mode; a generation request
    /// arriving with the queue full is shed with [`Reply::Busy`].
    pub queue_depth: usize,
    /// Worker-pool size for the batched forward (`threads = 1` ⇒ the exact
    /// serial path, `0` ⇒ auto). See [`ExecConfig`].
    pub exec: ExecConfig,
    /// Per-request wall-clock deadline, measured from front-end arrival
    /// (`Request::enqueued`) and checked at timestep boundaries. Expired
    /// requests answer `ERR DEADLINE` and drop their session as if `END`
    /// arrived. `None` = no deadline (CLI `--request-deadline-ms`).
    pub request_deadline: Option<Duration>,
    /// Reap sessions with no work for this long, exactly as if `END`
    /// arrived. `None` = keep until LRU eviction (CLI `--session-ttl-secs`).
    pub session_ttl: Option<Duration>,
    /// Deterministic fault-injection plan (`AMQ_FAULTS`); `None` reduces
    /// every injection seam to a branch on a null option.
    pub faults: Option<Arc<FaultPlan>>,
    /// Where `DRAIN` writes the session snapshot (CLI `--snapshot`).
    /// `None` = drains are refused (there is nowhere durable to put the
    /// sessions, so silently dropping them would be a lie).
    pub snapshot_path: Option<PathBuf>,
    /// How long a drain lets in-flight decodes finish before cutting the
    /// stragglers off with `ERR DRAINING` (CLI `--drain-deadline-ms`).
    pub drain_deadline: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            batch_wait: Duration::from_micros(500),
            max_sessions: 1024,
            continuous: false,
            max_slots: 0,
            queue_depth: 128,
            exec: ExecConfig::auto(),
            request_deadline: None,
            session_ttl: None,
            faults: None,
            snapshot_path: None,
            drain_deadline: Duration::from_millis(5000),
        }
    }
}

/// A generation request routed to the batcher.
pub struct Request {
    pub session: u64,
    pub max_new: usize,
    pub prime: Vec<usize>,
    /// Target model (`None` ⇒ the registry default). Admission rewrites it
    /// to the canonical registry name.
    pub model: Option<String>,
    pub respond: Respond,
    pub enqueued: Instant,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<usize>,
    pub queue_us: f64,
    pub compute_us: f64,
}

/// Every reply the batcher can produce, one type for every front end.
#[derive(Clone, Debug)]
pub enum Reply {
    Gen(Response),
    Score(f64),
    /// `true` ⇒ the session existed and was dropped.
    End(bool),
    Stats(String),
    /// Successful operator `RELOAD`; carries the canonical model name.
    Reloaded(String),
    /// Successful `DRAIN`: how many sessions were snapshotted, and where.
    Drained { sessions: u64, path: String },
    /// Request-level failure (out-of-vocab token, unknown model, model
    /// load failure, deadline expiry, poisoned model). Rendered as
    /// `ERR <message>`; the connection lives.
    Error(String),
    /// Load shed: the pending queue was full when the request arrived.
    Busy { queued: usize, depth: usize },
}

/// Where a completed [`Reply`] goes. The thread-per-connection front end
/// blocks on a channel; the event loop registers a [`ReplySink`] that
/// enqueues the completion and wakes the owning loop.
pub enum Respond {
    Channel(Sender<Reply>),
    Sink { sink: Arc<dyn ReplySink>, conn: u64, serial: u64 },
}

impl Respond {
    pub fn send(self, reply: Reply) {
        match self {
            Respond::Channel(tx) => {
                let _ = tx.send(reply);
            }
            Respond::Sink { sink, conn, serial } => sink.complete(conn, serial, reply),
        }
    }
}

/// Asynchronous completion target (the event loop's half of [`Respond`]).
pub trait ReplySink: Send + Sync {
    fn complete(&self, conn: u64, serial: u64, reply: Reply);
}

/// Work items multiplexed onto the batcher thread.
pub enum Work {
    Gen(Request),
    Score { tokens: Vec<usize>, model: Option<String>, respond: Respond },
    End { session: u64, model: Option<String>, respond: Respond },
    Stats { text: bool, respond: Respond },
    /// Operator recovery: clear a poison quarantine and re-publish the
    /// model from its `.amqz` path.
    Reload { model: String, respond: Respond },
    /// Graceful drain: stop admission, finish in-flight decodes up to the
    /// drain deadline, snapshot every saved session to `snapshot_path`.
    /// The server keeps answering non-generation verbs afterwards.
    Drain { respond: Respond },
    Shutdown,
}

/// One sequence occupying a batch slot. `slots[i]` always describes column
/// `i` of the lane's resident state batch; the parallel `tokens[i]` holds
/// the token that column consumes at the next timestep.
struct SeqSlot {
    session: u64,
    prime: Vec<usize>,
    /// Prime tokens consumed so far; `fed == prime.len()` ⇒ decoding.
    fed: usize,
    out: Vec<usize>,
    max_new: usize,
    respond: Respond,
    queue_us: f64,
    joined: Instant,
    /// Wall-clock expiry (`enqueued + request_deadline`); checked at
    /// timestep boundaries. `None` = no deadline.
    deadline: Option<Instant>,
    /// Finished this timestep (final emitted token consumed); freed at the
    /// end of the timestep.
    done: bool,
    /// Reusable per-session state buffer: holds the restored session state
    /// at join, receives the extracted column at leave.
    state_buf: LmState,
}

/// Everything decode-related for one resident model: its sessions, its
/// slots, and the persistent step workspaces (`step_state`, `step_logits`,
/// `step_ws` grow to the high-water batch once, after which a warmed
/// steady-state timestep runs the model's zero-allocation
/// [`RnnLm::step_batch_into_exec`] path end to end). In continuous mode,
/// `step_state` is the **resident** decode batch — columns are pushed and
/// swap-removed at timestep boundaries and are never re-gathered.
/// Dropping a lane (LRU eviction) drops the model `Arc` and all its saved
/// session states.
struct ModelLane {
    model: Arc<RnnLm>,
    sessions: SessionStore,
    step_state: LmStateBatch,
    step_logits: OutputBatch,
    step_ws: LmStepWorkspace,
    slots: Vec<SeqSlot>,
    tokens: Vec<usize>,
    /// Lifetime timestep count, lane-local and 1-based at the first step —
    /// the coordinate fault plans address (`panic_lane=NAME@STEP`).
    steps: u64,
}

impl ModelLane {
    fn new(model: Arc<RnnLm>, max_sessions: usize) -> Self {
        let step_state = model.zero_state_batch(0);
        ModelLane {
            model,
            sessions: SessionStore::new(max_sessions),
            step_state,
            step_logits: OutputBatch::zeros(0, 0),
            step_ws: LmStepWorkspace::new(),
            slots: Vec::new(),
            tokens: Vec::new(),
            steps: 0,
        }
    }

    /// Is this session currently resident in a decode slot? O(slots) — the
    /// slot count is small by construction (`max_slots`).
    fn session_decoding(&self, session: u64) -> bool {
        self.slots.iter().any(|s| s.session == session)
    }

    /// Join one request into a free slot: restore (or zero) its session
    /// state, push it as a new column of the resident state batch, and
    /// queue its first input token. O(layers · hidden), at a timestep
    /// boundary only. `deadline` is the server's per-request budget,
    /// anchored at front-end arrival so queue time counts against it.
    fn join_slot(&mut self, req: Request, deadline: Option<Duration>) {
        let Request { session, max_new, prime, model: _, respond, enqueued } = req;
        let queue_us = enqueued.elapsed().as_secs_f64() * 1e6;
        let deadline = deadline.map(|d| enqueued + d);
        let state_buf = self.sessions.take(session).unwrap_or_else(|| self.model.zero_state());
        self.model.push_state_column(&state_buf, &mut self.step_state);
        let mut out = Vec::new();
        // An empty prime (direct-API callers only; the wire protocol
        // requires ≥ 1) decodes from token 0, which is itself emitted —
        // the grouped batcher's historical semantics, preserved exactly.
        let first = match prime.first() {
            Some(&t) => t,
            None => {
                out.push(0);
                0
            }
        };
        self.tokens.push(first);
        self.slots.push(SeqSlot {
            session,
            prime,
            fed: 0,
            out,
            max_new,
            respond,
            queue_us,
            joined: Instant::now(),
            deadline,
            done: false,
            state_buf,
        });
    }

    /// Evict every slot whose deadline passed, replying `ERR DEADLINE`.
    /// Runs between timesteps, so removal is the same column swap-remove a
    /// normal leave does — invisible to the surviving slots' decoding. The
    /// session is NOT saved: the client cannot know how far a half-served
    /// request got, so the only deterministic contract is "as if `END`
    /// arrived" — its next request re-primes from scratch.
    fn expire_due(&mut self, now: Instant, deadline_ms: u128, counters: &Counters) {
        for i in (0..self.slots.len()).rev() {
            if self.slots[i].deadline.is_some_and(|d| now >= d) {
                let slot = self.slots.swap_remove(i);
                self.tokens.swap_remove(i);
                self.model.swap_remove_state_column(&mut self.step_state, i);
                // "As if END arrived" includes the token history: `take`
                // at join already dropped the state, this clears the rest.
                self.sessions.remove(slot.session);
                Counters::inc(&counters.deadline_expirations, 1);
                slot.respond.send(Reply::Error(format!(
                    "DEADLINE request exceeded {deadline_ms}ms deadline"
                )));
            }
        }
    }

    /// Free slot `i` after the timestep that consumed its final token:
    /// extract its state column into the slot's own buffer, swap-remove the
    /// column (the last slot takes index `i` — O(layers · hidden), no
    /// shifting), save the session, and reply.
    fn leave_slot(&mut self, i: usize, counters: &Counters, latency: &LatencyRing) {
        let mut slot = self.slots.swap_remove(i);
        self.tokens.swap_remove(i);
        self.model.scatter_state_into(&self.step_state, i, &mut slot.state_buf);
        self.model.swap_remove_state_column(&mut self.step_state, i);
        let compute_us = slot.joined.elapsed().as_secs_f64() * 1e6;
        Counters::inc(&counters.tokens_generated, slot.out.len() as u64);
        latency.record(Duration::from_secs_f64((slot.queue_us + compute_us) / 1e6));
        self.sessions.put(slot.session, slot.state_buf);
        // Record what this slot fed the model (prime then emissions) so a
        // drain snapshot can show where the session left off.
        self.sessions.append_history(slot.session, &slot.prime);
        self.sessions.append_history(slot.session, &slot.out);
        slot.respond.send(Reply::Gen(Response {
            tokens: slot.out,
            queue_us: slot.queue_us,
            compute_us,
        }));
    }

    /// One lockstep timestep across every occupied slot: batched forward on
    /// the resident state, then per-slot advance (next prime token, or emit
    /// the greedy token), then free the finished slots. Per-timestep
    /// bookkeeping is O(active) for the advance and O(leaves) for the
    /// frees — no per-timestep list rebuilds.
    fn timestep(&mut self, exec: &Exec, counters: &Counters, latency: &LatencyRing) {
        debug_assert_eq!(self.slots.len(), self.tokens.len());
        debug_assert_eq!(self.step_state.batch(), self.slots.len());
        self.model.step_batch_into_exec(
            &self.tokens,
            &mut self.step_state,
            &mut self.step_logits,
            exec,
            &mut self.step_ws,
        );
        Counters::inc(&counters.decode_timesteps, 1);
        let mut any_done = false;
        for i in 0..self.slots.len() {
            let slot = &mut self.slots[i];
            if slot.fed < slot.prime.len() {
                slot.fed += 1; // this step consumed prime[fed]
            }
            if slot.fed < slot.prime.len() {
                self.tokens[i] = slot.prime[slot.fed];
            } else if slot.out.len() >= slot.max_new {
                // The token consumed this step was the last emitted one:
                // the session state is now past it. Finished.
                slot.done = true;
                any_done = true;
            } else {
                // Greedy decode: the next input is this step's argmax, and
                // selecting it *is* emitting it.
                let t = argmax(self.step_logits.row(i));
                slot.out.push(t);
                self.tokens[i] = t;
            }
        }
        if any_done {
            // Reverse order: swap_remove moves an already-visited slot (the
            // last) into the freed index.
            for i in (0..self.slots.len()).rev() {
                if self.slots[i].done {
                    self.leave_slot(i, counters, latency);
                }
            }
        }
    }
}

/// The inference server state machine. Drive it with [`Self::run`] on a
/// dedicated thread, or call [`Self::process_batch`] directly (benches).
///
/// Holds a [`ModelRegistry`] plus one decode lane per resident model
/// (registration order, so iteration — and therefore STATS — is
/// deterministic). The single-model constructors pin their model under
/// the name [`DEFAULT_MODEL`] in an unlimited registry, which reproduces
/// the old single-tenant behavior exactly.
pub struct InferenceServer {
    registry: ModelRegistry,
    /// `(canonical name, lane)` in registration order. Linear scans — the
    /// lane count is "models an operator configured".
    lanes: Vec<(String, ModelLane)>,
    config: BatcherConfig,
    exec: Exec,
    pending: VecDeque<Request>,
    pub latency: Arc<LatencyRing>,
    pub counters: Arc<Counters>,
    /// Shared liveness state; front ends answer `HEALTH` from their clone
    /// of this without ever touching the work channel.
    pub health: Arc<HealthMonitor>,
    /// Set by the first `DRAIN`; new generations answer `ERR DRAINING`.
    draining: bool,
    /// Server birth (STATS `uptime_secs`).
    started: Instant,
    /// Last idle-session sweep; throttles `reap_sessions`.
    last_reap: Instant,
}

impl InferenceServer {
    pub fn new(model: Arc<RnnLm>, config: BatcherConfig) -> Self {
        let exec = Exec::new(config.exec);
        Self::with_exec(model, config, exec)
    }

    /// Single-model server on an existing engine: the model is pinned as
    /// [`DEFAULT_MODEL`] in a fresh unlimited registry.
    pub fn with_exec(model: Arc<RnnLm>, config: BatcherConfig, exec: Exec) -> Self {
        let mut registry = ModelRegistry::new(0);
        // The registry is freshly built and empty, so the one constant,
        // valid name cannot collide — registration is infallible here.
        #[allow(clippy::expect_used)]
        registry.insert_resident(DEFAULT_MODEL, model).expect("'default' is a valid model name");
        Self::with_registry(registry, config, exec)
    }

    /// Build with an existing engine (shares a pool already used to
    /// quantize the model, instead of spawning a second one). The stored
    /// config is normalized to the engine actually running, so
    /// `config.exec` can never disagree with the pool serving requests;
    /// `max_slots = 0` resolves to `max_batch`.
    pub fn with_registry(mut registry: ModelRegistry, mut config: BatcherConfig, exec: Exec) -> Self {
        config.exec = ExecConfig::with_threads(exec.threads());
        if config.max_slots == 0 {
            config.max_slots = config.max_batch;
        }
        registry.set_faults(config.faults.clone());
        let now = Instant::now();
        InferenceServer {
            registry,
            lanes: Vec::new(),
            config,
            exec,
            pending: VecDeque::new(),
            latency: Arc::new(LatencyRing::new(1024)),
            counters: Arc::new(Counters::new()),
            health: Arc::new(HealthMonitor::default()),
            draining: false,
            started: now,
            last_reap: now,
        }
    }

    /// The engine this server runs its batched forwards on.
    pub fn exec(&self) -> &Exec {
        &self.exec
    }

    fn lane(&self, name: &str) -> Option<&ModelLane> {
        self.lanes.iter().find(|(n, _)| n.as_str() == name).map(|(_, l)| l)
    }

    fn lane_mut(&mut self, name: &str) -> Option<&mut ModelLane> {
        self.lanes.iter_mut().find(|(n, _)| n.as_str() == name).map(|(_, l)| l)
    }

    /// Sequences decoding right now, across all models.
    fn total_slots(&self) -> usize {
        self.lanes.iter().map(|(_, l)| l.slots.len()).sum()
    }

    /// Materialize the lane for canonical model `name`: acquire from the
    /// registry (zero-copy load on a cold name), drop any lanes the
    /// registry LRU-evicted to fit the budget (a lane mid-decode is never
    /// a victim), and build the lane if it isn't resident. Err is a
    /// wire-ready message.
    fn ensure_lane(&mut self, name: &str) -> Result<(), String> {
        let lanes = &self.lanes;
        let acquired = self
            .registry
            .acquire(name, |n| !lanes.iter().any(|(ln, l)| ln == n && !l.slots.is_empty()));
        let (model, evicted) = match acquired {
            Ok(v) => v,
            Err(msg) => {
                if msg.starts_with("MODEL_CORRUPT") {
                    Counters::inc(&self.counters.corrupt_loads_rejected, 1);
                }
                return Err(msg);
            }
        };
        for gone in evicted {
            Counters::inc(&self.counters.evictions, 1);
            self.health.lane_gone(&gone);
            self.lanes.retain(|(n, _)| *n != gone);
        }
        if self.lane(name).is_none() {
            self.lanes.push((name.to_string(), ModelLane::new(model, self.config.max_sessions)));
        }
        Ok(())
    }

    /// Admission-time validation for a generation: resolve the model
    /// (loading it if needed) and check every prime token against its
    /// vocab, so an out-of-vocab token answers `ERR` here instead of
    /// panicking in `Embedding::lookup` mid-decode. Rewrites `req.model`
    /// to the canonical name. Err is a wire-ready message.
    fn prepare_gen(&mut self, req: &mut Request) -> Result<(), String> {
        let name = self.registry.resolve(req.model.as_deref())?;
        self.ensure_lane(&name)?;
        let vocab = match self.lane(&name) {
            Some(l) => l.model.config.vocab,
            None => return Err(format!("INTERNAL lane '{name}' missing after ensure")),
        };
        if let Some(&t) = req.prime.iter().find(|&&t| t >= vocab) {
            return Err(format!("token {t} out of vocab {vocab}"));
        }
        req.model = Some(name);
        Ok(())
    }

    /// Blocking work loop; dispatches on the configured batching mode.
    pub fn run(self, rx: Receiver<Work>) {
        if self.config.continuous {
            self.run_continuous(rx)
        } else {
            self.run_grouped(rx)
        }
    }

    /// How often an otherwise-idle loop wakes to run the TTL sweep.
    fn reap_tick(ttl: Duration) -> Duration {
        ttl.clamp(Duration::from_millis(10), Duration::from_secs(1))
    }

    /// Block for the next work item. With a session TTL configured, wake
    /// on the reap tick (sweeping idle sessions) instead of sleeping
    /// forever; `None` = the channel disconnected.
    fn recv_or_reap(&mut self, rx: &Receiver<Work>) -> Option<Work> {
        let Some(ttl) = self.config.session_ttl else {
            return rx.recv().ok();
        };
        loop {
            match rx.recv_timeout(Self::reap_tick(ttl)) {
                Ok(w) => return Some(w),
                Err(RecvTimeoutError::Timeout) => self.reap_sessions(),
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    /// Drop sessions idle past `session_ttl`, throttled to the reap tick
    /// so the hot path isn't scanning session maps every timestep.
    fn reap_sessions(&mut self) {
        let Some(ttl) = self.config.session_ttl else { return };
        let now = Instant::now();
        if now.duration_since(self.last_reap) < Self::reap_tick(ttl) {
            return;
        }
        self.last_reap = now;
        let mut reaped = 0usize;
        for (_, lane) in self.lanes.iter_mut() {
            reaped += lane.sessions.reap_idle(ttl, now);
        }
        if reaped > 0 {
            Counters::inc(&self.counters.sessions_reaped, reaped as u64);
        }
    }

    /// Grouped mode: drain work, collect a batch, run it to completion.
    fn run_grouped(mut self, rx: Receiver<Work>) {
        loop {
            // Block for the first item.
            let first = match self.recv_or_reap(&rx) {
                Some(w) => w,
                None => return,
            };
            let mut gens: Vec<Request> = Vec::new();
            if !self.dispatch_or_collect(first, &mut gens) {
                return;
            }
            // Fill the batch within the wait window.
            let deadline = Instant::now() + self.config.batch_wait;
            while gens.len() < self.config.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(w) => {
                        if !self.dispatch_or_collect(w, &mut gens) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            if !gens.is_empty() {
                self.process_batch(gens);
            }
            self.reap_sessions();
        }
    }

    /// Continuous mode: admit work between timesteps, never a group
    /// barrier. Blocks only when fully idle.
    fn run_continuous(mut self, rx: Receiver<Work>) {
        loop {
            if self.total_slots() == 0 && self.pending.is_empty() {
                // Idle: block until something arrives (or a reap tick).
                match self.recv_or_reap(&rx) {
                    Some(w) => {
                        if !self.absorb(w) {
                            return;
                        }
                    }
                    None => return,
                }
            }
            // Drain whatever else arrived while the last timestep ran.
            loop {
                match rx.try_recv() {
                    Ok(w) => {
                        if !self.absorb(w) {
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if self.total_slots() == 0 && self.pending.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            }
            // Join pending sequences into slots freed by the last
            // timestep's leaves.
            self.health.beat_loop();
            self.reap_sessions();
            self.admit();
            self.timestep_all();
        }
    }

    /// Move pending requests into free slots. Only ever called between
    /// timesteps, so a join always lands exactly at a boundary.
    ///
    /// A request whose session is already decoding in its model's lane is
    /// held back until that slot leaves: per-session generations
    /// serialize, so a client pipelining `GEN`s on one session observes
    /// exactly the sequential state handoff (the second request continues
    /// from the first's final state, never from a stale or zero snapshot).
    /// Held requests keep their queue position relative to their own
    /// session; unrelated sessions may admit past them — no head-of-line
    /// blocking. A queued request whose model was evicted while it waited
    /// triggers a reload here (its registry entry outlives the lane).
    fn admit(&mut self) {
        let mut i = 0;
        while self.total_slots() < self.config.max_slots && i < self.pending.len() {
            // Canonical from `prepare_gen` on the wire path; direct-API
            // callers may leave it unset, meaning the default model.
            let name = match self.pending[i].model.clone() {
                Some(n) => n,
                None => match self.registry.resolve(None) {
                    Ok(n) => n,
                    Err(msg) => {
                        self.fail_pending(i, msg);
                        continue;
                    }
                },
            };
            if self.lane(&name).is_some_and(|l| l.session_decoding(self.pending[i].session)) {
                i += 1;
                continue;
            }
            if let Err(msg) = self.ensure_lane(&name) {
                self.fail_pending(i, msg);
                continue;
            }
            let Some(req) = self.pending.remove(i) else { break };
            let deadline = self.config.request_deadline;
            match self.lane_mut(&name) {
                Some(lane) => lane.join_slot(req, deadline),
                None => {
                    Counters::inc(&self.counters.errors, 1);
                    req.respond.send(Reply::Error(format!(
                        "INTERNAL lane '{name}' missing after ensure"
                    )));
                }
            }
            // `remove` shifted the next unexamined request down to `i`.
        }
    }

    /// Drop pending request `i` with an error reply.
    fn fail_pending(&mut self, i: usize, msg: String) {
        let Some(req) = self.pending.remove(i) else { return };
        Counters::inc(&self.counters.errors, 1);
        req.respond.send(Reply::Error(msg));
    }

    /// Absorb one work item in continuous mode: generations pass model
    /// resolution, vocab validation, and admission control into the
    /// pending queue; everything else answers inline. Returns false on
    /// shutdown.
    fn absorb(&mut self, w: Work) -> bool {
        match w {
            Work::Gen(mut req) => {
                if self.draining {
                    Counters::inc(&self.counters.errors, 1);
                    req.respond.send(Reply::Error(Self::draining_msg()));
                } else if self.pending.len() >= self.config.queue_depth {
                    Counters::inc(&self.counters.shed, 1);
                    req.respond.send(Reply::Busy {
                        queued: self.pending.len(),
                        depth: self.config.queue_depth,
                    });
                } else {
                    Counters::inc(&self.counters.requests, 1);
                    match self.prepare_gen(&mut req) {
                        Ok(()) => {
                            self.pending.push_back(req);
                            // A free slot takes the head of the queue right
                            // away (we are between timesteps here), so
                            // `queue_depth` bounds the wait line, not
                            // slots + line.
                            self.admit();
                        }
                        Err(msg) => {
                            Counters::inc(&self.counters.errors, 1);
                            req.respond.send(Reply::Error(msg));
                        }
                    }
                }
                true
            }
            other => self.control(other),
        }
    }

    /// Handle non-generation work inline; push generations into the batch
    /// (grouped mode). Returns false on shutdown.
    fn dispatch_or_collect(&mut self, w: Work, gens: &mut Vec<Request>) -> bool {
        match w {
            Work::Gen(r) => {
                if self.draining {
                    Counters::inc(&self.counters.errors, 1);
                    r.respond.send(Reply::Error(Self::draining_msg()));
                } else {
                    gens.push(r);
                }
                true
            }
            Work::Drain { respond } => {
                // Finish the group collected so far first, so the drain
                // point is a clean request boundary and those sessions'
                // final states make it into the snapshot.
                if !gens.is_empty() {
                    self.process_batch(std::mem::take(gens));
                }
                self.drain(respond);
                true
            }
            other => self.control(other),
        }
    }

    /// Score / End / Stats / Shutdown — identical in both modes. Returns
    /// false on shutdown.
    fn control(&mut self, w: Work) -> bool {
        match w {
            Work::Gen(_) => unreachable!("generation handled by the mode-specific path"),
            Work::Score { tokens, model, respond } => {
                Counters::inc(&self.counters.requests, 1);
                let reply = self.score(&tokens, model.as_deref());
                if matches!(reply, Reply::Error(_)) {
                    Counters::inc(&self.counters.errors, 1);
                }
                respond.send(reply);
            }
            Work::End { session, model, respond } => {
                // Resolve without materializing: ending a session of an
                // evicted model must not pull it back off disk (its
                // sessions died with the lane anyway).
                let reply = match self.registry.resolve(model.as_deref()) {
                    Ok(name) => {
                        Reply::End(self.lane_mut(&name).is_some_and(|l| l.sessions.remove(session)))
                    }
                    Err(msg) => {
                        Counters::inc(&self.counters.errors, 1);
                        Reply::Error(msg)
                    }
                };
                respond.send(reply);
            }
            Work::Stats { text, respond } => {
                respond.send(Reply::Stats(self.stats_payload(text)));
            }
            Work::Reload { model, respond } => {
                let reply = self.reload_model(&model);
                if matches!(reply, Reply::Error(_)) {
                    Counters::inc(&self.counters.errors, 1);
                }
                respond.send(reply);
            }
            Work::Drain { respond } => self.drain(respond),
            Work::Shutdown => return false,
        }
        true
    }

    /// Operator `RELOAD <name>`: clear a poison quarantine and re-publish
    /// the model (eager `.amqz` re-read for path-backed entries — a
    /// corrupt file fails here, and the quarantine stays). The old lane —
    /// with any saved sessions — is dropped: the reload is a fresh start,
    /// exactly like an eviction. A lane mid-decode refuses, to avoid
    /// tearing state out from under in-flight requests.
    fn reload_model(&mut self, name: &str) -> Reply {
        let canonical = match self.registry.resolve(Some(name)) {
            Ok(c) => c,
            Err(msg) => return Reply::Error(msg),
        };
        if self.lane(&canonical).is_some_and(|l| !l.slots.is_empty())
            || self.pending.iter().any(|r| r.model.as_deref() == Some(canonical.as_str()))
        {
            return Reply::Error(format!(
                "model '{canonical}' is mid-decode; retry RELOAD when idle"
            ));
        }
        self.lanes.retain(|(n, _)| *n != canonical);
        let lanes = &self.lanes;
        let reloaded = self
            .registry
            .reload(&canonical, |n| !lanes.iter().any(|(ln, l)| ln == n && !l.slots.is_empty()));
        match reloaded {
            Ok((model, evicted)) => {
                for gone in evicted {
                    Counters::inc(&self.counters.evictions, 1);
                    self.health.lane_gone(&gone);
                    self.lanes.retain(|(n, _)| *n != gone);
                }
                self.lanes
                    .push((canonical.clone(), ModelLane::new(model, self.config.max_sessions)));
                Reply::Reloaded(canonical)
            }
            Err(msg) => {
                if msg.starts_with("MODEL_CORRUPT") {
                    Counters::inc(&self.counters.corrupt_loads_rejected, 1);
                }
                Reply::Error(msg)
            }
        }
    }

    /// The wire-ready refusal every generation gets once a drain started.
    fn draining_msg() -> String {
        "DRAINING server is draining; retry against another instance".to_string()
    }

    /// `DRAIN` (wire verb or SIGTERM): stop admitting generations, run the
    /// in-flight decodes to completion up to `drain_deadline` — the same
    /// timestep loop as normal serving, so finishing under drain is
    /// bit-exact — then snapshot every saved session to `snapshot_path`.
    /// Stragglers past the deadline answer `ERR DRAINING` and their
    /// sessions drop (the client cannot know how far they got). The queue
    /// is flushed the same way. Non-generation verbs keep working after.
    fn drain(&mut self, respond: Respond) {
        let Some(path) = self.config.snapshot_path.clone() else {
            Counters::inc(&self.counters.errors, 1);
            respond.send(Reply::Error(
                "DRAINING no snapshot path configured (start with --snapshot <path>)".into(),
            ));
            return;
        };
        self.draining = true;
        self.health.set_draining();
        let cutoff = Instant::now() + self.config.drain_deadline;
        while self.total_slots() > 0 && Instant::now() < cutoff {
            self.timestep_all();
        }
        for (_, lane) in self.lanes.iter_mut() {
            while let Some(i) = lane.slots.len().checked_sub(1) {
                let slot = lane.slots.swap_remove(i);
                lane.tokens.swap_remove(i);
                lane.model.swap_remove_state_column(&mut lane.step_state, i);
                lane.sessions.remove(slot.session);
                Counters::inc(&self.counters.errors, 1);
                slot.respond.send(Reply::Error(Self::draining_msg()));
            }
        }
        while let Some(req) = self.pending.pop_front() {
            Counters::inc(&self.counters.errors, 1);
            req.respond.send(Reply::Error(Self::draining_msg()));
        }
        match self.snapshot_sessions(&path) {
            Ok(count) => {
                Counters::inc(&self.counters.drains, 1);
                Counters::inc(&self.counters.sessions_snapshotted, count);
                respond
                    .send(Reply::Drained { sessions: count, path: path.display().to_string() });
            }
            Err(msg) => {
                Counters::inc(&self.counters.errors, 1);
                respond.send(Reply::Error(msg));
            }
        }
    }

    /// Serialize every saved session (state + capped history) to `path`,
    /// sorted by session id within each lane so identical server states
    /// produce identical snapshot bytes. Lanes whose registry entry is
    /// poisoned are skipped with a counted warning: a panic may have left
    /// their states damaged, and faithfully restoring damage is still
    /// damage.
    fn snapshot_sessions(&mut self, path: &Path) -> Result<u64, String> {
        let mut snapshot = SessionSnapshot::default();
        let mut count = 0u64;
        let mut skipped = 0usize;
        for (name, lane) in &self.lanes {
            if self.registry.entries().iter().any(|e| e.name == *name && e.poisoned) {
                skipped += 1;
                eprintln!(
                    "drain: skipping poisoned lane '{name}' ({} sessions not snapshotted)",
                    lane.sessions.len()
                );
                continue;
            }
            let cfg = lane.model.config;
            let mut sessions: Vec<SessionRecord> = lane
                .sessions
                .iter()
                .map(|(id, state, history)| SessionRecord {
                    id,
                    history: history.to_vec(),
                    state: state.flatten(),
                })
                .collect();
            sessions.sort_by_key(|s| s.id);
            count += sessions.len() as u64;
            snapshot.models.push(ModelSessions {
                model: name.clone(),
                kind: cfg.kind,
                layers: cfg.layers,
                hidden: cfg.hidden,
                sessions,
            });
        }
        if skipped > 0 {
            eprintln!("drain: {skipped} poisoned lane(s) skipped");
        }
        snapshot.save(path).map_err(|e| format!("DRAINING snapshot failed: {e:#}"))?;
        Ok(count)
    }

    /// Revive sessions from a drain snapshot (`--restore <path>`). Must
    /// run before serving starts: a server that already holds sessions or
    /// in-flight work refuses the whole restore (a dirty restore would
    /// silently mix two histories). Every snapshotted model must resolve
    /// to a lane with exactly the shape the states were saved under.
    /// Restored states are bit-exact — a revived session's next tokens
    /// equal an uninterrupted run's.
    pub fn restore_sessions(&mut self, path: &Path) -> Result<u64, String> {
        if self.total_slots() > 0
            || !self.pending.is_empty()
            || self.lanes.iter().any(|(_, l)| !l.sessions.is_empty())
        {
            return Err("dirty restore refused: server already has live sessions".into());
        }
        let snapshot = SessionSnapshot::load(path)
            .map_err(|e| format!("restoring {}: {e:#}", path.display()))?;
        let mut count = 0u64;
        for m in snapshot.models {
            let name = self.registry.resolve(Some(&m.model))?;
            self.ensure_lane(&name)?;
            let Some(lane) = self.lane_mut(&name) else {
                return Err(format!("INTERNAL lane '{name}' missing after ensure"));
            };
            let cfg = lane.model.config;
            if cfg.kind != m.kind || cfg.layers != m.layers || cfg.hidden != m.hidden {
                return Err(format!(
                    "snapshot model '{}' is shaped {:?}/{} layers/{} hidden but the serving \
                     model is {:?}/{} layers/{} hidden; refusing to restore mismatched states",
                    m.model, m.kind, m.layers, m.hidden, cfg.kind, cfg.layers, cfg.hidden
                ));
            }
            for s in m.sessions {
                let state = LmState::from_flat(cfg.kind, cfg.layers, cfg.hidden, &s.state)?;
                lane.sessions.restore(s.id, state, s.history);
                count += 1;
            }
        }
        Counters::inc(&self.counters.sessions_restored, count);
        Ok(count)
    }

    /// SCORE with the same admission-time model resolution and vocab
    /// validation as generations (`RnnLm::ppw` embeds every token).
    fn score(&mut self, tokens: &[usize], model: Option<&str>) -> Reply {
        let name = match self.registry.resolve(model) {
            Ok(n) => n,
            Err(msg) => return Reply::Error(msg),
        };
        if let Err(msg) = self.ensure_lane(&name) {
            return Reply::Error(msg);
        }
        let lane_model = match self.lane(&name) {
            Some(l) => Arc::clone(&l.model),
            None => return Reply::Error(format!("INTERNAL lane '{name}' missing after ensure")),
        };
        let vocab = lane_model.config.vocab;
        if let Some(&t) = tokens.iter().find(|&&t| t >= vocab) {
            return Reply::Error(format!("token {t} out of vocab {vocab}"));
        }
        Reply::Score(lane_model.ppw(tokens))
    }

    /// The `STATS` payload: single-line JSON, or the human-readable line
    /// behind `STATS TEXT`. Session and eviction counts sum over lanes;
    /// the `models` object reports per-model residency in registration
    /// order.
    fn stats_payload(&self, text: bool) -> String {
        let snap = self.latency.snapshot();
        let c = &self.counters;
        let sessions: usize = self.lanes.iter().map(|(_, l)| l.sessions.len()).sum();
        let session_evictions: u64 = self.lanes.iter().map(|(_, l)| l.sessions.evictions).sum();
        let uptime_secs = self.started.elapsed().as_secs();
        let faults_injected = self.config.faults.as_ref().map_or(0, |f| f.injected());
        if text {
            return format!(
                "{} uptime={}s requests={} tokens={} batches={} timesteps={} shed={} errors={} \
                 active={} queued={} evictions={} sessions={} models={} model_evictions={} \
                 lane_panics={} deadline_expirations={} sessions_reaped={} write_stall_closes={} \
                 faults_injected={} drains={} sessions_snapshotted={} sessions_restored={} \
                 corrupt_loads_rejected={} health={} mode={} kernel={} l2_kb={} threads={}",
                snap.report("latency"),
                uptime_secs,
                Counters::get(&c.requests),
                Counters::get(&c.tokens_generated),
                Counters::get(&c.batches),
                Counters::get(&c.decode_timesteps),
                Counters::get(&c.shed),
                Counters::get(&c.errors),
                self.total_slots(),
                self.pending.len(),
                session_evictions,
                sessions,
                self.registry.entries().len(),
                self.registry.total_evictions,
                Counters::get(&c.lane_panics),
                Counters::get(&c.deadline_expirations),
                Counters::get(&c.sessions_reaped),
                Counters::get(&c.write_stall_closes),
                faults_injected,
                Counters::get(&c.drains),
                Counters::get(&c.sessions_snapshotted),
                Counters::get(&c.sessions_restored),
                Counters::get(&c.corrupt_loads_rejected),
                self.health.status().0,
                if self.config.continuous { "continuous" } else { "grouped" },
                crate::kernels::backend::describe(crate::kernels::backend::active()),
                crate::kernels::cost::l2_bytes() / 1024,
                self.exec.threads(),
            );
        }
        let mut models = String::from("{");
        for (i, e) in self.registry.entries().iter().enumerate() {
            if i > 0 {
                models.push(',');
            }
            let (slots, lane_sessions) =
                self.lane(&e.name).map_or((0, 0), |l| (l.slots.len(), l.sessions.len()));
            let _ = write!(
                models,
                "\"{}\":{{\"resident\":{},\"bytes\":{},\"slots\":{},\"sessions\":{},\
                 \"hits\":{},\"loads\":{},\"evictions\":{}}}",
                e.name,
                e.resident(),
                e.bytes,
                slots,
                lane_sessions,
                e.hits,
                e.loads,
                e.evictions,
            );
        }
        models.push('}');
        // NaN (empty latency window) is not valid JSON; report zeros.
        let f = |v: f64| if v.is_finite() { v } else { 0.0 };
        format!(
            "{{\"mode\":\"{}\",\"uptime_secs\":{},\"active_slots\":{},\"max_slots\":{},\
             \"queued\":{},\
             \"queue_depth\":{},\"shed\":{},\"errors\":{},\"requests\":{},\
             \"tokens_generated\":{},\"batches\":{},\"decode_timesteps\":{},\"sessions\":{},\
             \"evictions\":{},\"models\":{},\"model_evictions\":{},\
             \"lane_panics\":{},\"deadline_expirations\":{},\"sessions_reaped\":{},\
             \"write_stall_closes\":{},\"faults_injected\":{},\
             \"drains\":{},\"sessions_snapshotted\":{},\"sessions_restored\":{},\
             \"corrupt_loads_rejected\":{},\"health\":\"{}\",\
             \"kernel\":\"{}\",\"l2_kb\":{},\"threads\":{},\
             \"latency_us\":{{\"count\":{},\"window\":{},\
             \"mean\":{:.1},\"p50\":{:.1},\"p95\":{:.1},\"p99\":{:.1},\"max\":{:.1}}}}}",
            if self.config.continuous { "continuous" } else { "grouped" },
            uptime_secs,
            self.total_slots(),
            self.config.max_slots,
            self.pending.len(),
            self.config.queue_depth,
            Counters::get(&c.shed),
            Counters::get(&c.errors),
            Counters::get(&c.requests),
            Counters::get(&c.tokens_generated),
            Counters::get(&c.batches),
            Counters::get(&c.decode_timesteps),
            sessions,
            session_evictions,
            models,
            self.registry.total_evictions,
            Counters::get(&c.lane_panics),
            Counters::get(&c.deadline_expirations),
            Counters::get(&c.sessions_reaped),
            Counters::get(&c.write_stall_closes),
            faults_injected,
            Counters::get(&c.drains),
            Counters::get(&c.sessions_snapshotted),
            Counters::get(&c.sessions_restored),
            Counters::get(&c.corrupt_loads_rejected),
            self.health.status().0,
            crate::kernels::backend::describe(crate::kernels::backend::active()),
            crate::kernels::cost::l2_bytes() / 1024,
            self.exec.threads(),
            snap.count,
            snap.count.min(self.latency.capacity()),
            f(snap.mean_us),
            f(snap.p50_us),
            f(snap.p95_us),
            f(snap.p99_us),
            f(snap.max_us),
        )
    }

    /// One timestep on every lane with occupied slots. Lanes step in
    /// registration order — deterministic, and independent (different
    /// models share nothing but the worker pool).
    ///
    /// Two containment layers wrap the step. First, with a request
    /// deadline configured, expired slots (and expired pending requests)
    /// are evicted *before* stepping — a removal at the boundary is
    /// exactly a normal leave, so surviving slots decode bit-identically
    /// to a run without the expired request. Second, each lane's step runs
    /// under `catch_unwind`: a panicking lane is quarantined (dropped,
    /// in-flight sessions failed, registry entry poisoned) and every other
    /// lane — and the batcher thread itself — keeps going.
    /// `AssertUnwindSafe` is sound because a poisoned lane is discarded
    /// wholesale below, never observed again in a broken state.
    fn timestep_all(&mut self) {
        self.health.beat_loop();
        if let Some(d) = self.config.request_deadline {
            self.expire_deadlines(d);
        }
        let mut poisoned: Vec<String> = Vec::new();
        {
            let exec = &self.exec;
            let counters = &self.counters;
            let latency = &self.latency;
            let health = &self.health;
            let faults = self.config.faults.as_deref();
            for (name, lane) in self.lanes.iter_mut() {
                if lane.slots.is_empty() {
                    continue;
                }
                lane.steps += 1;
                let step = lane.steps;
                let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                    if let Some(f) = faults {
                        f.on_lane_step(name, step);
                    }
                    lane.timestep(exec, counters, latency);
                }));
                if outcome.is_err() {
                    poisoned.push(name.clone());
                } else {
                    // Post-step beat: a stalled or wedged step never beats,
                    // which is exactly what flips HEALTH to degraded.
                    health.beat_lane(name, lane.steps, lane.slots.len());
                }
            }
        }
        for name in poisoned {
            self.quarantine(&name);
        }
    }

    /// Evict every expired decode slot and pending request with
    /// `ERR DEADLINE`. Runs only when a deadline is configured.
    fn expire_deadlines(&mut self, deadline: Duration) {
        let now = Instant::now();
        let ms = deadline.as_millis();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].enqueued + deadline <= now {
                if let Some(req) = self.pending.remove(i) {
                    Counters::inc(&self.counters.deadline_expirations, 1);
                    req.respond
                        .send(Reply::Error(format!("DEADLINE request exceeded {ms}ms deadline")));
                }
            } else {
                i += 1;
            }
        }
        for (_, lane) in self.lanes.iter_mut() {
            lane.expire_due(now, ms, &self.counters);
        }
    }

    /// A lane panicked mid-timestep. Its decode state is unreconstructable
    /// (the panic may have landed anywhere inside the batched forward), so
    /// the blast radius is exactly the lane: every in-flight session
    /// answers `ERR INTERNAL`, the lane — including the model's saved
    /// session states, which share its fate like they do on eviction — is
    /// dropped, and the registry entry is poisoned so later requests get
    /// `ERR MODEL_POISONED` instead of rebuilding a lane on a model that
    /// just proved it can panic. `RELOAD <name>` re-publishes it.
    fn quarantine(&mut self, name: &str) {
        Counters::inc(&self.counters.lane_panics, 1);
        self.registry.poison(name);
        self.health.lane_gone(name);
        eprintln!("lane '{name}' poisoned by a panic; quarantined until RELOAD {name}");
        if let Some(i) = self.lanes.iter().position(|(n, _)| n == name) {
            let (_, lane) = self.lanes.remove(i);
            for slot in lane.slots {
                Counters::inc(&self.counters.errors, 1);
                slot.respond.send(Reply::Error(format!("INTERNAL lane {name} poisoned")));
            }
        }
    }

    /// Run one batch of generation requests in lockstep and reply to each —
    /// grouped mode's inner loop, and the direct entry point for benches.
    ///
    /// Runs on the same slot machinery as continuous mode (join all, step
    /// until every slot leaves), so every timestep is a **true batched
    /// forward** ([`RnnLm::step_batch_into_exec`] on the server's worker
    /// pool and persistent workspaces) and finished sequences free their
    /// column mid-group instead of being rescanned every timestep. Because
    /// the `_into` path bit-matches per-session `step` for any batch
    /// composition and thread count, neither batching, threading, nor
    /// buffer reuse is visible to clients: a session generates the same
    /// tokens regardless of who it was batched with or how many cores
    /// served it. Requests resolving to different models join different
    /// lanes and step side by side.
    pub fn process_batch(&mut self, batch: Vec<Request>) {
        Counters::inc(&self.counters.batches, 1);
        Counters::inc(&self.counters.requests, batch.len() as u64);
        debug_assert!(self.total_slots() == 0, "grouped mode runs one batch at a time");
        let deadline = self.config.request_deadline;
        for mut req in batch {
            match self.prepare_gen(&mut req) {
                Ok(()) => {
                    // `prepare_gen` set the canonical name and ensured the
                    // lane; a miss here is an internal invariant failure.
                    let name = req.model.clone().unwrap_or_default();
                    match self.lane_mut(&name) {
                        Some(lane) => lane.join_slot(req, deadline),
                        None => {
                            Counters::inc(&self.counters.errors, 1);
                            req.respond.send(Reply::Error(format!(
                                "INTERNAL lane '{name}' missing after prepare"
                            )));
                        }
                    }
                }
                Err(msg) => {
                    Counters::inc(&self.counters.errors, 1);
                    req.respond.send(Reply::Error(msg));
                }
            }
        }
        while self.total_slots() > 0 {
            self.timestep_all();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy, RnnKind};
    use std::sync::mpsc;

    fn tiny_config() -> BatcherConfig {
        BatcherConfig { max_batch: 4, ..Default::default() }
    }

    fn tiny_model() -> RnnLm {
        RnnLm::random(
            LmConfig { kind: RnnKind::Lstm, vocab: 40, hidden: 16, layers: 1 },
            5,
            PrecisionPolicy::quantized(2, 2),
        )
    }

    fn tiny_server_with(config: BatcherConfig) -> InferenceServer {
        InferenceServer::new(Arc::new(tiny_model()), config)
    }

    fn tiny_server() -> InferenceServer {
        tiny_server_with(tiny_config())
    }

    fn gen_req(
        session: u64,
        max_new: usize,
        prime: Vec<usize>,
    ) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                session,
                max_new,
                prime,
                model: None,
                respond: Respond::Channel(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn recv_gen(rx: &mpsc::Receiver<Reply>) -> Response {
        match rx.recv().unwrap() {
            Reply::Gen(r) => r,
            other => panic!("expected Reply::Gen, got {other:?}"),
        }
    }

    #[test]
    fn batch_generates_requested_lengths() {
        let mut s = tiny_server();
        let (r1, rx1) = gen_req(1, 5, vec![1, 2]);
        let (r2, rx2) = gen_req(2, 3, vec![7]);
        s.process_batch(vec![r1, r2]);
        assert_eq!(recv_gen(&rx1).tokens.len(), 5);
        assert_eq!(recv_gen(&rx2).tokens.len(), 3);
        assert_eq!(Counters::get(&s.counters.tokens_generated), 8);
    }

    #[test]
    fn oov_prime_is_rejected_instead_of_panicking() {
        // vocab = 40: token 40 is the first invalid id. Before admission
        // validation this panicked the batcher thread inside
        // Embedding::lookup; now it must answer Reply::Error and keep the
        // in-batch valid request unaffected.
        let mut s = tiny_server();
        let (bad, bad_rx) = gen_req(1, 4, vec![2, 40, 3]);
        let (good, good_rx) = gen_req(2, 4, vec![2, 3]);
        s.process_batch(vec![bad, good]);
        match bad_rx.recv().unwrap() {
            Reply::Error(msg) => assert_eq!(msg, "token 40 out of vocab 40"),
            other => panic!("{other:?}"),
        }
        assert_eq!(recv_gen(&good_rx).tokens.len(), 4);
        assert_eq!(Counters::get(&s.counters.errors), 1);

        // Same check on the continuous absorb path, plus SCORE.
        let s = tiny_server_with(BatcherConfig { continuous: true, ..tiny_config() });
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let (bad, bad_rx) = gen_req(3, 4, vec![99]);
        tx.send(Work::Gen(bad)).unwrap();
        match bad_rx.recv().unwrap() {
            Reply::Error(msg) => assert_eq!(msg, "token 99 out of vocab 40"),
            other => panic!("{other:?}"),
        }
        let (stx, srx) = mpsc::channel();
        tx.send(Work::Score {
            tokens: vec![1, 40],
            model: None,
            respond: Respond::Channel(stx),
        })
        .unwrap();
        match srx.recv().unwrap() {
            Reply::Error(msg) => assert_eq!(msg, "token 40 out of vocab 40"),
            other => panic!("{other:?}"),
        }
        // The thread is still alive and serving.
        let (gtx, grx) = gen_req(4, 3, vec![1]);
        tx.send(Work::Gen(gtx)).unwrap();
        assert_eq!(recv_gen(&grx).tokens.len(), 3);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn unknown_model_answers_error() {
        let mut s = tiny_server();
        let (tx, rx) = mpsc::channel();
        let req = Request {
            session: 1,
            max_new: 3,
            prime: vec![1],
            model: Some("nope".into()),
            respond: Respond::Channel(tx),
            enqueued: Instant::now(),
        };
        s.process_batch(vec![req]);
        match rx.recv().unwrap() {
            Reply::Error(msg) => assert_eq!(msg, "unknown model 'nope'"),
            other => panic!("{other:?}"),
        }
        // Named default still works.
        let (tx, rx) = mpsc::channel();
        let req = Request {
            session: 1,
            max_new: 3,
            prime: vec![1],
            model: Some(DEFAULT_MODEL.into()),
            respond: Respond::Channel(tx),
            enqueued: Instant::now(),
        };
        s.process_batch(vec![req]);
        assert!(matches!(rx.recv().unwrap(), Reply::Gen(_)));
    }

    #[test]
    fn sessions_continue_deterministically() {
        // Generating 6 tokens in one request == 3 + 3 across two requests
        // with the same session (state is preserved server-side).
        let mut a = tiny_server();
        let (r, rx) = gen_req(9, 6, vec![4]);
        a.process_batch(vec![r]);
        let whole = recv_gen(&rx).tokens;

        let mut b = tiny_server();
        let (r1, rx1) = gen_req(9, 3, vec![4]);
        b.process_batch(vec![r1]);
        let first = recv_gen(&rx1).tokens;
        // Continue: prime with the token the first half ended on (whole[2]
        // was the last emitted; server state already consumed it).
        let (r2, rx2) = gen_req(9, 3, vec![whole[2]]);
        b.process_batch(vec![r2]);
        let second = recv_gen(&rx2).tokens;
        assert_eq!(first[..], whole[..3]);
        assert_eq!(second.len(), 3);
    }

    #[test]
    fn pipelined_same_session_requests_serialize() {
        // Sequential reference: two generations on one session, one at a
        // time (the second continues the first's saved state).
        let mut a = tiny_server();
        let (r1, rx1) = gen_req(7, 5, vec![3, 8]);
        a.process_batch(vec![r1]);
        let first_ref = recv_gen(&rx1).tokens;
        let (r2, rx2) = gen_req(7, 4, vec![11]);
        a.process_batch(vec![r2]);
        let second_ref = recv_gen(&rx2).tokens;

        // Continuous server with plenty of free slots and both requests
        // queued before it starts. Admission must hold the second back
        // until the first leaves its slot (same session) — not decode
        // both concurrently from a stale/zero state snapshot.
        let s = tiny_server_with(BatcherConfig {
            max_batch: 4,
            continuous: true,
            max_slots: 4,
            ..Default::default()
        });
        let (tx, rx) = mpsc::channel();
        let (r1, rx1) = gen_req(7, 5, vec![3, 8]);
        let (r2, rx2) = gen_req(7, 4, vec![11]);
        tx.send(Work::Gen(r1)).unwrap();
        tx.send(Work::Gen(r2)).unwrap();
        let handle = std::thread::spawn(move || s.run(rx));
        assert_eq!(recv_gen(&rx1).tokens, first_ref, "first request must match sequential");
        assert_eq!(recv_gen(&rx2).tokens, second_ref, "pipelined continuation must serialize");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn run_loop_end_to_end_with_shutdown() {
        let s = tiny_server();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let (g, grx) = gen_req(1, 4, vec![2, 3]);
        tx.send(Work::Gen(g)).unwrap();
        assert_eq!(recv_gen(&grx).tokens.len(), 4);
        let (stx, srx) = mpsc::channel();
        tx.send(Work::Score {
            tokens: vec![1, 2, 3, 4],
            model: None,
            respond: Respond::Channel(stx),
        })
        .unwrap();
        match srx.recv().unwrap() {
            Reply::Score(ppw) => assert!(ppw > 1.0),
            other => panic!("{other:?}"),
        }
        let (etx, erx) = mpsc::channel();
        tx.send(Work::End { session: 1, model: None, respond: Respond::Channel(etx) }).unwrap();
        assert!(matches!(erx.recv().unwrap(), Reply::End(true)));
        // JSON stats by default, the human-readable line behind text=true.
        let (mtx, mrx) = mpsc::channel();
        tx.send(Work::Stats { text: false, respond: Respond::Channel(mtx) }).unwrap();
        let Reply::Stats(stats) = mrx.recv().unwrap() else { panic!() };
        assert!(stats.starts_with('{') && stats.ends_with('}'), "{stats}");
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(stats.contains("\"mode\":\"grouped\""), "{stats}");
        assert!(stats.contains("\"kernel\":\"") && stats.contains("\"threads\":"), "{stats}");
        assert!(stats.contains("\"l2_kb\":"), "{stats}");
        assert!(stats.contains("\"latency_us\":{\"count\":1,"), "{stats}");
        assert!(stats.contains("\"errors\":0"), "{stats}");
        assert!(
            stats.contains("\"models\":{\"default\":{\"resident\":true,"),
            "{stats}"
        );
        assert!(stats.contains("\"model_evictions\":0"), "{stats}");
        let (mtx, mrx) = mpsc::channel();
        tx.send(Work::Stats { text: true, respond: Respond::Channel(mtx) }).unwrap();
        let Reply::Stats(stats) = mrx.recv().unwrap() else { panic!() };
        assert!(stats.contains("requests=2"), "{stats}");
        assert!(stats.contains("kernel=") && stats.contains("threads="), "{stats}");
        assert!(stats.contains("l2_kb="), "{stats}");
        assert!(stats.contains("models=1"), "{stats}");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn threaded_batcher_bitmatches_serial_batcher() {
        // The same requests against the same seed model must generate the
        // same tokens whether the forward runs on 1 thread or a pool.
        let run = |exec: ExecConfig| {
            let mut s = InferenceServer::new(
                Arc::new(tiny_model()),
                BatcherConfig { max_batch: 4, exec, ..Default::default() },
            );
            let mut rxs = Vec::new();
            let mut reqs = Vec::new();
            for i in 0..3u64 {
                let (r, rx) = gen_req(i, 4 + i as usize, vec![(3 * i + 1) as usize]);
                reqs.push(r);
                rxs.push(rx);
            }
            s.process_batch(reqs);
            rxs.iter().map(|rx| recv_gen(rx).tokens).collect::<Vec<_>>()
        };
        let serial = run(ExecConfig::serial());
        for threads in [2usize, 3, 8] {
            assert_eq!(run(ExecConfig::with_threads(threads)), serial, "threads={threads}");
        }
    }

    #[test]
    fn batcher_collects_up_to_max_batch() {
        let s = tiny_server();
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (g, grx) = gen_req(i, 2, vec![1]);
            tx.send(Work::Gen(g)).unwrap();
            rxs.push(grx);
        }
        for rx in rxs {
            assert_eq!(recv_gen(&rx).tokens.len(), 2);
        }
        // All four must have been served in at most 2 batch flushes (the
        // first may fire alone depending on scheduling).
        assert!(Counters::get(&counters.batches) <= 4);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn continuous_bitmatches_grouped_and_serial() {
        // Staggered sessions with different lengths joining and leaving
        // mid-decode must produce exactly the tokens a max_batch = 1
        // grouped reference produces per session.
        let scripts: Vec<(u64, usize, Vec<usize>)> = (0..6)
            .map(|i| (i as u64, 3 + (i % 4), vec![(3 * i + 1) % 40, (7 * i + 2) % 40]))
            .collect();

        // Sequential reference: one request at a time, grouped server.
        let mut reference = Vec::new();
        {
            let mut s = tiny_server_with(BatcherConfig { max_batch: 1, ..Default::default() });
            for (sess, max_new, prime) in &scripts {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                s.process_batch(vec![r]);
                reference.push(recv_gen(&rx).tokens);
            }
        }

        // Continuous server, all requests in flight at once with a tiny
        // slot budget so joins/leaves happen mid-decode.
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            max_slots: 2,
            queue_depth: 64,
            ..Default::default()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let rxs: Vec<_> = scripts
            .iter()
            .map(|(sess, max_new, prime)| {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                tx.send(Work::Gen(r)).unwrap();
                rx
            })
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            assert_eq!(recv_gen(rx).tokens, reference[i], "session {i} diverged");
        }
        assert!(Counters::get(&counters.decode_timesteps) > 0);
        assert_eq!(Counters::get(&counters.shed), 0);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn lane_panic_quarantines_and_reload_recovers() {
        // Clean reference tokens for the post-recovery request.
        let mut r = tiny_server_with(BatcherConfig { max_batch: 1, ..Default::default() });
        let (req, rx) = gen_req(50, 4, vec![6, 7]);
        r.process_batch(vec![req]);
        let reference = recv_gen(&rx).tokens;

        // Victim lane: prime 2 + decode — alive well past step 3, where
        // the injected panic fires inside the catch_unwind seam.
        let plan = Arc::new(FaultPlan::parse("panic_lane=default@3").unwrap());
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            faults: Some(Arc::clone(&plan)),
            ..tiny_config()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));

        let (victim, victim_rx) = gen_req(1, 10, vec![1, 2]);
        tx.send(Work::Gen(victim)).unwrap();
        match victim_rx.recv().unwrap() {
            Reply::Error(msg) => assert_eq!(msg, "INTERNAL lane default poisoned"),
            other => panic!("{other:?}"),
        }
        assert_eq!(Counters::get(&counters.lane_panics), 1);

        // The batcher thread survived; the model is quarantined.
        let (next, next_rx) = gen_req(2, 3, vec![1]);
        tx.send(Work::Gen(next)).unwrap();
        match next_rx.recv().unwrap() {
            Reply::Error(msg) => assert!(msg.starts_with("MODEL_POISONED "), "{msg}"),
            other => panic!("{other:?}"),
        }

        // RELOAD clears the quarantine (pinned model: no disk involved)
        // and a fresh session decodes bit-exactly.
        let (rtx, rrx) = mpsc::channel();
        tx.send(Work::Reload { model: DEFAULT_MODEL.into(), respond: Respond::Channel(rtx) })
            .unwrap();
        match rrx.recv().unwrap() {
            Reply::Reloaded(name) => assert_eq!(name, DEFAULT_MODEL),
            other => panic!("{other:?}"),
        }
        let (fresh, fresh_rx) = gen_req(50, 4, vec![6, 7]);
        tx.send(Work::Gen(fresh)).unwrap();
        assert_eq!(recv_gen(&fresh_rx).tokens, reference, "post-recovery decode diverged");
        assert_eq!(plan.injected(), 1, "exactly the one planned panic fired");

        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn deadline_expiry_is_bit_neutral_to_cobatched_requests() {
        // Sequential reference for the three short requests, no victim,
        // no faults, no deadline.
        let scripts: Vec<(u64, usize, Vec<usize>)> =
            (0..3).map(|i| (i as u64, 3, vec![(3 * i + 1) % 40, (7 * i + 2) % 40])).collect();
        let mut reference = Vec::new();
        {
            let mut s = tiny_server_with(BatcherConfig { max_batch: 1, ..Default::default() });
            for (sess, max_new, prime) in &scripts {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                s.process_batch(vec![r]);
                reference.push(recv_gen(&rx).tokens);
            }
        }

        // Faulted run: a long victim co-batched with the shorts. The
        // shorts finish by lane step 5; at step 7 an injected stall holds
        // the lane 2500ms, pushing the victim past its 1000ms deadline —
        // it must leave with ERR DEADLINE at the next boundary while the
        // shorts' tokens (already emitted) match the reference exactly.
        // (The deadline is generous so CI scheduling jitter before the
        // loop's first timestep can't expire the short requests.)
        let plan = Arc::new(FaultPlan::parse("stall_lane=default@7:2500").unwrap());
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            max_slots: 8,
            request_deadline: Some(Duration::from_millis(1000)),
            faults: Some(Arc::clone(&plan)),
            ..tiny_config()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let (victim, victim_rx) = gen_req(99, 3000, vec![5, 6]);
        tx.send(Work::Gen(victim)).unwrap();
        let rxs: Vec<_> = scripts
            .iter()
            .map(|(sess, max_new, prime)| {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                tx.send(Work::Gen(r)).unwrap();
                rx
            })
            .collect();
        let handle = std::thread::spawn(move || s.run(rx));
        for (i, rx) in rxs.iter().enumerate() {
            assert_eq!(recv_gen(rx).tokens, reference[i], "co-batched session {i} diverged");
        }
        match victim_rx.recv().unwrap() {
            Reply::Error(msg) => {
                assert_eq!(msg, "DEADLINE request exceeded 1000ms deadline");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Counters::get(&counters.deadline_expirations), 1);
        assert_eq!(plan.injected(), 1, "the stall fired once");

        // The victim's session dropped as if END arrived: a follow-up on
        // the same id re-primes from scratch, deterministically.
        let (end_tx, end_rx) = mpsc::channel();
        tx.send(Work::End { session: 99, model: None, respond: Respond::Channel(end_tx) })
            .unwrap();
        assert!(matches!(end_rx.recv().unwrap(), Reply::End(false)));
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn idle_sessions_reap_after_ttl() {
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            session_ttl: Some(Duration::from_millis(50)),
            ..tiny_config()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let (req, req_rx) = gen_req(5, 2, vec![1]);
        tx.send(Work::Gen(req)).unwrap();
        assert_eq!(recv_gen(&req_rx).tokens.len(), 2);
        // Idle past the TTL: the recv timeout tick must run the sweep
        // even though no new work arrives.
        std::thread::sleep(Duration::from_millis(400));
        let (end_tx, end_rx) = mpsc::channel();
        tx.send(Work::End { session: 5, model: None, respond: Respond::Channel(end_tx) }).unwrap();
        assert!(
            matches!(end_rx.recv().unwrap(), Reply::End(false)),
            "session must already be reaped"
        );
        assert_eq!(Counters::get(&counters.sessions_reaped), 1);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn stats_report_uptime_and_fault_counters() {
        let mut s = tiny_server();
        let stats = s.stats_payload(false);
        for key in [
            "\"uptime_secs\":",
            "\"lane_panics\":0",
            "\"deadline_expirations\":0",
            "\"sessions_reaped\":0",
            "\"write_stall_closes\":0",
            "\"faults_injected\":0",
            "\"drains\":0",
            "\"sessions_snapshotted\":0",
            "\"sessions_restored\":0",
            "\"corrupt_loads_rejected\":0",
            "\"health\":\"ok\"",
        ] {
            assert!(stats.contains(key), "missing {key} in {stats}");
        }
        let text = s.stats_payload(true);
        assert!(text.contains("lane_panics=0") && text.contains("uptime="), "{text}");
        assert!(text.contains("drains=0") && text.contains("health=ok"), "{text}");
        // RELOAD of an unknown model is a wire-ready error.
        match s.reload_model("nope") {
            Reply::Error(msg) => assert_eq!(msg, "unknown model 'nope'"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn admission_control_sheds_beyond_queue_depth() {
        // One slot, queue depth one, three long requests sent while the
        // loop is blocked inside the first timestep window: at least one
        // must shed with Reply::Busy, and shed requests leave no trace in
        // the session store.
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            max_slots: 1,
            queue_depth: 1,
            ..Default::default()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        // Stuff the channel BEFORE the loop starts: deterministic shed.
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            let (r, rrx) = gen_req(i, 8, vec![1]);
            tx.send(Work::Gen(r)).unwrap();
            rxs.push(rrx);
        }
        let handle = std::thread::spawn(move || s.run(rx));
        let mut served = 0;
        let mut shed = 0;
        for rx in &rxs {
            match rx.recv().unwrap() {
                Reply::Gen(r) => {
                    assert_eq!(r.tokens.len(), 8);
                    served += 1;
                }
                Reply::Busy { queued, depth } => {
                    assert_eq!((queued, depth), (1, 1));
                    shed += 1;
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(served, 2, "slot + queue hold exactly two");
        assert_eq!(shed, 1);
        assert_eq!(Counters::get(&counters.shed), 1);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    fn drain_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("batcher_drain_{}_{tag}.amqs", std::process::id()))
    }

    #[test]
    fn drain_snapshots_sessions_and_restore_continues_bit_exactly() {
        let path = drain_path("roundtrip");
        let _ = std::fs::remove_file(&path);

        // Reference: two requests on one session, no restart in between.
        let mut a = tiny_server();
        let (r1, rx1) = gen_req(9, 3, vec![4]);
        a.process_batch(vec![r1]);
        let first_ref = recv_gen(&rx1).tokens;
        let (r2, rx2) = gen_req(9, 3, vec![11]);
        a.process_batch(vec![r2]);
        let second_ref = recv_gen(&rx2).tokens;

        // Interrupted run: first request, then DRAIN.
        let mut s = tiny_server_with(BatcherConfig {
            snapshot_path: Some(path.clone()),
            ..tiny_config()
        });
        let (r1, rx1) = gen_req(9, 3, vec![4]);
        s.process_batch(vec![r1]);
        assert_eq!(recv_gen(&rx1).tokens, first_ref);
        let (dtx, drx) = mpsc::channel();
        s.drain(Respond::Channel(dtx));
        match drx.recv().unwrap() {
            Reply::Drained { sessions, path: p } => {
                assert_eq!(sessions, 1);
                assert_eq!(p, path.display().to_string());
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Counters::get(&s.counters.drains), 1);
        assert_eq!(Counters::get(&s.counters.sessions_snapshotted), 1);

        // Admission is closed now.
        let (late, late_rx) = gen_req(10, 2, vec![1]);
        assert!(s.absorb(Work::Gen(late)));
        match late_rx.recv().unwrap() {
            Reply::Error(msg) => assert!(msg.starts_with("DRAINING "), "{msg}"),
            other => panic!("{other:?}"),
        }

        // The snapshot carries the session's history: prime + emissions.
        let snap = SessionSnapshot::load(&path).unwrap();
        assert_eq!(snap.models.len(), 1);
        let rec = &snap.models[0].sessions[0];
        assert_eq!(rec.id, 9);
        let mut expect_hist = vec![4usize];
        expect_hist.extend_from_slice(&first_ref);
        assert_eq!(rec.history, expect_hist);

        // Restore into a fresh server: the revived session's continuation
        // is byte-identical to the never-restarted reference.
        let mut fresh = tiny_server();
        assert_eq!(fresh.restore_sessions(&path).unwrap(), 1);
        assert_eq!(Counters::get(&fresh.counters.sessions_restored), 1);
        let (r2, rx2) = gen_req(9, 3, vec![11]);
        fresh.process_batch(vec![r2]);
        assert_eq!(recv_gen(&rx2).tokens, second_ref, "restored continuation diverged");

        // A second restore onto the now-dirty server refuses.
        let err = fresh.restore_sessions(&path).unwrap_err();
        assert!(err.starts_with("dirty restore refused"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn drain_refuses_without_a_path_and_handles_empty_stores() {
        let mut s = tiny_server();
        let (dtx, drx) = mpsc::channel();
        s.drain(Respond::Channel(dtx));
        match drx.recv().unwrap() {
            Reply::Error(msg) => assert!(msg.starts_with("DRAINING no snapshot path"), "{msg}"),
            other => panic!("{other:?}"),
        }

        // With a path but no sessions: an empty snapshot publishes and
        // restores cleanly.
        let path = drain_path("empty");
        let _ = std::fs::remove_file(&path);
        let mut s = tiny_server_with(BatcherConfig {
            snapshot_path: Some(path.clone()),
            ..tiny_config()
        });
        let (dtx, drx) = mpsc::channel();
        s.drain(Respond::Channel(dtx));
        match drx.recv().unwrap() {
            Reply::Drained { sessions, .. } => assert_eq!(sessions, 0),
            other => panic!("{other:?}"),
        }
        let mut fresh = tiny_server();
        assert_eq!(fresh.restore_sessions(&path).unwrap(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn poisoned_lanes_are_skipped_by_the_snapshot() {
        let path = drain_path("poison");
        let _ = std::fs::remove_file(&path);
        let mut s = tiny_server_with(BatcherConfig {
            snapshot_path: Some(path.clone()),
            ..tiny_config()
        });
        let (r, rx) = gen_req(1, 2, vec![3]);
        s.process_batch(vec![r]);
        recv_gen(&rx);
        // A panic between requests poisons the entry; the lane's saved
        // state is suspect, so the drain must not persist it.
        s.registry.poison(DEFAULT_MODEL);
        let (dtx, drx) = mpsc::channel();
        s.drain(Respond::Channel(dtx));
        match drx.recv().unwrap() {
            Reply::Drained { sessions, .. } => {
                assert_eq!(sessions, 0, "poisoned lane must be skipped");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Counters::get(&s.counters.sessions_snapshotted), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mid_flight_drain_cuts_stragglers_and_drops_their_sessions() {
        let path = drain_path("cut");
        let _ = std::fs::remove_file(&path);
        // Zero drain deadline: anything still in flight when DRAIN lands
        // is cut off with ERR DRAINING instead of running to completion.
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            snapshot_path: Some(path.clone()),
            drain_deadline: Duration::from_millis(0),
            ..tiny_config()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        // Stuffed before the loop starts: the huge request is in a slot
        // (or the queue) when the drain arrives right behind it.
        let (victim, victim_rx) = gen_req(3, 100_000, vec![1, 2]);
        tx.send(Work::Gen(victim)).unwrap();
        let (dtx, drx) = mpsc::channel();
        tx.send(Work::Drain { respond: Respond::Channel(dtx) }).unwrap();
        let handle = std::thread::spawn(move || s.run(rx));
        match victim_rx.recv().unwrap() {
            Reply::Error(msg) => assert!(msg.starts_with("DRAINING "), "{msg}"),
            other => panic!("{other:?}"),
        }
        match drx.recv().unwrap() {
            Reply::Drained { sessions, .. } => {
                assert_eq!(sessions, 0, "a cut session must not be snapshotted");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Counters::get(&counters.drains), 1);
        // Non-generation verbs still answer after the drain.
        let (stx, srx) = mpsc::channel();
        tx.send(Work::Stats { text: false, respond: Respond::Channel(stx) }).unwrap();
        let Reply::Stats(stats) = srx.recv().unwrap() else { panic!() };
        assert!(stats.contains("\"drains\":1"), "{stats}");
        assert!(stats.contains("\"health\":\"draining\""), "{stats}");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn restore_refuses_a_shape_mismatched_snapshot() {
        let path = drain_path("shape");
        let _ = std::fs::remove_file(&path);
        let mut s = tiny_server_with(BatcherConfig {
            snapshot_path: Some(path.clone()),
            ..tiny_config()
        });
        let (r, rx) = gen_req(1, 2, vec![3]);
        s.process_batch(vec![r]);
        recv_gen(&rx);
        let (dtx, drx) = mpsc::channel();
        s.drain(Respond::Channel(dtx));
        assert!(matches!(drx.recv().unwrap(), Reply::Drained { sessions: 1, .. }));

        // Same model name, different architecture: the restore must refuse
        // rather than pour LSTM floats into a GRU state.
        let gru = RnnLm::random(
            LmConfig { kind: RnnKind::Gru, vocab: 40, hidden: 16, layers: 1 },
            5,
            PrecisionPolicy::quantized(2, 2),
        );
        let mut other = InferenceServer::new(Arc::new(gru), tiny_config());
        let err = other.restore_sessions(&path).unwrap_err();
        assert!(err.contains("refusing to restore"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}
