//! The dynamic batcher + inference loop.
//!
//! Requests queue on a channel; the batcher drains up to `max_batch` of
//! them (waiting at most `batch_wait` to fill a batch — the classic
//! throughput/latency knob), then runs generation in **lockstep across the
//! batch**: one timestep for every active request per inner iteration, so
//! short requests finish early and the weight planes are walked once per
//! timestep group (Fig. 3 right). Each batched timestep executes on the
//! server's [`Exec`] worker pool (`config.exec`), which row-shards every
//! GEMM across cores — bit-exactly, so neither batching nor threading is
//! observable to clients.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::exec::{Exec, ExecConfig};
use crate::metrics::{Counters, LatencyRecorder};
use crate::model::lm::{LmState, LmStateBatch, LmStepWorkspace};
use crate::model::math::argmax;
use crate::model::OutputBatch;
use crate::model::RnnLm;
use crate::server::session::SessionStore;

/// Batching knobs ([server] config section).
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub batch_wait: Duration,
    pub max_sessions: usize,
    /// Worker-pool size for the batched forward (`threads = 1` ⇒ the exact
    /// serial path, `0` ⇒ auto). See [`ExecConfig`].
    pub exec: ExecConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            batch_wait: Duration::from_micros(500),
            max_sessions: 1024,
            exec: ExecConfig::auto(),
        }
    }
}

/// A generation request routed to the batcher.
pub struct Request {
    pub session: u64,
    pub max_new: usize,
    pub prime: Vec<usize>,
    pub respond: Sender<Response>,
    pub enqueued: Instant,
}

/// The batcher's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<usize>,
    pub queue_us: f64,
    pub compute_us: f64,
}

/// One in-flight generation request inside a lockstep batch.
struct Slot {
    req: Request,
    state: LmState,
    out: Vec<usize>,
    last: usize,
    queue_us: f64,
}

/// Work items multiplexed onto the batcher thread.
pub enum Work {
    Gen(Request),
    Score { tokens: Vec<usize>, respond: Sender<f64> },
    End { session: u64, respond: Sender<bool> },
    Stats { respond: Sender<String> },
    Shutdown,
}

/// The inference server state machine. Drive it with [`Self::run`] on a
/// dedicated thread, or call [`Self::process_batch`] directly (benches).
///
/// The server owns the decode-path workspaces (`step_state`, `step_logits`,
/// `step_ws`): they grow to the max-batch high-water mark once and are then
/// reused across every prime + decode timestep group of every batch, so a
/// steady-state timestep runs the model's zero-allocation
/// [`RnnLm::step_batch_into_exec`] path end to end.
pub struct InferenceServer {
    model: Arc<RnnLm>,
    sessions: SessionStore,
    config: BatcherConfig,
    exec: Exec,
    step_state: LmStateBatch,
    step_logits: OutputBatch,
    step_ws: LmStepWorkspace,
    pub latency: Arc<LatencyRecorder>,
    pub counters: Arc<Counters>,
}

impl InferenceServer {
    pub fn new(model: Arc<RnnLm>, config: BatcherConfig) -> Self {
        let exec = Exec::new(config.exec);
        Self::with_exec(model, config, exec)
    }

    /// Build with an existing engine (shares a pool already used to
    /// quantize the model, instead of spawning a second one). The stored
    /// config is normalized to the engine actually running, so
    /// `config.exec` can never disagree with the pool serving requests.
    pub fn with_exec(model: Arc<RnnLm>, mut config: BatcherConfig, exec: Exec) -> Self {
        config.exec = ExecConfig::with_threads(exec.threads());
        let step_state = model.zero_state_batch(0);
        InferenceServer {
            model,
            sessions: SessionStore::new(config.max_sessions),
            config,
            exec,
            step_state,
            step_logits: OutputBatch::zeros(0, 0),
            step_ws: LmStepWorkspace::new(),
            latency: Arc::new(LatencyRecorder::new()),
            counters: Arc::new(Counters::new()),
        }
    }

    /// The engine this server runs its batched forwards on.
    pub fn exec(&self) -> &Exec {
        &self.exec
    }

    /// Blocking event loop: drain work, batch generations, reply.
    pub fn run(mut self, rx: Receiver<Work>) {
        loop {
            // Block for the first item.
            let first = match rx.recv() {
                Ok(w) => w,
                Err(_) => return,
            };
            let mut gens: Vec<Request> = Vec::new();
            if !self.dispatch_or_collect(first, &mut gens) {
                return;
            }
            // Fill the batch within the wait window.
            let deadline = Instant::now() + self.config.batch_wait;
            while gens.len() < self.config.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(w) => {
                        if !self.dispatch_or_collect(w, &mut gens) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            if !gens.is_empty() {
                self.process_batch(gens);
            }
        }
    }

    /// Handle non-generation work inline; push generations into the batch.
    /// Returns false on shutdown.
    fn dispatch_or_collect(&mut self, w: Work, gens: &mut Vec<Request>) -> bool {
        match w {
            Work::Gen(r) => gens.push(r),
            Work::Score { tokens, respond } => {
                let ppw = self.model.ppw(&tokens);
                let _ = respond.send(ppw);
                Counters::inc(&self.counters.requests, 1);
            }
            Work::End { session, respond } => {
                let _ = respond.send(self.sessions.remove(session));
            }
            Work::Stats { respond } => {
                let snap = self.latency.snapshot();
                let _ = respond.send(format!(
                    "{} requests={} tokens={} batches={} evictions={} sessions={} \
                     kernel={} threads={}",
                    snap.report("latency"),
                    Counters::get(&self.counters.requests),
                    Counters::get(&self.counters.tokens_generated),
                    Counters::get(&self.counters.batches),
                    self.sessions.evictions,
                    self.sessions.len(),
                    crate::kernels::backend::active(),
                    self.exec.threads(),
                ));
            }
            Work::Shutdown => return false,
        }
        true
    }

    /// One batched timestep across the slots selected by `active`: gather
    /// into the server's reused state batch → [`RnnLm::step_batch_into_exec`]
    /// on the persistent workspace → scatter back into the slots' state
    /// buffers in place, updating each slot's greedy token. All the step
    /// buffers are reused across timestep groups; once at the max-batch
    /// high-water mark, a timestep allocates nothing beyond the small
    /// per-group bookkeeping lists in [`Self::process_batch`].
    fn step_active(&mut self, slots: &mut [Slot], active: &[usize], tokens: &[usize]) {
        let refs: Vec<&LmState> = active.iter().map(|&i| &slots[i].state).collect();
        self.model.gather_states_into(&refs, &mut self.step_state);
        self.model.step_batch_into_exec(
            tokens,
            &mut self.step_state,
            &mut self.step_logits,
            &self.exec,
            &mut self.step_ws,
        );
        for (k, &i) in active.iter().enumerate() {
            self.model.scatter_state_into(&self.step_state, k, &mut slots[i].state);
            slots[i].last = argmax(self.step_logits.row(k));
        }
    }

    /// Run one batch of generation requests in lockstep and reply to each.
    ///
    /// Both phases execute as **true batched forwards**
    /// ([`RnnLm::step_batch_into_exec`] on the server's worker pool and
    /// persistent workspaces): per timestep, the states of all still-active
    /// slots are gathered into the reused `LmStateBatch`, the model runs
    /// one batched step (each weight matrix swept once for the whole group
    /// — Fig. 3 right — with its rows sharded across the pool), and the
    /// updated states scatter back in place. Because the `_into` path
    /// bit-matches per-session `step` for any thread count, neither
    /// batching, threading, nor buffer reuse is visible to clients: a
    /// session generates the same tokens regardless of who it was batched
    /// with or how many cores served it.
    pub fn process_batch(&mut self, batch: Vec<Request>) {
        Counters::inc(&self.counters.batches, 1);
        Counters::inc(&self.counters.requests, batch.len() as u64);
        let start = Instant::now();

        // Restore per-session states.
        let mut slots: Vec<Slot> = batch
            .into_iter()
            .map(|req| {
                let queue_us = req.enqueued.elapsed().as_secs_f64() * 1e6;
                let state =
                    self.sessions.take(req.session).unwrap_or_else(|| self.model.zero_state());
                Slot { req, state, out: Vec::new(), last: 0, queue_us }
            })
            .collect();

        // Prime phase: consume prompt tokens in lockstep (prompts of
        // different lengths drop out as they finish).
        let max_prime = slots.iter().map(|s| s.req.prime.len()).max().unwrap_or(0);
        for pos in 0..max_prime {
            let active: Vec<usize> =
                (0..slots.len()).filter(|&i| pos < slots[i].req.prime.len()).collect();
            let tokens: Vec<usize> = active.iter().map(|&i| slots[i].req.prime[pos]).collect();
            self.step_active(&mut slots, &active, &tokens);
        }

        // Lockstep decode: one batched timestep across all active slots per
        // round; short requests drop out early.
        let max_rounds = slots.iter().map(|s| s.req.max_new).max().unwrap_or(0);
        for round in 0..max_rounds {
            let active: Vec<usize> =
                (0..slots.len()).filter(|&i| round < slots[i].req.max_new).collect();
            if active.is_empty() {
                break;
            }
            let tokens: Vec<usize> = active
                .iter()
                .map(|&i| {
                    let slot = &mut slots[i];
                    slot.out.push(slot.last);
                    slot.last
                })
                .collect();
            self.step_active(&mut slots, &active, &tokens);
        }

        let compute_us = start.elapsed().as_secs_f64() * 1e6;
        for slot in slots {
            Counters::inc(&self.counters.tokens_generated, slot.out.len() as u64);
            self.latency.record(Duration::from_secs_f64(
                (slot.queue_us + compute_us) / 1e6,
            ));
            self.sessions.put(slot.req.session, slot.state);
            let _ = slot.req.respond.send(Response {
                tokens: slot.out,
                queue_us: slot.queue_us,
                compute_us,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy, RnnKind};
    use std::sync::mpsc;

    fn tiny_server() -> InferenceServer {
        let lm = RnnLm::random(
            LmConfig { kind: RnnKind::Lstm, vocab: 40, hidden: 16, layers: 1 },
            5,
            PrecisionPolicy::quantized(2, 2),
        );
        InferenceServer::new(Arc::new(lm), BatcherConfig { max_batch: 4, ..Default::default() })
    }

    fn gen_req(session: u64, max_new: usize, prime: Vec<usize>) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request { session, max_new, prime, respond: tx, enqueued: Instant::now() },
            rx,
        )
    }

    #[test]
    fn batch_generates_requested_lengths() {
        let mut s = tiny_server();
        let (r1, rx1) = gen_req(1, 5, vec![1, 2]);
        let (r2, rx2) = gen_req(2, 3, vec![7]);
        s.process_batch(vec![r1, r2]);
        assert_eq!(rx1.recv().unwrap().tokens.len(), 5);
        assert_eq!(rx2.recv().unwrap().tokens.len(), 3);
        assert_eq!(Counters::get(&s.counters.tokens_generated), 8);
    }

    #[test]
    fn sessions_continue_deterministically() {
        // Generating 6 tokens in one request == 3 + 3 across two requests
        // with the same session (state is preserved server-side).
        let mut a = tiny_server();
        let (r, rx) = gen_req(9, 6, vec![4]);
        a.process_batch(vec![r]);
        let whole = rx.recv().unwrap().tokens;

        let mut b = tiny_server();
        let (r1, rx1) = gen_req(9, 3, vec![4]);
        b.process_batch(vec![r1]);
        let first = rx1.recv().unwrap().tokens;
        // Continue: prime with the last generated token's *successor* step
        // already happened server-side; new prime continues the stream.
        let (r2, rx2) = gen_req(9, 3, vec![whole[3 - 1 + 0]]);
        // ^ prime with the token the first half ended on (whole[2] was the
        //   last emitted; server state already consumed it + predicted next).
        b.process_batch(vec![r2]);
        let second = rx2.recv().unwrap().tokens;
        assert_eq!(first[..], whole[..3]);
        assert_eq!(second.len(), 3);
    }

    #[test]
    fn run_loop_end_to_end_with_shutdown() {
        let s = tiny_server();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let (g, grx) = gen_req(1, 4, vec![2, 3]);
        tx.send(Work::Gen(g)).unwrap();
        assert_eq!(grx.recv().unwrap().tokens.len(), 4);
        let (stx, srx) = mpsc::channel();
        tx.send(Work::Score { tokens: vec![1, 2, 3, 4], respond: stx }).unwrap();
        assert!(srx.recv().unwrap() > 1.0);
        let (etx, erx) = mpsc::channel();
        tx.send(Work::End { session: 1, respond: etx }).unwrap();
        assert!(erx.recv().unwrap());
        let (mtx, mrx) = mpsc::channel();
        tx.send(Work::Stats { respond: mtx }).unwrap();
        let stats = mrx.recv().unwrap();
        assert!(stats.contains("requests=2"), "{stats}");
        // The active kernel backend and thread count report together.
        assert!(stats.contains("kernel=") && stats.contains("threads="), "{stats}");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn threaded_batcher_bitmatches_serial_batcher() {
        // The same requests against the same seed model must generate the
        // same tokens whether the forward runs on 1 thread or a pool.
        let model = || {
            Arc::new(RnnLm::random(
                LmConfig { kind: RnnKind::Lstm, vocab: 40, hidden: 16, layers: 1 },
                5,
                PrecisionPolicy::quantized(2, 2),
            ))
        };
        let run = |exec: ExecConfig| {
            let mut s = InferenceServer::new(
                model(),
                BatcherConfig { max_batch: 4, exec, ..Default::default() },
            );
            let mut rxs = Vec::new();
            let mut reqs = Vec::new();
            for i in 0..3u64 {
                let (r, rx) = gen_req(i, 4 + i as usize, vec![(3 * i + 1) as usize]);
                reqs.push(r);
                rxs.push(rx);
            }
            s.process_batch(reqs);
            rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect::<Vec<_>>()
        };
        let serial = run(ExecConfig::serial());
        for threads in [2usize, 3, 8] {
            assert_eq!(run(ExecConfig::with_threads(threads)), serial, "threads={threads}");
        }
    }

    #[test]
    fn batcher_collects_up_to_max_batch() {
        let s = tiny_server();
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (g, grx) = gen_req(i, 2, vec![1]);
            tx.send(Work::Gen(g)).unwrap();
            rxs.push(grx);
        }
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 2);
        }
        // All four must have been served in at most 2 batch flushes (the
        // first may fire alone depending on scheduling).
        assert!(Counters::get(&counters.batches) <= 4);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
