//! The batching inference loop: fixed timestep groups or continuous
//! batching, one code path for the actual decode.
//!
//! **Grouped mode** (the classic [`Self::run`] loop with
//! `continuous = false`): requests queue on a channel; the batcher drains
//! up to `max_batch` of them (waiting at most `batch_wait` to fill a batch
//! — the throughput/latency knob), then runs the whole group to completion
//! before looking at the queue again.
//!
//! **Continuous mode** (`continuous = true`, the event-loop front end's
//! default): there is no group barrier. The decode batch is a set of
//! **slots** over a state batch that stays resident across timesteps; a
//! new request joins at the next timestep boundary
//! ([`RnnLm::push_state_column`]) and a finished sequence frees its slot
//! immediately ([`RnnLm::swap_remove_state_column`]) — a short request
//! never waits for a long one it happens to share a batch with.
//! Slot bookkeeping is swap-remove in O(joins + leaves) per timestep;
//! the steady-state timestep itself is the zero-allocation
//! [`RnnLm::step_batch_into_exec`] on the server's persistent workspace.
//! Admission control backs the loop: at most `max_slots` sequences decode
//! concurrently, at most `queue_depth` wait behind them, and anything
//! beyond that is shed instantly with [`Reply::Busy`] (`ERR BUSY` on the
//! wire) instead of building unbounded latency. Generations for a session
//! already decoding are held until its slot leaves (per-session
//! serialization — pipelined requests continue state exactly as if sent
//! one at a time; unrelated sessions admit past them).
//!
//! Both modes run every batched timestep on the server's [`Exec`] worker
//! pool (`config.exec`), which row-shards every GEMM across cores —
//! bit-exactly, so neither batching mode nor threading is observable to
//! clients: the tokens equal a serial `max_batch = 1` run, always.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::exec::{Exec, ExecConfig};
use crate::metrics::{Counters, LatencyRing};
use crate::model::lm::{LmState, LmStateBatch, LmStepWorkspace};
use crate::model::math::argmax;
use crate::model::OutputBatch;
use crate::model::RnnLm;
use crate::server::session::SessionStore;

/// Batching knobs ([server] config section).
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub batch_wait: Duration,
    pub max_sessions: usize,
    /// Continuous batching: join/leave at timestep boundaries instead of
    /// fixed prime+decode groups. The event-loop front end's mode.
    pub continuous: bool,
    /// Max sequences decoding concurrently in continuous mode
    /// (`0` ⇒ `max_batch`).
    pub max_slots: usize,
    /// Bounded pending queue in continuous mode; a generation request
    /// arriving with the queue full is shed with [`Reply::Busy`].
    pub queue_depth: usize,
    /// Worker-pool size for the batched forward (`threads = 1` ⇒ the exact
    /// serial path, `0` ⇒ auto). See [`ExecConfig`].
    pub exec: ExecConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            batch_wait: Duration::from_micros(500),
            max_sessions: 1024,
            continuous: false,
            max_slots: 0,
            queue_depth: 128,
            exec: ExecConfig::auto(),
        }
    }
}

/// A generation request routed to the batcher.
pub struct Request {
    pub session: u64,
    pub max_new: usize,
    pub prime: Vec<usize>,
    pub respond: Respond,
    pub enqueued: Instant,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<usize>,
    pub queue_us: f64,
    pub compute_us: f64,
}

/// Every reply the batcher can produce, one type for every front end.
#[derive(Clone, Debug)]
pub enum Reply {
    Gen(Response),
    Score(f64),
    /// `true` ⇒ the session existed and was dropped.
    End(bool),
    Stats(String),
    /// Load shed: the pending queue was full when the request arrived.
    Busy { queued: usize, depth: usize },
}

/// Where a completed [`Reply`] goes. The thread-per-connection front end
/// blocks on a channel; the event loop registers a [`ReplySink`] that
/// enqueues the completion and wakes the owning loop.
pub enum Respond {
    Channel(Sender<Reply>),
    Sink { sink: Arc<dyn ReplySink>, conn: u64, serial: u64 },
}

impl Respond {
    pub fn send(self, reply: Reply) {
        match self {
            Respond::Channel(tx) => {
                let _ = tx.send(reply);
            }
            Respond::Sink { sink, conn, serial } => sink.complete(conn, serial, reply),
        }
    }
}

/// Asynchronous completion target (the event loop's half of [`Respond`]).
pub trait ReplySink: Send + Sync {
    fn complete(&self, conn: u64, serial: u64, reply: Reply);
}

/// Work items multiplexed onto the batcher thread.
pub enum Work {
    Gen(Request),
    Score { tokens: Vec<usize>, respond: Respond },
    End { session: u64, respond: Respond },
    Stats { text: bool, respond: Respond },
    Shutdown,
}

/// One sequence occupying a batch slot. `slots[i]` always describes column
/// `i` of the resident state batch; the parallel `tokens[i]` holds the
/// token that column consumes at the next timestep.
struct SeqSlot {
    session: u64,
    prime: Vec<usize>,
    /// Prime tokens consumed so far; `fed == prime.len()` ⇒ decoding.
    fed: usize,
    out: Vec<usize>,
    max_new: usize,
    respond: Respond,
    queue_us: f64,
    joined: Instant,
    /// Finished this timestep (final emitted token consumed); freed at the
    /// end of the timestep.
    done: bool,
    /// Reusable per-session state buffer: holds the restored session state
    /// at join, receives the extracted column at leave.
    state_buf: LmState,
}

/// The inference server state machine. Drive it with [`Self::run`] on a
/// dedicated thread, or call [`Self::process_batch`] directly (benches).
///
/// The server owns the decode-path workspaces (`step_state`, `step_logits`,
/// `step_ws`): they grow to the max-batch high-water mark once and are then
/// reused across every timestep of every request, so a steady-state
/// timestep runs the model's zero-allocation
/// [`RnnLm::step_batch_into_exec`] path end to end. In continuous mode,
/// `step_state` is the **resident** decode batch — columns are pushed and
/// swap-removed at timestep boundaries and are never re-gathered.
pub struct InferenceServer {
    model: Arc<RnnLm>,
    sessions: SessionStore,
    config: BatcherConfig,
    exec: Exec,
    step_state: LmStateBatch,
    step_logits: OutputBatch,
    step_ws: LmStepWorkspace,
    slots: Vec<SeqSlot>,
    tokens: Vec<usize>,
    pending: VecDeque<Request>,
    pub latency: Arc<LatencyRing>,
    pub counters: Arc<Counters>,
}

impl InferenceServer {
    pub fn new(model: Arc<RnnLm>, config: BatcherConfig) -> Self {
        let exec = Exec::new(config.exec);
        Self::with_exec(model, config, exec)
    }

    /// Build with an existing engine (shares a pool already used to
    /// quantize the model, instead of spawning a second one). The stored
    /// config is normalized to the engine actually running, so
    /// `config.exec` can never disagree with the pool serving requests;
    /// `max_slots = 0` resolves to `max_batch`.
    pub fn with_exec(model: Arc<RnnLm>, mut config: BatcherConfig, exec: Exec) -> Self {
        config.exec = ExecConfig::with_threads(exec.threads());
        if config.max_slots == 0 {
            config.max_slots = config.max_batch;
        }
        let step_state = model.zero_state_batch(0);
        InferenceServer {
            model,
            sessions: SessionStore::new(config.max_sessions),
            config,
            exec,
            step_state,
            step_logits: OutputBatch::zeros(0, 0),
            step_ws: LmStepWorkspace::new(),
            slots: Vec::new(),
            tokens: Vec::new(),
            pending: VecDeque::new(),
            latency: Arc::new(LatencyRing::new(1024)),
            counters: Arc::new(Counters::new()),
        }
    }

    /// The engine this server runs its batched forwards on.
    pub fn exec(&self) -> &Exec {
        &self.exec
    }

    /// Blocking work loop; dispatches on the configured batching mode.
    pub fn run(self, rx: Receiver<Work>) {
        if self.config.continuous {
            self.run_continuous(rx)
        } else {
            self.run_grouped(rx)
        }
    }

    /// Grouped mode: drain work, collect a batch, run it to completion.
    fn run_grouped(mut self, rx: Receiver<Work>) {
        loop {
            // Block for the first item.
            let first = match rx.recv() {
                Ok(w) => w,
                Err(_) => return,
            };
            let mut gens: Vec<Request> = Vec::new();
            if !self.dispatch_or_collect(first, &mut gens) {
                return;
            }
            // Fill the batch within the wait window.
            let deadline = Instant::now() + self.config.batch_wait;
            while gens.len() < self.config.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(w) => {
                        if !self.dispatch_or_collect(w, &mut gens) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            if !gens.is_empty() {
                self.process_batch(gens);
            }
        }
    }

    /// Continuous mode: admit work between timesteps, never a group
    /// barrier. Blocks only when fully idle.
    fn run_continuous(mut self, rx: Receiver<Work>) {
        loop {
            if self.slots.is_empty() && self.pending.is_empty() {
                // Idle: block until something arrives.
                match rx.recv() {
                    Ok(w) => {
                        if !self.absorb(w) {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
            // Drain whatever else arrived while the last timestep ran.
            loop {
                match rx.try_recv() {
                    Ok(w) => {
                        if !self.absorb(w) {
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if self.slots.is_empty() && self.pending.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            }
            // Join pending sequences into slots freed by the last
            // timestep's leaves.
            self.admit();
            if !self.slots.is_empty() {
                self.timestep();
            }
        }
    }

    /// Move pending requests into free slots. Only ever called between
    /// timesteps, so a join always lands exactly at a boundary.
    ///
    /// A request whose session is already decoding in a slot is held back
    /// until that slot leaves: per-session generations serialize, so a
    /// client pipelining `GEN`s on one session observes exactly the
    /// sequential state handoff (the second request continues from the
    /// first's final state, never from a stale or zero snapshot). Held
    /// requests keep their queue position relative to their own session;
    /// unrelated sessions may admit past them — no head-of-line blocking.
    fn admit(&mut self) {
        let mut i = 0;
        while self.slots.len() < self.config.max_slots && i < self.pending.len() {
            if self.session_decoding(self.pending[i].session) {
                i += 1;
                continue;
            }
            let req = self.pending.remove(i).expect("index checked in bounds");
            self.join_slot(req);
            // `remove` shifted the next unexamined request down to `i`.
        }
    }

    /// Is this session currently resident in a decode slot? O(slots) — the
    /// slot count is small by construction (`max_slots`).
    fn session_decoding(&self, session: u64) -> bool {
        self.slots.iter().any(|s| s.session == session)
    }

    /// Absorb one work item in continuous mode: generations pass admission
    /// control into the pending queue, everything else answers inline.
    /// Returns false on shutdown.
    fn absorb(&mut self, w: Work) -> bool {
        match w {
            Work::Gen(req) => {
                if self.pending.len() >= self.config.queue_depth {
                    Counters::inc(&self.counters.shed, 1);
                    req.respond.send(Reply::Busy {
                        queued: self.pending.len(),
                        depth: self.config.queue_depth,
                    });
                } else {
                    Counters::inc(&self.counters.requests, 1);
                    self.pending.push_back(req);
                    // A free slot takes the head of the queue right away
                    // (we are between timesteps here), so `queue_depth`
                    // bounds the wait line, not slots + line.
                    self.admit();
                }
                true
            }
            other => self.control(other),
        }
    }

    /// Handle non-generation work inline; push generations into the batch
    /// (grouped mode). Returns false on shutdown.
    fn dispatch_or_collect(&mut self, w: Work, gens: &mut Vec<Request>) -> bool {
        match w {
            Work::Gen(r) => {
                gens.push(r);
                true
            }
            other => self.control(other),
        }
    }

    /// Score / End / Stats / Shutdown — identical in both modes. Returns
    /// false on shutdown.
    fn control(&mut self, w: Work) -> bool {
        match w {
            Work::Gen(_) => unreachable!("generation handled by the mode-specific path"),
            Work::Score { tokens, respond } => {
                Counters::inc(&self.counters.requests, 1);
                respond.send(Reply::Score(self.model.ppw(&tokens)));
            }
            Work::End { session, respond } => {
                respond.send(Reply::End(self.sessions.remove(session)));
            }
            Work::Stats { text, respond } => {
                respond.send(Reply::Stats(self.stats_payload(text)));
            }
            Work::Shutdown => return false,
        }
        true
    }

    /// The `STATS` payload: single-line JSON, or the human-readable line
    /// behind `STATS TEXT`.
    fn stats_payload(&self, text: bool) -> String {
        let snap = self.latency.snapshot();
        let c = &self.counters;
        if text {
            return format!(
                "{} requests={} tokens={} batches={} timesteps={} shed={} active={} queued={} \
                 evictions={} sessions={} mode={} kernel={} threads={}",
                snap.report("latency"),
                Counters::get(&c.requests),
                Counters::get(&c.tokens_generated),
                Counters::get(&c.batches),
                Counters::get(&c.decode_timesteps),
                Counters::get(&c.shed),
                self.slots.len(),
                self.pending.len(),
                self.sessions.evictions,
                self.sessions.len(),
                if self.config.continuous { "continuous" } else { "grouped" },
                crate::kernels::backend::active(),
                self.exec.threads(),
            );
        }
        // NaN (empty latency window) is not valid JSON; report zeros.
        let f = |v: f64| if v.is_finite() { v } else { 0.0 };
        format!(
            "{{\"mode\":\"{}\",\"active_slots\":{},\"max_slots\":{},\"queued\":{},\
             \"queue_depth\":{},\"shed\":{},\"requests\":{},\"tokens_generated\":{},\
             \"batches\":{},\"decode_timesteps\":{},\"sessions\":{},\"evictions\":{},\
             \"kernel\":\"{}\",\"threads\":{},\"latency_us\":{{\"count\":{},\"window\":{},\
             \"mean\":{:.1},\"p50\":{:.1},\"p95\":{:.1},\"p99\":{:.1},\"max\":{:.1}}}}}",
            if self.config.continuous { "continuous" } else { "grouped" },
            self.slots.len(),
            self.config.max_slots,
            self.pending.len(),
            self.config.queue_depth,
            Counters::get(&c.shed),
            Counters::get(&c.requests),
            Counters::get(&c.tokens_generated),
            Counters::get(&c.batches),
            Counters::get(&c.decode_timesteps),
            self.sessions.len(),
            self.sessions.evictions,
            crate::kernels::backend::active(),
            self.exec.threads(),
            snap.count,
            snap.count.min(self.latency.capacity()),
            f(snap.mean_us),
            f(snap.p50_us),
            f(snap.p95_us),
            f(snap.p99_us),
            f(snap.max_us),
        )
    }

    /// Join one request into a free slot: restore (or zero) its session
    /// state, push it as a new column of the resident state batch, and
    /// queue its first input token. O(layers · hidden), at a timestep
    /// boundary only.
    fn join_slot(&mut self, req: Request) {
        let Request { session, max_new, prime, respond, enqueued } = req;
        let queue_us = enqueued.elapsed().as_secs_f64() * 1e6;
        let state_buf = self.sessions.take(session).unwrap_or_else(|| self.model.zero_state());
        self.model.push_state_column(&state_buf, &mut self.step_state);
        let mut out = Vec::new();
        // An empty prime (direct-API callers only; the wire protocol
        // requires ≥ 1) decodes from token 0, which is itself emitted —
        // the grouped batcher's historical semantics, preserved exactly.
        let first = match prime.first() {
            Some(&t) => t,
            None => {
                out.push(0);
                0
            }
        };
        self.tokens.push(first);
        self.slots.push(SeqSlot {
            session,
            prime,
            fed: 0,
            out,
            max_new,
            respond,
            queue_us,
            joined: Instant::now(),
            done: false,
            state_buf,
        });
    }

    /// Free slot `i` after the timestep that consumed its final token:
    /// extract its state column into the slot's own buffer, swap-remove the
    /// column (the last slot takes index `i` — O(layers · hidden), no
    /// shifting), save the session, and reply.
    fn leave_slot(&mut self, i: usize) {
        let mut slot = self.slots.swap_remove(i);
        self.tokens.swap_remove(i);
        self.model.scatter_state_into(&self.step_state, i, &mut slot.state_buf);
        self.model.swap_remove_state_column(&mut self.step_state, i);
        let compute_us = slot.joined.elapsed().as_secs_f64() * 1e6;
        Counters::inc(&self.counters.tokens_generated, slot.out.len() as u64);
        self.latency.record(Duration::from_secs_f64((slot.queue_us + compute_us) / 1e6));
        self.sessions.put(slot.session, slot.state_buf);
        slot.respond.send(Reply::Gen(Response {
            tokens: slot.out,
            queue_us: slot.queue_us,
            compute_us,
        }));
    }

    /// One lockstep timestep across every occupied slot: batched forward on
    /// the resident state, then per-slot advance (next prime token, or emit
    /// the greedy token), then free the finished slots. Per-timestep
    /// bookkeeping is O(active) for the advance and O(leaves) for the
    /// frees — no per-timestep list rebuilds.
    fn timestep(&mut self) {
        debug_assert_eq!(self.slots.len(), self.tokens.len());
        debug_assert_eq!(self.step_state.batch(), self.slots.len());
        self.model.step_batch_into_exec(
            &self.tokens,
            &mut self.step_state,
            &mut self.step_logits,
            &self.exec,
            &mut self.step_ws,
        );
        Counters::inc(&self.counters.decode_timesteps, 1);
        let mut any_done = false;
        for i in 0..self.slots.len() {
            let slot = &mut self.slots[i];
            if slot.fed < slot.prime.len() {
                slot.fed += 1; // this step consumed prime[fed]
            }
            if slot.fed < slot.prime.len() {
                self.tokens[i] = slot.prime[slot.fed];
            } else if slot.out.len() >= slot.max_new {
                // The token consumed this step was the last emitted one:
                // the session state is now past it. Finished.
                slot.done = true;
                any_done = true;
            } else {
                // Greedy decode: the next input is this step's argmax, and
                // selecting it *is* emitting it.
                let t = argmax(self.step_logits.row(i));
                slot.out.push(t);
                self.tokens[i] = t;
            }
        }
        if any_done {
            // Reverse order: swap_remove moves an already-visited slot (the
            // last) into the freed index.
            for i in (0..self.slots.len()).rev() {
                if self.slots[i].done {
                    self.leave_slot(i);
                }
            }
        }
    }

    /// Run one batch of generation requests in lockstep and reply to each —
    /// grouped mode's inner loop, and the direct entry point for benches.
    ///
    /// Runs on the same slot machinery as continuous mode (join all, step
    /// until every slot leaves), so every timestep is a **true batched
    /// forward** ([`RnnLm::step_batch_into_exec`] on the server's worker
    /// pool and persistent workspaces) and finished sequences free their
    /// column mid-group instead of being rescanned every timestep. Because
    /// the `_into` path bit-matches per-session `step` for any batch
    /// composition and thread count, neither batching, threading, nor
    /// buffer reuse is visible to clients: a session generates the same
    /// tokens regardless of who it was batched with or how many cores
    /// served it.
    pub fn process_batch(&mut self, batch: Vec<Request>) {
        Counters::inc(&self.counters.batches, 1);
        Counters::inc(&self.counters.requests, batch.len() as u64);
        debug_assert!(self.slots.is_empty(), "grouped mode runs one batch at a time");
        for req in batch {
            self.join_slot(req);
        }
        while !self.slots.is_empty() {
            self.timestep();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy, RnnKind};
    use std::sync::mpsc;

    fn tiny_config() -> BatcherConfig {
        BatcherConfig { max_batch: 4, ..Default::default() }
    }

    fn tiny_server_with(config: BatcherConfig) -> InferenceServer {
        let lm = RnnLm::random(
            LmConfig { kind: RnnKind::Lstm, vocab: 40, hidden: 16, layers: 1 },
            5,
            PrecisionPolicy::quantized(2, 2),
        );
        InferenceServer::new(Arc::new(lm), config)
    }

    fn tiny_server() -> InferenceServer {
        tiny_server_with(tiny_config())
    }

    fn gen_req(session: u64, max_new: usize, prime: Vec<usize>) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                session,
                max_new,
                prime,
                respond: Respond::Channel(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn recv_gen(rx: &mpsc::Receiver<Reply>) -> Response {
        match rx.recv().unwrap() {
            Reply::Gen(r) => r,
            other => panic!("expected Reply::Gen, got {other:?}"),
        }
    }

    #[test]
    fn batch_generates_requested_lengths() {
        let mut s = tiny_server();
        let (r1, rx1) = gen_req(1, 5, vec![1, 2]);
        let (r2, rx2) = gen_req(2, 3, vec![7]);
        s.process_batch(vec![r1, r2]);
        assert_eq!(recv_gen(&rx1).tokens.len(), 5);
        assert_eq!(recv_gen(&rx2).tokens.len(), 3);
        assert_eq!(Counters::get(&s.counters.tokens_generated), 8);
    }

    #[test]
    fn sessions_continue_deterministically() {
        // Generating 6 tokens in one request == 3 + 3 across two requests
        // with the same session (state is preserved server-side).
        let mut a = tiny_server();
        let (r, rx) = gen_req(9, 6, vec![4]);
        a.process_batch(vec![r]);
        let whole = recv_gen(&rx).tokens;

        let mut b = tiny_server();
        let (r1, rx1) = gen_req(9, 3, vec![4]);
        b.process_batch(vec![r1]);
        let first = recv_gen(&rx1).tokens;
        // Continue: prime with the token the first half ended on (whole[2]
        // was the last emitted; server state already consumed it).
        let (r2, rx2) = gen_req(9, 3, vec![whole[2]]);
        b.process_batch(vec![r2]);
        let second = recv_gen(&rx2).tokens;
        assert_eq!(first[..], whole[..3]);
        assert_eq!(second.len(), 3);
    }

    #[test]
    fn pipelined_same_session_requests_serialize() {
        // Sequential reference: two generations on one session, one at a
        // time (the second continues the first's saved state).
        let mut a = tiny_server();
        let (r1, rx1) = gen_req(7, 5, vec![3, 8]);
        a.process_batch(vec![r1]);
        let first_ref = recv_gen(&rx1).tokens;
        let (r2, rx2) = gen_req(7, 4, vec![11]);
        a.process_batch(vec![r2]);
        let second_ref = recv_gen(&rx2).tokens;

        // Continuous server with plenty of free slots and both requests
        // queued before it starts. Admission must hold the second back
        // until the first leaves its slot (same session) — not decode
        // both concurrently from a stale/zero state snapshot.
        let s = tiny_server_with(BatcherConfig {
            max_batch: 4,
            continuous: true,
            max_slots: 4,
            ..Default::default()
        });
        let (tx, rx) = mpsc::channel();
        let (r1, rx1) = gen_req(7, 5, vec![3, 8]);
        let (r2, rx2) = gen_req(7, 4, vec![11]);
        tx.send(Work::Gen(r1)).unwrap();
        tx.send(Work::Gen(r2)).unwrap();
        let handle = std::thread::spawn(move || s.run(rx));
        assert_eq!(recv_gen(&rx1).tokens, first_ref, "first request must match sequential");
        assert_eq!(recv_gen(&rx2).tokens, second_ref, "pipelined continuation must serialize");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn run_loop_end_to_end_with_shutdown() {
        let s = tiny_server();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let (g, grx) = gen_req(1, 4, vec![2, 3]);
        tx.send(Work::Gen(g)).unwrap();
        assert_eq!(recv_gen(&grx).tokens.len(), 4);
        let (stx, srx) = mpsc::channel();
        tx.send(Work::Score { tokens: vec![1, 2, 3, 4], respond: Respond::Channel(stx) }).unwrap();
        match srx.recv().unwrap() {
            Reply::Score(ppw) => assert!(ppw > 1.0),
            other => panic!("{other:?}"),
        }
        let (etx, erx) = mpsc::channel();
        tx.send(Work::End { session: 1, respond: Respond::Channel(etx) }).unwrap();
        assert!(matches!(erx.recv().unwrap(), Reply::End(true)));
        // JSON stats by default, the human-readable line behind text=true.
        let (mtx, mrx) = mpsc::channel();
        tx.send(Work::Stats { text: false, respond: Respond::Channel(mtx) }).unwrap();
        let Reply::Stats(stats) = mrx.recv().unwrap() else { panic!() };
        assert!(stats.starts_with('{') && stats.ends_with('}'), "{stats}");
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(stats.contains("\"mode\":\"grouped\""), "{stats}");
        assert!(stats.contains("\"kernel\":\"") && stats.contains("\"threads\":"), "{stats}");
        assert!(stats.contains("\"latency_us\":{\"count\":1,"), "{stats}");
        let (mtx, mrx) = mpsc::channel();
        tx.send(Work::Stats { text: true, respond: Respond::Channel(mtx) }).unwrap();
        let Reply::Stats(stats) = mrx.recv().unwrap() else { panic!() };
        assert!(stats.contains("requests=2"), "{stats}");
        assert!(stats.contains("kernel=") && stats.contains("threads="), "{stats}");
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn threaded_batcher_bitmatches_serial_batcher() {
        // The same requests against the same seed model must generate the
        // same tokens whether the forward runs on 1 thread or a pool.
        let model = || {
            Arc::new(RnnLm::random(
                LmConfig { kind: RnnKind::Lstm, vocab: 40, hidden: 16, layers: 1 },
                5,
                PrecisionPolicy::quantized(2, 2),
            ))
        };
        let run = |exec: ExecConfig| {
            let mut s = InferenceServer::new(
                model(),
                BatcherConfig { max_batch: 4, exec, ..Default::default() },
            );
            let mut rxs = Vec::new();
            let mut reqs = Vec::new();
            for i in 0..3u64 {
                let (r, rx) = gen_req(i, 4 + i as usize, vec![(3 * i + 1) as usize]);
                reqs.push(r);
                rxs.push(rx);
            }
            s.process_batch(reqs);
            rxs.iter().map(|rx| recv_gen(rx).tokens).collect::<Vec<_>>()
        };
        let serial = run(ExecConfig::serial());
        for threads in [2usize, 3, 8] {
            assert_eq!(run(ExecConfig::with_threads(threads)), serial, "threads={threads}");
        }
    }

    #[test]
    fn batcher_collects_up_to_max_batch() {
        let s = tiny_server();
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (g, grx) = gen_req(i, 2, vec![1]);
            tx.send(Work::Gen(g)).unwrap();
            rxs.push(grx);
        }
        for rx in rxs {
            assert_eq!(recv_gen(&rx).tokens.len(), 2);
        }
        // All four must have been served in at most 2 batch flushes (the
        // first may fire alone depending on scheduling).
        assert!(Counters::get(&counters.batches) <= 4);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn continuous_bitmatches_grouped_and_serial() {
        // Staggered sessions with different lengths joining and leaving
        // mid-decode must produce exactly the tokens a max_batch = 1
        // grouped reference produces per session.
        let scripts: Vec<(u64, usize, Vec<usize>)> = (0..6)
            .map(|i| (i as u64, 3 + (i % 4), vec![(3 * i + 1) % 40, (7 * i + 2) % 40]))
            .collect();

        // Sequential reference: one request at a time, grouped server.
        let mut reference = Vec::new();
        {
            let mut s = tiny_server_with(BatcherConfig { max_batch: 1, ..Default::default() });
            for (sess, max_new, prime) in &scripts {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                s.process_batch(vec![r]);
                reference.push(recv_gen(&rx).tokens);
            }
        }

        // Continuous server, all requests in flight at once with a tiny
        // slot budget so joins/leaves happen mid-decode.
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            max_slots: 2,
            queue_depth: 64,
            ..Default::default()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || s.run(rx));
        let rxs: Vec<_> = scripts
            .iter()
            .map(|(sess, max_new, prime)| {
                let (r, rx) = gen_req(*sess, *max_new, prime.clone());
                tx.send(Work::Gen(r)).unwrap();
                rx
            })
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            assert_eq!(recv_gen(rx).tokens, reference[i], "session {i} diverged");
        }
        assert!(Counters::get(&counters.decode_timesteps) > 0);
        assert_eq!(Counters::get(&counters.shed), 0);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn admission_control_sheds_beyond_queue_depth() {
        // One slot, queue depth one, three long requests sent while the
        // loop is blocked inside the first timestep window: at least one
        // must shed with Reply::Busy, and shed requests leave no trace in
        // the session store.
        let s = tiny_server_with(BatcherConfig {
            continuous: true,
            max_slots: 1,
            queue_depth: 1,
            ..Default::default()
        });
        let counters = s.counters.clone();
        let (tx, rx) = mpsc::channel();
        // Stuff the channel BEFORE the loop starts: deterministic shed.
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            let (r, rrx) = gen_req(i, 8, vec![1]);
            tx.send(Work::Gen(r)).unwrap();
            rxs.push(rrx);
        }
        let handle = std::thread::spawn(move || s.run(rx));
        let mut served = 0;
        let mut shed = 0;
        for rx in &rxs {
            match rx.recv().unwrap() {
                Reply::Gen(r) => {
                    assert_eq!(r.tokens.len(), 8);
                    served += 1;
                }
                Reply::Busy { queued, depth } => {
                    assert_eq!((queued, depth), (1, 1));
                    shed += 1;
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(served, 2, "slot + queue hold exactly two");
        assert_eq!(shed, 1);
        assert_eq!(Counters::get(&counters.shed), 1);
        tx.send(Work::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
