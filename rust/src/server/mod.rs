//! Serving coordinator — the Layer-3 contribution shaped by the paper's
//! motivation (§1): server-side RNN inference under large-scale concurrent
//! requests, where latency per request and throughput per machine are the
//! product constraints that quantization relieves.
//!
//! Two front ends feed one batcher thread over the same `Work` channel:
//!
//! ```text
//!  thread-per-conn (tcp)        event loop (eventloop, --event-loop)
//!  one blocking thread          N loop threads × epoll/kqueue Poller,
//!  per client                   nonblocking conns, pipelined framing
//!         │                               │
//!         └───────────── Work channel ────┘
//!                             │
//!            ┌─ admission control (continuous mode) ─┐
//!            │ pending queue ≤ queue_depth, else     │
//!            │ ERR BUSY (shed counter)               │
//!            └───────────────┬───────────────────────┘
//!                            ▼
//!              continuous batcher (decode timesteps)
//!          slots ≤ max_slots; a request JOINS at the next
//!          timestep boundary (state column pushed into the
//!          resident LmStateBatch), a finished sequence LEAVES
//!          immediately (swap-remove, O(1)) freeing its slot —
//!          no group barrier, no drain/refill
//!                            │ step_batch_into_exec
//!              batched forward: one sweep over each packed
//!              weight plane serves all live columns; exec pool
//!              row-shards every GEMM across cores
//!                            │ scatter on leave
//!              session cache (hidden states, LRU)
//! ```
//!
//! **Slot lifecycle** (continuous mode): arrive → pending queue (or shed
//! with `ERR BUSY` when the queue is at `queue_depth`) → join a free slot
//! at a timestep boundary (state column pushed, first token placed) → step
//! with every other live slot each timestep → leave the moment its quota
//! fills (column scattered back to the session store, slot swap-removed) →
//! reply. Joins and leaves cost O(changed slots); steady-state bookkeeping
//! per timestep is O(live slots) with no per-slot gather/scatter.
//!
//! **Backpressure** is layered: each event-loop connection stops being
//! read at `MAX_PIPELINE` in-flight requests (the client's TCP window
//! fills), and the batcher sheds `GEN` work once `pending == queue_depth`,
//! so memory stays bounded under any offered load.
//!
//! Both batching modes are exactness-preserving: `step_batch_into_exec`
//! bit-matches per-session `step` for **every batch composition and thread
//! count** (`rust/tests/exec_parity.rs`), so a sequence's tokens are
//! independent of who shares its batch — continuous batching is bit-exact
//! versus a sequential reference by construction (asserted under
//! mid-decode joins/leaves in `batcher::tests` and over TCP in
//! `rust/tests/eventloop_server.rs`). Shutdown joins every thread: the
//! exec pool on drop, connection handlers in `tcp::serve`, loop threads in
//! `eventloop::EventLoopServer::shutdown`.
//!
//! **Multi-tenancy**: the batcher owns a [`registry::ModelRegistry`] and
//! one decode lane per resident model. Requests carry an optional
//! `MODEL <name>` field; named `.amqz` files (`--model name=path`,
//! repeatable, or a `[models]` config section) load zero-copy on first use
//! and LRU-evict past `--model-mem-budget` while idle. Admission validates
//! every token against the target model's vocab, so malformed or hostile
//! requests answer `ERR` instead of panicking the batcher thread
//! (`rust/tests/hostile_client.rs` drives both front ends adversarially).
//!
//! CLI knobs: `--event-loop` selects the multiplexed front end (implies
//! continuous batching), `--max-slots` caps live decode slots,
//! `--queue-depth` bounds the admission queue. `STATS` returns one-line
//! JSON; `STATS TEXT` the human form.
//!
//! **Failure containment**: a panic inside a model lane's timestep is
//! caught at the batcher loop ([`batcher`]), the lane quarantined and its
//! registry entry poisoned until an operator `RELOAD <name>` succeeds —
//! other lanes keep decoding bit-exactly and the batcher thread never
//! dies. Requests can carry a server-wide deadline
//! (`--request-deadline-ms`, answered `ERR DEADLINE` at a timestep
//! boundary), idle sessions are reaped after `--session-ttl-secs`, and the
//! event loop closes connections stalled past `--write-stall-ms`. All
//! fault paths are drivable deterministically via [`faults::FaultPlan`]
//! (`AMQ_FAULTS`, tests only).
//!
//! The server tree bans stray `unwrap`/`expect` on runtime paths — every
//! fallible step must answer `ERR INTERNAL <context>` instead of killing a
//! serving thread. (CI runs clippy with `-D warnings`, promoting these
//! lints to errors; test modules opt out locally.)
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
#[cfg(unix)]
pub mod eventloop;
pub mod faults;
pub mod health;
pub mod protocol;
pub mod registry;
pub mod session;
pub mod tcp;

pub use batcher::{BatcherConfig, InferenceServer, Reply, Request, Respond, Response, Work};
pub use faults::FaultPlan;
pub use health::{HealthMonitor, HealthStatus};
pub use registry::ModelRegistry;
pub use session::SessionStore;
