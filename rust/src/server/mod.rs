//! Serving coordinator — the Layer-3 contribution shaped by the paper's
//! motivation (§1): server-side RNN inference under large-scale concurrent
//! requests, where latency per request and throughput per machine are the
//! product constraints that quantization relieves.
//!
//! Architecture (vLLM-router-style, scaled to RNN LMs):
//!
//! ```text
//! TCP clients ──► router (thread per conn) ──► request queue
//!                                                │
//!                                     dynamic batcher (max_batch / wait)
//!                                                │ per-timestep batches
//!                                     inference workers (quantized LM)
//!                                                │
//!                                     session cache (hidden states, LRU)
//! ```
//!
//! RNN steps are synchronous per token, so the batcher groups *steps* of
//! different sessions into one pass over the weight planes — the
//! concatenated-binary-codes layout of Fig. 3 (right).

pub mod batcher;
pub mod protocol;
pub mod session;
pub mod tcp;

pub use batcher::{BatcherConfig, InferenceServer, Request, Response};
pub use session::SessionStore;
