//! Serving coordinator — the Layer-3 contribution shaped by the paper's
//! motivation (§1): server-side RNN inference under large-scale concurrent
//! requests, where latency per request and throughput per machine are the
//! product constraints that quantization relieves.
//!
//! Architecture (vLLM-router-style, scaled to RNN LMs):
//!
//! ```text
//! TCP clients ──► router (thread per conn) ──► request queue
//!                                                │
//!                                     dynamic batcher (max_batch / wait)
//!                                                │ gather LmStateBatch
//!                                     batched forward (RnnLm::step_batch_exec)
//!                                       · one ActivationBatch per layer,
//!                                         quantized once per batch
//!                                       · one sweep over each packed
//!                                         weight plane serves all B
//!                                         columns (PreparedGemm)
//!                                                │
//!                                ┌─── exec worker pool (BatcherConfig.exec) ───┐
//!                                │ W_x / W_h gate products as parallel tasks;  │
//!                                │ each GEMM row-sharded into disjoint output  │
//!                                │ row ranges across `threads` workers         │
//!                                │ (threads = 1 ⇒ the exact serial path)       │
//!                                └──────────────────────────────────────────────┘
//!                                                │ scatter states
//!                                     session cache (hidden states, LRU)
//! ```
//!
//! RNN steps are synchronous per token, so the batcher groups *steps* of
//! different sessions and executes them as **one** batched XNOR/popcount
//! GEMM per weight matrix — the concatenated-binary-codes layout of Fig. 3
//! (right) — and the execution engine (`crate::exec`) spreads that GEMM's
//! output rows across the machine's cores. Both layers are exactness-
//! preserving: `step_batch_exec` bit-matches per-session `step` for every
//! batch size *and* thread count (`rust/tests/exec_parity.rs`), so neither
//! dynamic batching nor the worker pool ever changes what a client
//! observes. Dropping the server joins the pool's workers — shutdown leaks
//! no threads.

pub mod batcher;
pub mod protocol;
pub mod session;
pub mod tcp;

pub use batcher::{BatcherConfig, InferenceServer, Request, Response};
pub use session::SessionStore;
