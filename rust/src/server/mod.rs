//! Serving coordinator — the Layer-3 contribution shaped by the paper's
//! motivation (§1): server-side RNN inference under large-scale concurrent
//! requests, where latency per request and throughput per machine are the
//! product constraints that quantization relieves.
//!
//! Architecture (vLLM-router-style, scaled to RNN LMs):
//!
//! ```text
//! TCP clients ──► router (thread per conn) ──► request queue
//!                                                │
//!                                     dynamic batcher (max_batch / wait)
//!                                                │ gather LmStateBatch
//!                                     batched forward (RnnLm::step_batch)
//!                                       · one ActivationBatch per layer,
//!                                         quantized once per batch
//!                                       · one sweep over each packed
//!                                         weight plane serves all B
//!                                         columns (PreparedGemm)
//!                                                │ scatter states
//!                                     session cache (hidden states, LRU)
//! ```
//!
//! RNN steps are synchronous per token, so the batcher groups *steps* of
//! different sessions and executes them as **one** batched XNOR/popcount
//! GEMM per weight matrix — the concatenated-binary-codes layout of Fig. 3
//! (right). `step_batch` bit-matches per-session `step`, so dynamic
//! batching never changes what any client observes.

pub mod batcher;
pub mod protocol;
pub mod session;
pub mod tcp;

pub use batcher::{BatcherConfig, InferenceServer, Request, Response};
pub use session::SessionStore;
