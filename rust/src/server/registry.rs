//! Multi-tenant model registry: named models, lazy `.amqz` loading, and
//! LRU eviction under a byte budget.
//!
//! The paper's ~16× memory saving (2-bit packed planes vs dense f32) is
//! what makes many-models-resident serving realistic; the registry turns
//! that into a policy. Entries come in two flavors:
//!
//! - **pinned** — built in process (`insert_resident`, e.g. the legacy
//!   single-model `amq serve` path). There is nowhere to reload them
//!   from, so they are never evicted.
//! - **path-backed** — registered with a `.amqz` file (`register_path`).
//!   Loaded lazily on first use via the zero-copy `data::amqz` loader and
//!   evictable: whenever resident bytes exceed the budget, the
//!   least-recently-used *idle* path-backed model is dropped (and counted),
//!   to be reloaded on its next request.
//!
//! Eviction drops the model's `Arc` — memory is actually reclaimed once
//! the batcher also drops its decode lane, which is why [`acquire`]
//! reports the evicted names back to the caller. A model's saved session
//! states live in its lane, so eviction also forgets its sessions;
//! clients of a swapped-out model re-prime on their next `GEN`.
//!
//! Entries can also be **poisoned**: when a model's decode lane panics,
//! the batcher marks the entry here so later acquires answer
//! `ERR MODEL_POISONED` instead of rebuilding a lane on a model that just
//! proved it can panic. The mark is cleared only by a successful operator
//! [`reload`] (for path-backed entries that re-reads the `.amqz` from
//! disk, eagerly, so a corrupt file fails the `RELOAD` itself).
//!
//! Error values are wire-ready strings (they go out verbatim after
//! `ERR `), matching the taxonomy in `server::protocol`.
//!
//! [`acquire`]: ModelRegistry::acquire
//! [`reload`]: ModelRegistry::reload

use std::path::PathBuf;
use std::sync::Arc;

use crate::data::amqz;
use crate::model::lm::LmConfig;
use crate::model::RnnLm;
use crate::server::faults::FaultPlan;

/// One registered model.
pub struct ModelEntry {
    pub name: String,
    /// `.amqz` source (`None` = pinned in memory).
    pub path: Option<PathBuf>,
    model: Option<Arc<RnnLm>>,
    /// Set when this model's lane panicked; acquires refuse until a
    /// successful `RELOAD` clears it.
    pub poisoned: bool,
    /// Weight bytes while resident (sticky after the first load so STATS
    /// stays informative for evicted entries).
    pub bytes: usize,
    /// Logical timestamp of the last acquire — the LRU key.
    last_used: u64,
    /// The config this entry's serving lane was built for, pinned at the
    /// first load. A republished `.amqz` whose header disagrees is refused
    /// on implicit re-acquire (the lane's saved session states are shaped
    /// for this config); an explicit `RELOAD` adopts the new config.
    expected: Option<LmConfig>,
    /// Requests served while resident (admission-time acquires).
    pub hits: u64,
    /// Cold loads from disk.
    pub loads: u64,
    /// Times this model was evicted.
    pub evictions: u64,
}

impl ModelEntry {
    pub fn resident(&self) -> bool {
        self.model.is_some()
    }
}

/// The registry. Linear scans throughout — the population is "models an
/// operator configured", not a data structure problem.
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
    /// `alias → canonical` pairs, resolved one level deep.
    aliases: Vec<(String, String)>,
    default_name: Option<String>,
    /// Resident-bytes budget; 0 = unlimited.
    budget: usize,
    clock: u64,
    /// Total evictions across all entries (STATS `model_evictions`).
    pub total_evictions: u64,
    /// Fault-injection seam for `.amqz` loads (`None` = disabled).
    faults: Option<Arc<FaultPlan>>,
}

impl ModelRegistry {
    pub fn new(budget_bytes: usize) -> Self {
        ModelRegistry {
            entries: Vec::new(),
            aliases: Vec::new(),
            default_name: None,
            budget: budget_bytes,
            clock: 0,
            total_evictions: 0,
            faults: None,
        }
    }

    /// Arm (or disarm) the fault-injection seam for disk loads.
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    /// Names are constrained so they embed cleanly in both the wire
    /// protocol (whitespace-split) and the STATS JSON (no escapes needed).
    fn validate_name(name: &str) -> Result<(), String> {
        let ok = !name.is_empty()
            && name.len() <= 64
            && name.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'_' | b'-' | b'.'));
        if ok {
            Ok(())
        } else {
            Err(format!("invalid model name '{name}' (want [A-Za-z0-9._-]{{1,64}})"))
        }
    }

    fn entry(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    fn entry_mut(&mut self, name: &str) -> Option<&mut ModelEntry> {
        self.entries.iter_mut().find(|e| e.name == name)
    }

    fn add(
        &mut self,
        name: &str,
        path: Option<PathBuf>,
        model: Option<Arc<RnnLm>>,
    ) -> Result<(), String> {
        Self::validate_name(name)?;
        if self.entry(name).is_some() || self.aliases.iter().any(|(a, _)| a == name) {
            return Err(format!("model name '{name}' already registered"));
        }
        let bytes = model.as_ref().map_or(0, |m| m.bytes());
        let expected = model.as_ref().map(|m| m.config);
        self.entries.push(ModelEntry {
            name: name.to_string(),
            path,
            model,
            poisoned: false,
            bytes,
            last_used: 0,
            expected,
            hits: 0,
            loads: 0,
            evictions: 0,
        });
        if self.default_name.is_none() {
            self.default_name = Some(name.to_string());
        }
        Ok(())
    }

    /// Register a model that is already in memory (pinned, never evicted).
    /// The first registered model becomes the default.
    pub fn insert_resident(&mut self, name: &str, model: Arc<RnnLm>) -> Result<(), String> {
        self.add(name, None, Some(model))
    }

    /// Register a published `.amqz` for lazy loading. The first registered
    /// model becomes the default.
    pub fn register_path(&mut self, name: &str, path: PathBuf) -> Result<(), String> {
        self.add(name, Some(path), None)
    }

    /// Register `alias` as another name for `target` (which must already
    /// be registered).
    pub fn alias(&mut self, alias: &str, target: &str) -> Result<(), String> {
        Self::validate_name(alias)?;
        if self.entry(alias).is_some() || self.aliases.iter().any(|(a, _)| a == alias) {
            return Err(format!("model name '{alias}' already registered"));
        }
        if self.entry(target).is_none() {
            return Err(format!("unknown model '{target}'"));
        }
        self.aliases.push((alias.to_string(), target.to_string()));
        Ok(())
    }

    /// Make `name` (a model or alias) the default for requests without a
    /// `MODEL` field.
    pub fn set_default(&mut self, name: &str) -> Result<(), String> {
        let canonical = self.resolve(Some(name))?;
        self.default_name = Some(canonical);
        Ok(())
    }

    pub fn default_name(&self) -> Option<&str> {
        self.default_name.as_deref()
    }

    /// Resolve a request's model field to the canonical entry name.
    pub fn resolve(&self, name: Option<&str>) -> Result<String, String> {
        let name = match name {
            Some(n) => n,
            None => self.default_name.as_deref().ok_or("no models configured")?,
        };
        if self.entry(name).is_some() {
            return Ok(name.to_string());
        }
        if let Some((_, target)) = self.aliases.iter().find(|(a, _)| a == name) {
            if self.entry(target).is_some() {
                return Ok(target.clone());
            }
        }
        Err(format!("unknown model '{name}'"))
    }

    fn resident_bytes(&self) -> usize {
        self.entries.iter().filter(|e| e.resident()).map(|e| e.bytes).sum()
    }

    /// Get `name`'s model (canonical name — call [`Self::resolve`] first),
    /// loading it from disk on a miss, then LRU-evict idle path-backed
    /// models while resident bytes exceed the budget. `idle(other)` tells
    /// whether `other`'s decode lane is quiescent (a model mid-decode is
    /// never evicted). Returns the model plus the names evicted — the
    /// caller must drop its lanes for those, or the memory stays live.
    pub fn acquire(
        &mut self,
        name: &str,
        idle: impl Fn(&str) -> bool,
    ) -> Result<(Arc<RnnLm>, Vec<String>), String> {
        self.clock += 1;
        let clock = self.clock;
        let budget = self.budget;
        let faults = self.faults.clone();
        let entry = self.entry_mut(name).ok_or_else(|| format!("unknown model '{name}'"))?;
        if entry.poisoned {
            return Err(format!(
                "MODEL_POISONED model '{name}' quarantined after a lane panic; RELOAD {name} to restore"
            ));
        }
        entry.last_used = clock;
        let model = match &entry.model {
            Some(m) => {
                entry.hits += 1;
                Arc::clone(m)
            }
            None => {
                let path = entry.path.clone().ok_or_else(|| {
                    format!("model '{name}' has no source to load from")
                })?;
                if faults.as_ref().is_some_and(|f| f.on_model_load(name)) {
                    return Err(format!("model {name}: injected fault: corrupt load"));
                }
                let model = Arc::new(amqz::load_model(&path).map_err(|e| {
                    match e.downcast_ref::<amqz::CorruptModel>() {
                        // Checksum-verified damage gets its own wire-ready
                        // taxonomy entry, naming the failed section.
                        Some(c) => format!("MODEL_CORRUPT {name} {}: {}", c.section, c.detail),
                        None => format!("model {name}: {e:#}"),
                    }
                })?);
                match entry.expected {
                    Some(cfg) if cfg != model.config => {
                        return Err(format!(
                            "MODEL_CORRUPT {name} header: on-disk config {:?} disagrees \
                             with the serving lane's {cfg:?}; RELOAD {name} to adopt a \
                             republished model",
                            model.config
                        ));
                    }
                    _ => entry.expected = Some(model.config),
                }
                entry.model = Some(Arc::clone(&model));
                entry.bytes = model.bytes();
                entry.loads += 1;
                entry.hits += 1;
                model
            }
        };
        let mut evicted = Vec::new();
        if budget > 0 {
            while self.resident_bytes() > budget {
                let victim = self
                    .entries
                    .iter()
                    .filter(|e| {
                        e.resident() && e.path.is_some() && e.name != name && idle(&e.name)
                    })
                    .min_by_key(|e| e.last_used)
                    .map(|e| e.name.clone());
                let Some(victim) = victim else { break };
                let Some(e) = self.entry_mut(&victim) else { break };
                e.model = None;
                e.evictions += 1;
                self.total_evictions += 1;
                evicted.push(victim);
            }
        }
        Ok((model, evicted))
    }

    /// Mark `name` (canonical) poisoned: a lane panic proved the model
    /// unsafe to serve. Acquires refuse until [`Self::reload`] succeeds.
    pub fn poison(&mut self, name: &str) {
        if let Some(e) = self.entry_mut(name) {
            e.poisoned = true;
        }
    }

    /// Operator `RELOAD <name>` (canonical): clear the poison mark and
    /// re-publish the entry. Path-backed entries drop their resident model
    /// and re-read the `.amqz` **eagerly**, so a corrupt file fails the
    /// RELOAD right now instead of the next unlucky request; pinned
    /// entries have no disk copy, so reload just clears the mark. On
    /// failure the previous poison state is restored.
    pub fn reload(
        &mut self,
        name: &str,
        idle: impl Fn(&str) -> bool,
    ) -> Result<(Arc<RnnLm>, Vec<String>), String> {
        let (was_poisoned, was_expected) = {
            let entry =
                self.entry_mut(name).ok_or_else(|| format!("unknown model '{name}'"))?;
            let was = (entry.poisoned, entry.expected);
            entry.poisoned = false;
            if entry.path.is_some() {
                entry.model = None; // force a fresh read from disk
                entry.expected = None; // an explicit RELOAD may change config
            }
            was
        };
        match self.acquire(name, idle) {
            Ok(r) => Ok(r),
            Err(msg) => {
                if let Some(e) = self.entry_mut(name) {
                    e.poisoned = was_poisoned;
                    e.expected = was_expected;
                }
                Err(msg)
            }
        }
    }

    /// Entries in registration order (deterministic STATS / lane
    /// iteration).
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy};
    use crate::model::RnnKind;

    fn tiny(seed: u64) -> Arc<RnnLm> {
        let config = LmConfig { kind: RnnKind::Gru, vocab: 30, hidden: 8, layers: 1 };
        Arc::new(RnnLm::random(config, seed, PrecisionPolicy::quantized(2, 2)))
    }

    fn publish(seed: u64, tag: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("registry_unit_{}_{tag}.amqz", std::process::id()));
        crate::data::amqz::save(&path, &tiny(seed).to_packed().unwrap()).unwrap();
        path
    }

    #[test]
    fn resolve_follows_aliases_and_default() {
        let mut r = ModelRegistry::new(0);
        r.insert_resident("base", tiny(1)).unwrap();
        r.alias("prod", "base").unwrap();
        assert_eq!(r.resolve(None).unwrap(), "base");
        assert_eq!(r.resolve(Some("prod")).unwrap(), "base");
        assert_eq!(r.resolve(Some("nope")).unwrap_err(), "unknown model 'nope'");
        assert!(r.alias("base", "base").is_err(), "duplicate names rejected");
        assert!(r.insert_resident("bad name", tiny(2)).is_err());
    }

    #[test]
    fn lru_evicts_idle_path_backed_models_under_budget() {
        let (pa, pb, pc) = (publish(1, "a"), publish(2, "b"), publish(3, "c"));
        let one = tiny(1).bytes();
        let mut r = ModelRegistry::new(2 * one + one / 2);
        r.register_path("a", pa.clone()).unwrap();
        r.register_path("b", pb.clone()).unwrap();
        r.register_path("c", pc.clone()).unwrap();

        let (_, ev) = r.acquire("a", |_| true).unwrap();
        assert!(ev.is_empty());
        let (_, ev) = r.acquire("b", |_| true).unwrap();
        assert!(ev.is_empty());
        // Third load busts the 2.5-model budget: `a` is LRU.
        let (_, ev) = r.acquire("c", |_| true).unwrap();
        assert_eq!(ev, vec!["a".to_string()]);
        assert!(!r.entry("a").unwrap().resident());
        assert_eq!(r.total_evictions, 1);

        // Re-acquiring `a` reloads it and evicts `b` (now LRU).
        let (_, ev) = r.acquire("a", |_| true).unwrap();
        assert_eq!(ev, vec!["b".to_string()]);
        assert_eq!(r.entry("a").unwrap().loads, 2);

        // A busy (non-idle) model is never evicted.
        let (_, ev) = r.acquire("b", |n| n != "c").unwrap();
        assert_eq!(ev, vec!["a".to_string()], "c is busy, so the other idle entry goes");

        for p in [pa, pb, pc] {
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn poisoned_entries_refuse_until_a_successful_reload() {
        let pb = publish(4, "poison_b");
        let mut r = ModelRegistry::new(0);
        r.register_path("b", pb.clone()).unwrap();
        assert!(r.acquire("b", |_| true).is_ok());

        r.poison("b");
        let err = r.acquire("b", |_| true).unwrap_err();
        assert!(err.starts_with("MODEL_POISONED "), "{err}");

        // Corrupt the file: RELOAD fails eagerly and the poison sticks.
        let good = std::fs::read(&pb).unwrap();
        std::fs::write(&pb, b"not an amqz file").unwrap();
        let err = r.reload("b", |_| true).unwrap_err();
        assert!(err.starts_with("model b:"), "{err}");
        assert!(r.acquire("b", |_| true).unwrap_err().starts_with("MODEL_POISONED "));

        // Restore the file: RELOAD clears the mark and re-reads the disk.
        std::fs::write(&pb, &good).unwrap();
        let loads_before = r.entry("b").unwrap().loads;
        r.reload("b", |_| true).unwrap();
        assert_eq!(r.entry("b").unwrap().loads, loads_before + 1, "eager re-read");
        assert!(r.acquire("b", |_| true).is_ok());
        std::fs::remove_file(pb).unwrap();
    }

    #[test]
    fn injected_load_fault_fails_one_acquire_then_recovers() {
        let pb = publish(5, "fault_b");
        let mut r = ModelRegistry::new(0);
        r.register_path("b", pb.clone()).unwrap();
        let plan = Arc::new(FaultPlan::parse("load_err=b").unwrap());
        r.set_faults(Some(Arc::clone(&plan)));
        let err = r.acquire("b", |_| true).unwrap_err();
        assert_eq!(err, "model b: injected fault: corrupt load");
        assert_eq!(plan.injected(), 1);
        // The fault fires once; the retry loads for real.
        assert!(r.acquire("b", |_| true).is_ok());
        assert_eq!(plan.injected(), 1);
        std::fs::remove_file(pb).unwrap();
    }

    #[test]
    fn corrupt_files_are_refused_with_the_model_corrupt_taxonomy() {
        let pb = publish(6, "corrupt_b");
        let mut r = ModelRegistry::new(0);
        r.register_path("b", pb.clone()).unwrap();
        // Flip one byte mid-file: a per-section CRC catches it and the
        // error is wire-ready (`ERR MODEL_CORRUPT <name> <section>`).
        let mut bytes = std::fs::read(&pb).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        std::fs::write(&pb, &bytes).unwrap();
        let err = r.acquire("b", |_| true).unwrap_err();
        assert!(err.starts_with("MODEL_CORRUPT b "), "{err}");
        std::fs::remove_file(pb).unwrap();
    }

    #[test]
    fn config_changes_are_refused_on_reacquire_but_adopted_by_reload() {
        let (pa, pb) = (publish(7, "cfg_a"), publish(8, "cfg_b"));
        let one = tiny(1).bytes();
        let mut r = ModelRegistry::new(one + one / 2);
        r.register_path("a", pa.clone()).unwrap();
        r.register_path("b", pb.clone()).unwrap();
        r.acquire("a", |_| true).unwrap();
        let (_, ev) = r.acquire("b", |_| true).unwrap();
        assert_eq!(ev, vec!["a".to_string()], "budget fits ~1.5 models");

        // Republish `a` with a different hidden size while it is evicted:
        // its lane's saved sessions are shaped for the old config, so a
        // silent swap on re-acquire must be refused.
        let config = LmConfig { kind: RnnKind::Gru, vocab: 30, hidden: 16, layers: 1 };
        let bigger = RnnLm::random(config, 9, PrecisionPolicy::quantized(2, 2));
        crate::data::amqz::save(&pa, &bigger.to_packed().unwrap()).unwrap();
        let err = r.acquire("a", |_| true).unwrap_err();
        assert!(err.starts_with("MODEL_CORRUPT a header:"), "{err}");

        // An explicit operator RELOAD adopts the republished config.
        let (m, _) = r.reload("a", |_| true).unwrap();
        assert_eq!(m.config.hidden, 16);
        assert!(r.acquire("a", |_| true).is_ok());
        for p in [pa, pb] {
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn pinned_models_are_never_evicted() {
        let pb = publish(2, "pin_b");
        let one = tiny(1).bytes();
        let mut r = ModelRegistry::new(one); // budget fits only one model
        r.insert_resident("pinned", tiny(1)).unwrap();
        r.register_path("b", pb.clone()).unwrap();
        // Loading `b` exceeds the budget, but `pinned` has no path and the
        // just-acquired `b` is protected: nothing can go.
        let (_, ev) = r.acquire("b", |_| true).unwrap();
        assert!(ev.is_empty());
        assert!(r.entry("pinned").unwrap().resident());
        std::fs::remove_file(pb).unwrap();
    }
}
