//! Deterministic fault injection for the serving stack.
//!
//! [`FaultPlan`] is the single seam every injection point consults. It is
//! off by default — `AMQ_FAULTS` unset means every call site branches on a
//! `None` option and does nothing else, so the steady-state decode path
//! stays zero-cost (and zero-alloc). `amq serve` arms it from the
//! environment via [`FaultPlan::from_env`]; tests construct plans directly
//! with [`FaultPlan::parse`] so several plans can coexist in one test
//! binary.
//!
//! Every trigger is either counter-based (the Nth event) or drawn from a
//! seeded LCG, so a failing CI run replays exactly from its `AMQ_FAULTS`
//! string. Plan syntax is comma-separated `key=value` pairs:
//!
//! | key | value | effect |
//! |-----|-------|--------|
//! | `panic_lane` | `NAME@STEP` | panic at entry to lane `NAME`'s `STEP`-th timestep (lane-local, 1-based; fires once) |
//! | `stall_lane` | `NAME@STEP:MS` | sleep `MS` ms at entry to lane `NAME`'s `STEP`-th timestep (fires once; drives deterministic deadline expiry) |
//! | `short_write` | probability `0..=1` | truncate an event-loop socket write to one byte |
//! | `short_read` | probability `0..=1` | truncate an event-loop socket read to one byte |
//! | `write_err` | `N` | the `N`-th socket write (global, 1-based) fails with `BrokenPipe` |
//! | `clog_write` | `N` | the `N`-th socket write clogs its connection: that write and all later ones on the same connection pretend `WouldBlock` (simulated zero-window peer; arms `--write-stall-ms`) |
//! | `accept_err` | `N` | the first `N` accept passes fail `EMFILE`-style (level-triggered readiness retries them, so clients see delay, not refusal) |
//! | `load_err` | `NAME` | the next registry `.amqz` load of `NAME` fails (fires once) |
//! | `torn_write` | `N` | truncate the next published `.amqz` at byte offset `N` (fires once; simulates a torn write / post-publish bit rot that the checksum trailer must refuse at load) |
//! | `bitflip` | `OFF:MASK` | XOR the published byte at offset `OFF` with `MASK` (hex `0x..` or decimal; fires once; the per-section CRC must name the damaged section) |
//! | `fsync_err` | flag (bare or `=1`) | the next publish fails at its fsync boundary (fires once; the previous artifact must survive untouched) |
//! | `seed` | `N` | LCG seed for the probabilistic faults (default `0x5eed`) |
//!
//! The plan also counts every fault it actually fires ([`injected`]) —
//! that single counter is what STATS reports as `faults_injected`, so a
//! test holding the same `Arc<FaultPlan>` can cross-check injected vs
//! observed counts exactly.
//!
//! [`injected`]: FaultPlan::injected

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What an event-loop connection write attempt should do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteFault {
    /// Write normally.
    None,
    /// Truncate to one byte (partial-write resume must reframe correctly).
    Short,
    /// Fail with `BrokenPipe` (peer reset mid-reply).
    Error,
    /// Simulated zero-window peer: this and every later write on the
    /// connection pretend `WouldBlock`, so the write buffer never drains.
    Clog,
}

/// A parsed, armed fault plan. See the module docs for the syntax.
#[derive(Debug, Default)]
pub struct FaultPlan {
    panic_lane: Option<(String, u64)>,
    stall_lane: Option<(String, u64, u64)>,
    short_write: f64,
    short_read: f64,
    write_err: u64,
    clog_write: u64,
    accept_err: u64,
    load_err: Option<String>,
    torn_write: Option<u64>,
    bitflip: Option<(u64, u8)>,
    fsync_err: bool,
    /// Runtime state: LCG cursor, global write counter, accept-failure
    /// budget used, fire-once latches, and the injected-fault count.
    rng: AtomicU64,
    writes: AtomicU64,
    accepts: AtomicU64,
    panic_fired: AtomicU64,
    stall_fired: AtomicU64,
    load_fired: AtomicU64,
    torn_fired: AtomicU64,
    bitflip_fired: AtomicU64,
    fsync_fired: AtomicU64,
    injected: AtomicU64,
}

/// Fire-once latch: true exactly on the first call.
fn once(flag: &AtomicU64) -> bool {
    flag.swap(1, Ordering::Relaxed) == 0
}

fn parse_count(key: &str, value: &str) -> Result<u64, String> {
    value.parse::<u64>().map_err(|_| format!("fault {key}: want an integer, got '{value}'"))
}

fn parse_prob(key: &str, value: &str) -> Result<f64, String> {
    let p = value
        .parse::<f64>()
        .map_err(|_| format!("fault {key}: want a probability, got '{value}'"))?;
    if (0.0..=1.0).contains(&p) {
        Ok(p)
    } else {
        Err(format!("fault {key}: probability out of range 0..=1: {value}"))
    }
}

/// `NAME@STEP` → `(name, step)`.
fn parse_at(key: &str, value: &str) -> Result<(String, u64), String> {
    let (name, step) =
        value.split_once('@').ok_or_else(|| format!("fault {key}: want NAME@STEP, got '{value}'"))?;
    if name.is_empty() {
        return Err(format!("fault {key}: empty lane name in '{value}'"));
    }
    Ok((name.to_string(), parse_count(key, step)?))
}

/// `NAME@STEP:MS` → `(name, step, ms)`.
fn parse_stall(key: &str, value: &str) -> Result<(String, u64, u64), String> {
    let (at, ms) = value
        .split_once(':')
        .ok_or_else(|| format!("fault {key}: want NAME@STEP:MS, got '{value}'"))?;
    let (name, step) = parse_at(key, at)?;
    Ok((name, step, parse_count(key, ms)?))
}

/// `OFF:MASK` → `(offset, mask)`, mask in decimal or `0x..` hex.
fn parse_bitflip(key: &str, value: &str) -> Result<(u64, u8), String> {
    let (off, mask) = value
        .split_once(':')
        .ok_or_else(|| format!("fault {key}: want OFF:MASK, got '{value}'"))?;
    let off = parse_count(key, off)?;
    let mask = match mask.strip_prefix("0x").or_else(|| mask.strip_prefix("0X")) {
        Some(hex) => u8::from_str_radix(hex, 16)
            .map_err(|_| format!("fault {key}: want a byte mask, got '{mask}'"))?,
        None => mask.parse::<u8>().map_err(|_| format!("fault {key}: want a byte mask, got '{mask}'"))?,
    };
    if mask == 0 {
        return Err(format!("fault {key}: mask 0 flips nothing"));
    }
    Ok((off, mask))
}

impl FaultPlan {
    /// Parse a plan from its `AMQ_FAULTS` syntax. An empty spec is a valid
    /// plan that never fires.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        let mut seed = 0x5eed_u64;
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if item == "fsync_err" {
                plan.fsync_err = true;
                continue;
            }
            let (key, value) =
                item.split_once('=').ok_or_else(|| format!("fault '{item}': want key=value"))?;
            match key {
                "panic_lane" => plan.panic_lane = Some(parse_at(key, value)?),
                "stall_lane" => plan.stall_lane = Some(parse_stall(key, value)?),
                "short_write" => plan.short_write = parse_prob(key, value)?,
                "short_read" => plan.short_read = parse_prob(key, value)?,
                "write_err" => plan.write_err = parse_count(key, value)?,
                "clog_write" => plan.clog_write = parse_count(key, value)?,
                "accept_err" => plan.accept_err = parse_count(key, value)?,
                "load_err" => plan.load_err = Some(value.to_string()),
                "torn_write" => plan.torn_write = Some(parse_count(key, value)?),
                "bitflip" => plan.bitflip = Some(parse_bitflip(key, value)?),
                "fsync_err" => plan.fsync_err = parse_count(key, value)? != 0,
                "seed" => seed = parse_count(key, value)?,
                other => return Err(format!("unknown fault key '{other}'")),
            }
        }
        plan.rng = AtomicU64::new(seed);
        Ok(plan)
    }

    /// Read `AMQ_FAULTS`. `Ok(None)` when unset or blank (the common,
    /// zero-cost case).
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>, String> {
        match std::env::var("AMQ_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(Arc::new(Self::parse(&spec)?))),
            _ => Ok(None),
        }
    }

    /// How many faults this plan has actually fired so far. STATS reports
    /// this verbatim as `faults_injected`.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    fn fire(&self) {
        self.injected.fetch_add(1, Ordering::Relaxed);
    }

    /// Advance the seeded LCG (Knuth MMIX constants, same idiom as the
    /// quantizer fuzz) and draw a uniform in `[0, 1)`.
    fn chance(&self, p: f64) -> bool {
        let prev = self.rng.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
            Some(s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407))
        });
        let x = match prev {
            Ok(v) | Err(v) => v.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407),
        };
        ((x >> 11) as f64 / (1u64 << 53) as f64) < p
    }

    /// Lane-step seam, called at entry to lane `lane`'s `step`-th timestep
    /// (lane-local, 1-based) — inside the batcher's `catch_unwind`, so an
    /// injected panic exercises the real quarantine path.
    pub fn on_lane_step(&self, lane: &str, step: u64) {
        if let Some((name, at, ms)) = &self.stall_lane {
            if name == lane && step == *at && once(&self.stall_fired) {
                self.fire();
                std::thread::sleep(Duration::from_millis(*ms));
            }
        }
        if let Some((name, at)) = &self.panic_lane {
            if name == lane && step == *at && once(&self.panic_fired) {
                self.fire();
                panic!("injected fault: panic_lane={lane}@{step}");
            }
        }
    }

    /// Accept seam: true means this accept pass should fail
    /// `EMFILE`-style. Consumes one unit of the `accept_err` budget.
    pub fn on_accept(&self) -> bool {
        if self.accept_err == 0 || self.accepts.load(Ordering::Relaxed) >= self.accept_err {
            return false;
        }
        let n = self.accepts.fetch_add(1, Ordering::Relaxed) + 1;
        if n <= self.accept_err {
            self.fire();
            true
        } else {
            false
        }
    }

    /// Write seam: consulted once per actual socket write attempt.
    /// Counter-based faults (`write_err`, `clog_write`) take priority over
    /// the probabilistic `short_write`.
    pub fn on_conn_write(&self) -> WriteFault {
        if self.write_err == 0 && self.clog_write == 0 && self.short_write <= 0.0 {
            return WriteFault::None;
        }
        let n = self.writes.fetch_add(1, Ordering::Relaxed) + 1;
        if self.write_err != 0 && n == self.write_err {
            self.fire();
            return WriteFault::Error;
        }
        if self.clog_write != 0 && n == self.clog_write {
            self.fire();
            return WriteFault::Clog;
        }
        if self.short_write > 0.0 && self.chance(self.short_write) {
            self.fire();
            return WriteFault::Short;
        }
        WriteFault::None
    }

    /// Read seam: true means truncate this socket read to one byte.
    pub fn on_conn_read(&self) -> bool {
        if self.short_read <= 0.0 {
            return false;
        }
        if self.chance(self.short_read) {
            self.fire();
            true
        } else {
            false
        }
    }

    /// Registry-load seam: true means the `.amqz` load of `model` should
    /// fail (fires once per plan).
    pub fn on_model_load(&self, model: &str) -> bool {
        match &self.load_err {
            Some(name) if name == model && once(&self.load_fired) => {
                self.fire();
                true
            }
            _ => false,
        }
    }

    /// Publish seam: truncate the encoded `.amqz` at this byte offset
    /// before it hits disk (fires once per plan).
    pub fn on_publish_torn_write(&self) -> Option<usize> {
        match self.torn_write {
            Some(n) if once(&self.torn_fired) => {
                self.fire();
                Some(n as usize)
            }
            _ => None,
        }
    }

    /// Publish seam: XOR one byte of the encoded `.amqz` (fires once).
    pub fn on_publish_bitflip(&self) -> Option<(usize, u8)> {
        match self.bitflip {
            Some((off, mask)) if once(&self.bitflip_fired) => {
                self.fire();
                Some((off as usize, mask))
            }
            _ => None,
        }
    }

    /// Publish seam: true means this publish fails at its fsync boundary
    /// (fires once). The caller must leave the previous artifact intact.
    pub fn on_publish_fsync_err(&self) -> bool {
        if self.fsync_err && once(&self.fsync_fired) {
            self.fire();
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_plan_and_rejections() {
        let p = FaultPlan::parse(
            "panic_lane=beta@17, stall_lane=alpha@3:250, short_write=0.1, short_read=0.05, \
             write_err=4, clog_write=7, accept_err=3, load_err=beta, seed=99",
        )
        .unwrap();
        assert_eq!(p.panic_lane, Some(("beta".into(), 17)));
        assert_eq!(p.stall_lane, Some(("alpha".into(), 3, 250)));
        assert_eq!(p.write_err, 4);
        assert_eq!(p.accept_err, 3);
        assert_eq!(p.load_err.as_deref(), Some("beta"));
        assert_eq!(p.injected(), 0);

        assert!(FaultPlan::parse("nope=1").is_err());
        assert!(FaultPlan::parse("panic_lane=beta").is_err(), "missing @STEP");
        assert!(FaultPlan::parse("short_write=1.5").is_err(), "probability range");
        assert!(FaultPlan::parse("write_err=x").is_err());
        assert!(FaultPlan::parse("").unwrap().panic_lane.is_none(), "empty plan is inert");

        let p = FaultPlan::parse("torn_write=4096, bitflip=64:0x80, fsync_err").unwrap();
        assert_eq!(p.torn_write, Some(4096));
        assert_eq!(p.bitflip, Some((64, 0x80)));
        assert!(p.fsync_err);
        assert!(FaultPlan::parse("fsync_err=1").unwrap().fsync_err, "key=value form too");
        assert!(!FaultPlan::parse("fsync_err=0").unwrap().fsync_err);
        assert!(FaultPlan::parse("bitflip=10").is_err(), "missing :MASK");
        assert!(FaultPlan::parse("bitflip=10:0").is_err(), "mask 0 flips nothing");
        assert!(FaultPlan::parse("bitflip=10:0xzz").is_err());
    }

    #[test]
    fn publish_faults_fire_exactly_once() {
        let p = FaultPlan::parse("torn_write=100,bitflip=5:0x01,fsync_err").unwrap();
        assert_eq!(p.on_publish_torn_write(), Some(100));
        assert_eq!(p.on_publish_torn_write(), None, "latched");
        assert_eq!(p.on_publish_bitflip(), Some((5, 0x01)));
        assert_eq!(p.on_publish_bitflip(), None, "latched");
        assert!(p.on_publish_fsync_err());
        assert!(!p.on_publish_fsync_err(), "latched");
        assert_eq!(p.injected(), 3);

        let inert = FaultPlan::parse("seed=1").unwrap();
        assert_eq!(inert.on_publish_torn_write(), None);
        assert_eq!(inert.on_publish_bitflip(), None);
        assert!(!inert.on_publish_fsync_err());
        assert_eq!(inert.injected(), 0);
    }

    #[test]
    fn counter_faults_fire_exactly_once_at_their_index() {
        let p = FaultPlan::parse("write_err=2,clog_write=3").unwrap();
        assert_eq!(p.on_conn_write(), WriteFault::None);
        assert_eq!(p.on_conn_write(), WriteFault::Error);
        assert_eq!(p.on_conn_write(), WriteFault::Clog);
        assert_eq!(p.on_conn_write(), WriteFault::None);
        assert_eq!(p.injected(), 2);

        let p = FaultPlan::parse("accept_err=2").unwrap();
        assert!(p.on_accept());
        assert!(p.on_accept());
        assert!(!p.on_accept(), "budget spent");
        assert_eq!(p.injected(), 2);
    }

    #[test]
    fn lane_faults_match_name_and_step_and_fire_once() {
        let p = FaultPlan::parse("panic_lane=beta@2").unwrap();
        p.on_lane_step("alpha", 2); // wrong lane: no panic
        p.on_lane_step("beta", 1); // wrong step: no panic
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.on_lane_step("beta", 2);
        }));
        assert!(caught.is_err(), "panic fires at beta@2");
        p.on_lane_step("beta", 2); // latched: never again
        assert_eq!(p.injected(), 1);

        let p = FaultPlan::parse("load_err=beta").unwrap();
        assert!(!p.on_model_load("alpha"));
        assert!(p.on_model_load("beta"));
        assert!(!p.on_model_load("beta"), "fires once");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn probabilistic_faults_replay_from_the_seed() {
        let draws = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::parse(&format!("short_write=0.3,seed={seed}")).unwrap();
            (0..64).map(|_| p.on_conn_write() == WriteFault::Short).collect()
        };
        assert_eq!(draws(7), draws(7), "same seed, same sequence");
        assert_ne!(draws(7), draws(8), "different seed, different sequence");
        let hits = draws(7).iter().filter(|b| **b).count();
        assert!(hits > 5 && hits < 40, "p=0.3 over 64 draws lands near 19, got {hits}");
    }
}
