//! Line-based wire protocol for the LM server (one request per line, one
//! response per line; trivially scriptable with `nc`).
//!
//! Requests:
//! ```text
//! GEN <session_id> <max_new_tokens> <tok,tok,...>   generate continuation
//! SCORE <tok,tok,...>                               PPW of a token stream
//! END <session_id>                                  drop a session
//! STATS                                             server metrics (one-line JSON)
//! STATS TEXT                                        …human-readable form
//! ```
//!
//! Responses:
//! ```text
//! OK GEN <tok,tok,...>
//! OK SCORE <ppw>
//! OK END | OK STATS <json-or-text> | ERR <message>
//! ERR BUSY queue full (<queued>/<depth>)            load shed — retry later
//! ```
//!
//! [`format_reply`] renders every batcher [`Reply`] to its wire line —
//! the single formatting path shared by the thread-per-connection and
//! event-loop front ends.

use anyhow::{bail, Result};

use super::batcher::Reply;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Generate { session: u64, max_new: usize, prime: Vec<usize> },
    Score { tokens: Vec<usize> },
    End { session: u64 },
    Stats { text: bool },
}

pub fn parse_request(line: &str) -> Result<WireRequest> {
    let mut parts = line.trim().split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "GEN" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            let max_new: usize = parts.next().unwrap_or("").parse().map_err(|_| bad("max_new"))?;
            if max_new == 0 || max_new > 4096 {
                bail!("max_new out of range (1..=4096)");
            }
            let prime = parse_tokens(parts.next().unwrap_or(""))?;
            if prime.is_empty() {
                bail!("GEN needs at least one prime token");
            }
            Ok(WireRequest::Generate { session, max_new, prime })
        }
        "SCORE" => {
            let tokens = parse_tokens(parts.next().unwrap_or(""))?;
            if tokens.len() < 2 {
                bail!("SCORE needs at least two tokens");
            }
            Ok(WireRequest::Score { tokens })
        }
        "END" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            Ok(WireRequest::End { session })
        }
        "STATS" => match parts.next() {
            None => Ok(WireRequest::Stats { text: false }),
            Some("TEXT") => Ok(WireRequest::Stats { text: true }),
            Some(other) => bail!("unknown STATS form '{other}' (want STATS or STATS TEXT)"),
        },
        other => bail!("unknown verb '{other}'"),
    }
}

/// Render a batcher reply to its single wire line (no trailing newline).
pub fn format_reply(reply: &Reply) -> String {
    match reply {
        Reply::Gen(resp) => format!("OK GEN {}", format_tokens(&resp.tokens)),
        Reply::Score(ppw) => format!("OK SCORE {ppw:.4}"),
        Reply::End(existed) => {
            if *existed {
                "OK END".to_string()
            } else {
                "OK END (no such session)".to_string()
            }
        }
        Reply::Stats(s) => format!("OK STATS {s}"),
        Reply::Busy { queued, depth } => format!("ERR BUSY queue full ({queued}/{depth})"),
    }
}

fn bad(what: &str) -> anyhow::Error {
    anyhow::anyhow!("malformed {what}")
}

fn parse_tokens(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|t| t.parse::<usize>().map_err(|_| bad("token list")))
        .collect()
}

pub fn format_tokens(tokens: &[usize]) -> String {
    tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

/// Split complete `\n`-terminated lines off the front of `buf` (leaving the
/// trailing partial line in place), appending the non-blank ones to `lines`.
/// Carriage returns and surrounding whitespace are trimmed; blank lines are
/// skipped. Errors on any complete line that is not valid UTF-8. Shared by
/// both front ends so framing behaves identically with and without
/// `--event-loop`.
pub fn split_lines(buf: &mut Vec<u8>, lines: &mut Vec<String>) -> std::io::Result<()> {
    let mut start = 0;
    while let Some(rel) = buf[start..].iter().position(|&b| b == b'\n') {
        let end = start + rel;
        let line = std::str::from_utf8(&buf[start..end]).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "request is not UTF-8")
        })?;
        let line = line.trim();
        if !line.is_empty() {
            lines.push(line.to_string());
        }
        start = end + 1;
    }
    buf.drain(..start);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen() {
        let r = parse_request("GEN 42 10 1,2,3\n").unwrap();
        assert_eq!(
            r,
            WireRequest::Generate { session: 42, max_new: 10, prime: vec![1, 2, 3] }
        );
    }

    #[test]
    fn parse_score_and_end_and_stats() {
        assert_eq!(parse_request("SCORE 5,6").unwrap(), WireRequest::Score { tokens: vec![5, 6] });
        assert_eq!(parse_request("END 3").unwrap(), WireRequest::End { session: 3 });
        assert_eq!(parse_request("STATS").unwrap(), WireRequest::Stats { text: false });
        assert_eq!(parse_request("STATS TEXT").unwrap(), WireRequest::Stats { text: true });
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("GEN x 10 1").is_err());
        assert!(parse_request("GEN 1 0 1").is_err());
        assert!(parse_request("GEN 1 10 ").is_err());
        assert!(parse_request("SCORE 1").is_err());
        assert!(parse_request("FROB").is_err());
        assert!(parse_request("GEN 1 10 1,a,3").is_err());
        assert!(parse_request("STATS JSON").is_err());
    }

    #[test]
    fn reply_formatting() {
        use crate::server::batcher::Response;
        let gen = Reply::Gen(Response { tokens: vec![1, 2, 3], queue_us: 0.0, compute_us: 0.0 });
        assert_eq!(format_reply(&gen), "OK GEN 1,2,3");
        assert_eq!(format_reply(&Reply::Score(1.25)), "OK SCORE 1.2500");
        assert_eq!(format_reply(&Reply::End(true)), "OK END");
        assert_eq!(format_reply(&Reply::End(false)), "OK END (no such session)");
        assert_eq!(format_reply(&Reply::Stats("{}".into())), "OK STATS {}");
        assert_eq!(
            format_reply(&Reply::Busy { queued: 4, depth: 4 }),
            "ERR BUSY queue full (4/4)"
        );
    }

    #[test]
    fn token_format_roundtrip() {
        let toks = vec![1usize, 22, 333];
        assert_eq!(parse_tokens(&format_tokens(&toks)).unwrap(), toks);
    }

    #[test]
    fn split_lines_handles_partials_and_pipelining() {
        let mut buf = Vec::new();
        let mut lines = Vec::new();
        // A partial write: no newline yet, nothing extracted.
        buf.extend_from_slice(b"GEN 1 4");
        split_lines(&mut buf, &mut lines).unwrap();
        assert!(lines.is_empty());
        assert_eq!(buf, b"GEN 1 4");
        // The rest of the line plus two pipelined commands in one chunk.
        buf.extend_from_slice(b" 2,3\r\nSTATS\n\nEND 1\nSCO");
        split_lines(&mut buf, &mut lines).unwrap();
        assert_eq!(lines, vec!["GEN 1 4 2,3", "STATS", "END 1"]);
        assert_eq!(buf, b"SCO", "partial tail stays buffered");
        // Byte-at-a-time completion of the tail.
        for &b in b"RE 1,2\n" {
            buf.push(b);
            split_lines(&mut buf, &mut lines).unwrap();
        }
        assert_eq!(lines.last().unwrap(), "SCORE 1,2");
        assert!(buf.is_empty());
    }

    #[test]
    fn split_lines_rejects_non_utf8() {
        let mut buf = vec![0xff, 0xfe, b'\n'];
        assert!(split_lines(&mut buf, &mut Vec::new()).is_err());
    }
}
