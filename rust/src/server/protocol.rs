//! Line-based wire protocol for the LM server (one request per line, one
//! response per line; trivially scriptable with `nc`).
//!
//! Requests:
//! ```text
//! GEN <session_id> <max_new_tokens> <tok,tok,...> [MODEL <name>]
//! SCORE <tok,tok,...> [MODEL <name>]              PPW of a token stream
//! END <session_id> [MODEL <name>]                 drop a session
//! STATS                                           server metrics (one-line JSON)
//! STATS TEXT                                      …human-readable form
//! RELOAD <name>                                   operator: re-publish a model
//! DRAIN                                           operator: stop admission, snapshot sessions
//! HEALTH                                          liveness probe (see below)
//! ```
//!
//! `DRAIN` is the zero-downtime-ops verb (also triggered by SIGTERM): new
//! generations answer `ERR DRAINING`, in-flight decodes finish up to the
//! drain deadline, and every saved session is serialized to the server's
//! `--snapshot` path — a restarted server started with `--restore` revives
//! them bit-exactly. `HEALTH` is answered **by the front end itself** from
//! the shared [`crate::server::HealthMonitor`], never via the batcher's
//! work channel, so a wedged batcher thread is precisely what the probe
//! can still report (`ok`, `degraded` with the stuck lane named, or
//! `draining`).
//!
//! The optional trailing `MODEL <name>` selects a model from the server's
//! registry (`amq serve --model name=path.amqz`, repeatable); omitting it
//! targets the default model. Session ids are scoped per model. Published
//! `.amqz` files (see `data::amqz`) load zero-copy; the registry LRU-evicts
//! idle models past `--model-mem-budget`. Anything after the documented
//! fields is rejected — a request either parses completely or answers
//! `ERR`.
//!
//! `RELOAD` is the operator's recovery verb: it clears a lane-panic
//! quarantine (see `ERR MODEL_POISONED` below) and, for path-backed
//! models, eagerly re-reads the `.amqz` from disk — a corrupt file fails
//! the RELOAD itself. A model currently mid-decode refuses to reload.
//!
//! Responses:
//! ```text
//! OK GEN <tok,tok,...>
//! OK SCORE <ppw>
//! OK END | OK STATS <json-or-text> | OK RELOAD <name> | ERR <message>
//! OK DRAIN <sessions> <path>                      sessions snapshotted, where
//! OK HEALTH <status> [detail] uptime=<n>s         status ∈ ok|degraded|draining
//! ERR BUSY queue full (<queued>/<depth>)          load shed — retry later
//! ```
//!
//! `ERR` taxonomy (the reply's first token after `ERR` tells the class):
//!
//! | reply                                        | cause |
//! |----------------------------------------------|-------|
//! | `ERR unknown verb '<v>'`                     | first word not GEN/SCORE/END/STATS/RELOAD |
//! | `ERR malformed session id`                   | GEN/END id not a u64 |
//! | `ERR malformed max_new`                      | GEN count not a usize |
//! | `ERR max_new out of range (1..=4096)`        | GEN count 0 or beyond the cap |
//! | `ERR malformed token list`                   | token list not comma-separated usizes |
//! | `ERR GEN needs at least one prime token`     | empty prime |
//! | `ERR SCORE needs at least two tokens`        | PPW needs a transition |
//! | `ERR unknown STATS form '<x>'`               | STATS argument other than TEXT |
//! | `ERR MODEL needs a name`                     | trailing `MODEL` with no name |
//! | `ERR RELOAD needs a model name`              | bare `RELOAD` |
//! | `ERR unexpected trailing field '<x>'`        | unconsumed fields after a request |
//! | `ERR token <t> out of vocab <v>`             | admission-time vocab check (OOV) |
//! | `ERR unknown model '<name>'`                 | name not in the registry |
//! | `ERR model <name>: <why>`                    | `.amqz` load failure (incl. a failed RELOAD) |
//! | `ERR model '<name>' is mid-decode; retry RELOAD when idle` | RELOAD raced in-flight requests |
//! | `ERR no models configured`                   | registry empty / no default |
//! | `ERR BUSY queue full (<q>/<d>)`              | admission control shed |
//! | `ERR DEADLINE request exceeded <n>ms deadline` | `--request-deadline-ms` expiry; the session drops as if `END` arrived |
//! | `ERR DRAINING <why>`                         | server is draining: new generations refused, stragglers cut at the drain deadline, or `DRAIN` with no `--snapshot` path |
//! | `ERR MODEL_CORRUPT <name> <section>: <why>`  | checksum verification refused a damaged `.amqz` (the failed section is named), or a republished file's config disagrees with the serving lane |
//! | `ERR MODEL_POISONED model '<name>' …`        | the model's lane panicked; quarantined until `RELOAD <name>` succeeds |
//! | `ERR INTERNAL <context>`                     | server-side invariant failure (e.g. the lane serving this request panicked) |
//! | `ERR request line exceeds MAX_LINE`          | framing abuse; connection closes |
//! | `ERR request is not UTF-8`                   | framing abuse; connection closes |
//! | `ERR server shutting down`                   | request raced shutdown |
//!
//! Every error except the two framing classes leaves the connection open;
//! framing errors flush any already-parsed pipelined replies plus the
//! diagnostic, then close.
//!
//! [`format_reply`] renders every batcher [`Reply`] to its wire line —
//! the single formatting path shared by the thread-per-connection and
//! event-loop front ends.

use anyhow::{bail, Result};

use super::batcher::Reply;

/// Longest request line either front end will buffer. The tail left after
/// [`split_lines`] is bounded by this, so a client streaming newline-free
/// bytes cannot grow a connection buffer without bound.
pub const MAX_LINE: usize = 64 * 1024;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Generate { session: u64, max_new: usize, prime: Vec<usize>, model: Option<String> },
    Score { tokens: Vec<usize>, model: Option<String> },
    End { session: u64, model: Option<String> },
    Stats { text: bool },
    Reload { model: String },
    Drain,
    /// Answered front-end-side from the shared `HealthMonitor`; never
    /// enters the batcher's work channel.
    Health,
}

pub fn parse_request(line: &str) -> Result<WireRequest> {
    let mut parts = line.trim().split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "GEN" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            let max_new: usize = parts.next().unwrap_or("").parse().map_err(|_| bad("max_new"))?;
            if max_new == 0 || max_new > 4096 {
                bail!("max_new out of range (1..=4096)");
            }
            let prime = parse_tokens(parts.next().unwrap_or(""))?;
            if prime.is_empty() {
                bail!("GEN needs at least one prime token");
            }
            let model = parse_model(&mut parts)?;
            Ok(WireRequest::Generate { session, max_new, prime, model })
        }
        "SCORE" => {
            let tokens = parse_tokens(parts.next().unwrap_or(""))?;
            if tokens.len() < 2 {
                bail!("SCORE needs at least two tokens");
            }
            let model = parse_model(&mut parts)?;
            Ok(WireRequest::Score { tokens, model })
        }
        "END" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            let model = parse_model(&mut parts)?;
            Ok(WireRequest::End { session, model })
        }
        "STATS" => {
            let text = match parts.next() {
                None => false,
                Some("TEXT") => true,
                Some(other) => bail!("unknown STATS form '{other}' (want STATS or STATS TEXT)"),
            };
            no_trailing(&mut parts)?;
            Ok(WireRequest::Stats { text })
        }
        "RELOAD" => {
            let model = match parts.next() {
                Some(name) => name.to_string(),
                None => bail!("RELOAD needs a model name"),
            };
            no_trailing(&mut parts)?;
            Ok(WireRequest::Reload { model })
        }
        "DRAIN" => {
            no_trailing(&mut parts)?;
            Ok(WireRequest::Drain)
        }
        "HEALTH" => {
            no_trailing(&mut parts)?;
            Ok(WireRequest::Health)
        }
        other => bail!("unknown verb '{other}'"),
    }
}

/// Consume an optional trailing `MODEL <name>` and reject anything else —
/// a request line either parses completely or errors, so malformed
/// pipelining (`GEN 1 10 1,2 9,9`) can't be mis-read as success.
fn parse_model(parts: &mut std::str::SplitWhitespace) -> Result<Option<String>> {
    let model = match parts.next() {
        None => None,
        Some("MODEL") => match parts.next() {
            Some(name) => Some(name.to_string()),
            None => bail!("MODEL needs a name"),
        },
        Some(other) => bail!("unexpected trailing field '{other}'"),
    };
    no_trailing(parts)?;
    Ok(model)
}

fn no_trailing(parts: &mut std::str::SplitWhitespace) -> Result<()> {
    if let Some(extra) = parts.next() {
        bail!("unexpected trailing field '{extra}'");
    }
    Ok(())
}

/// Render a batcher reply to its single wire line (no trailing newline).
pub fn format_reply(reply: &Reply) -> String {
    match reply {
        Reply::Gen(resp) => format!("OK GEN {}", format_tokens(&resp.tokens)),
        Reply::Score(ppw) => format!("OK SCORE {ppw:.4}"),
        Reply::End(existed) => {
            if *existed {
                "OK END".to_string()
            } else {
                "OK END (no such session)".to_string()
            }
        }
        Reply::Stats(s) => format!("OK STATS {s}"),
        Reply::Reloaded(name) => format!("OK RELOAD {name}"),
        Reply::Drained { sessions, path } => format!("OK DRAIN {sessions} {path}"),
        Reply::Error(msg) => format!("ERR {msg}"),
        Reply::Busy { queued, depth } => format!("ERR BUSY queue full ({queued}/{depth})"),
    }
}

fn bad(what: &str) -> anyhow::Error {
    anyhow::anyhow!("malformed {what}")
}

fn parse_tokens(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|t| t.parse::<usize>().map_err(|_| bad("token list")))
        .collect()
}

pub fn format_tokens(tokens: &[usize]) -> String {
    tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

/// Split complete `\n`-terminated lines off the front of `buf` (leaving the
/// trailing partial line in place), appending the non-blank ones to `lines`.
/// Carriage returns and surrounding whitespace are trimmed; blank lines are
/// skipped. Errors on any complete line that is not valid UTF-8. Shared by
/// both front ends so framing behaves identically with and without
/// `--event-loop`. Callers must bound the partial tail left behind against
/// [`MAX_LINE`] — checking only the unsplit buffer would let one valid
/// pipelined line disarm the oversize guard.
pub fn split_lines(buf: &mut Vec<u8>, lines: &mut Vec<String>) -> std::io::Result<()> {
    let mut start = 0;
    while let Some(rel) = buf[start..].iter().position(|&b| b == b'\n') {
        let end = start + rel;
        let line = std::str::from_utf8(&buf[start..end]).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "request is not UTF-8")
        })?;
        let line = line.trim();
        if !line.is_empty() {
            lines.push(line.to_string());
        }
        start = end + 1;
    }
    buf.drain(..start);
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen() {
        let r = parse_request("GEN 42 10 1,2,3\n").unwrap();
        assert_eq!(
            r,
            WireRequest::Generate { session: 42, max_new: 10, prime: vec![1, 2, 3], model: None }
        );
    }

    #[test]
    fn parse_score_and_end_and_stats() {
        assert_eq!(
            parse_request("SCORE 5,6").unwrap(),
            WireRequest::Score { tokens: vec![5, 6], model: None }
        );
        assert_eq!(parse_request("END 3").unwrap(), WireRequest::End { session: 3, model: None });
        assert_eq!(parse_request("STATS").unwrap(), WireRequest::Stats { text: false });
        assert_eq!(parse_request("STATS TEXT").unwrap(), WireRequest::Stats { text: true });
    }

    #[test]
    fn parse_model_field() {
        assert_eq!(
            parse_request("GEN 1 4 7,8 MODEL ptb-2bit").unwrap(),
            WireRequest::Generate {
                session: 1,
                max_new: 4,
                prime: vec![7, 8],
                model: Some("ptb-2bit".into())
            }
        );
        assert_eq!(
            parse_request("SCORE 1,2 MODEL m").unwrap(),
            WireRequest::Score { tokens: vec![1, 2], model: Some("m".into()) }
        );
        assert_eq!(
            parse_request("END 9 MODEL m").unwrap(),
            WireRequest::End { session: 9, model: Some("m".into()) }
        );
        assert!(parse_request("GEN 1 4 7 MODEL").is_err());
    }

    #[test]
    fn parse_reload() {
        assert_eq!(
            parse_request("RELOAD ptb-2bit").unwrap(),
            WireRequest::Reload { model: "ptb-2bit".into() }
        );
        assert_eq!(
            parse_request("RELOAD").unwrap_err().to_string(),
            "RELOAD needs a model name"
        );
        let err = parse_request("RELOAD m x").unwrap_err().to_string();
        assert!(err.contains("trailing field"), "{err}");
    }

    #[test]
    fn parse_drain_and_health() {
        assert_eq!(parse_request("DRAIN").unwrap(), WireRequest::Drain);
        assert_eq!(parse_request("HEALTH").unwrap(), WireRequest::Health);
        for line in ["DRAIN now", "HEALTH TEXT", "DRAIN MODEL m"] {
            let err = parse_request(line).unwrap_err().to_string();
            assert!(err.contains("trailing field"), "{line:?} → {err}");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("GEN x 10 1").is_err());
        assert!(parse_request("GEN 1 0 1").is_err());
        assert!(parse_request("GEN 1 10 ").is_err());
        assert!(parse_request("SCORE 1").is_err());
        assert!(parse_request("FROB").is_err());
        assert!(parse_request("GEN 1 10 1,a,3").is_err());
        assert!(parse_request("STATS JSON").is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        for line in [
            "GEN 1 10 1,2 9,9",
            "GEN 1 10 1,2 MODEL m extra",
            "SCORE 1,2 junk",
            "END 3 junk",
            "END 3 MODEL m x",
            "STATS TEXT x",
            "STATS TEXT MODEL m",
        ] {
            let err = parse_request(line).unwrap_err().to_string();
            assert!(err.contains("trailing field"), "{line:?} → {err}");
        }
    }

    #[test]
    fn reply_formatting() {
        use crate::server::batcher::Response;
        let gen = Reply::Gen(Response { tokens: vec![1, 2, 3], queue_us: 0.0, compute_us: 0.0 });
        assert_eq!(format_reply(&gen), "OK GEN 1,2,3");
        assert_eq!(format_reply(&Reply::Score(1.25)), "OK SCORE 1.2500");
        assert_eq!(format_reply(&Reply::End(true)), "OK END");
        assert_eq!(format_reply(&Reply::End(false)), "OK END (no such session)");
        assert_eq!(format_reply(&Reply::Stats("{}".into())), "OK STATS {}");
        assert_eq!(format_reply(&Reply::Reloaded("beta".into())), "OK RELOAD beta");
        assert_eq!(
            format_reply(&Reply::Drained { sessions: 3, path: "/tmp/s.amqs".into() }),
            "OK DRAIN 3 /tmp/s.amqs"
        );
        assert_eq!(
            format_reply(&Reply::Error("token 99 out of vocab 40".into())),
            "ERR token 99 out of vocab 40"
        );
        assert_eq!(
            format_reply(&Reply::Busy { queued: 4, depth: 4 }),
            "ERR BUSY queue full (4/4)"
        );
    }

    #[test]
    fn token_format_roundtrip() {
        let toks = vec![1usize, 22, 333];
        assert_eq!(parse_tokens(&format_tokens(&toks)).unwrap(), toks);
    }

    #[test]
    fn split_lines_handles_partials_and_pipelining() {
        let mut buf = Vec::new();
        let mut lines = Vec::new();
        // A partial write: no newline yet, nothing extracted.
        buf.extend_from_slice(b"GEN 1 4");
        split_lines(&mut buf, &mut lines).unwrap();
        assert!(lines.is_empty());
        assert_eq!(buf, b"GEN 1 4");
        // The rest of the line plus two pipelined commands in one chunk.
        buf.extend_from_slice(b" 2,3\r\nSTATS\n\nEND 1\nSCO");
        split_lines(&mut buf, &mut lines).unwrap();
        assert_eq!(lines, vec!["GEN 1 4 2,3", "STATS", "END 1"]);
        assert_eq!(buf, b"SCO", "partial tail stays buffered");
        // Byte-at-a-time completion of the tail.
        for &b in b"RE 1,2\n" {
            buf.push(b);
            split_lines(&mut buf, &mut lines).unwrap();
        }
        assert_eq!(lines.last().unwrap(), "SCORE 1,2");
        assert!(buf.is_empty());
    }

    #[test]
    fn split_lines_rejects_non_utf8() {
        let mut buf = vec![0xff, 0xfe, b'\n'];
        assert!(split_lines(&mut buf, &mut Vec::new()).is_err());
    }
}
