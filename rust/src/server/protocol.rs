//! Line-based wire protocol for the LM server (one request per line, one
//! response per line; trivially scriptable with `nc`).
//!
//! Requests:
//! ```text
//! GEN <session_id> <max_new_tokens> <tok,tok,...>   generate continuation
//! SCORE <tok,tok,...>                               PPW of a token stream
//! END <session_id>                                  drop a session
//! STATS                                             server metrics
//! ```
//!
//! Responses:
//! ```text
//! OK GEN <tok,tok,...>
//! OK SCORE <ppw>
//! OK END | OK STATS <text> | ERR <message>
//! ```

use anyhow::{bail, Result};

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Generate { session: u64, max_new: usize, prime: Vec<usize> },
    Score { tokens: Vec<usize> },
    End { session: u64 },
    Stats,
}

pub fn parse_request(line: &str) -> Result<WireRequest> {
    let mut parts = line.trim().split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "GEN" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            let max_new: usize = parts.next().unwrap_or("").parse().map_err(|_| bad("max_new"))?;
            if max_new == 0 || max_new > 4096 {
                bail!("max_new out of range (1..=4096)");
            }
            let prime = parse_tokens(parts.next().unwrap_or(""))?;
            if prime.is_empty() {
                bail!("GEN needs at least one prime token");
            }
            Ok(WireRequest::Generate { session, max_new, prime })
        }
        "SCORE" => {
            let tokens = parse_tokens(parts.next().unwrap_or(""))?;
            if tokens.len() < 2 {
                bail!("SCORE needs at least two tokens");
            }
            Ok(WireRequest::Score { tokens })
        }
        "END" => {
            let session: u64 = parts.next().unwrap_or("").parse().map_err(|_| bad("session id"))?;
            Ok(WireRequest::End { session })
        }
        "STATS" => Ok(WireRequest::Stats),
        other => bail!("unknown verb '{other}'"),
    }
}

fn bad(what: &str) -> anyhow::Error {
    anyhow::anyhow!("malformed {what}")
}

fn parse_tokens(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|t| t.parse::<usize>().map_err(|_| bad("token list")))
        .collect()
}

pub fn format_tokens(tokens: &[usize]) -> String {
    tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen() {
        let r = parse_request("GEN 42 10 1,2,3\n").unwrap();
        assert_eq!(
            r,
            WireRequest::Generate { session: 42, max_new: 10, prime: vec![1, 2, 3] }
        );
    }

    #[test]
    fn parse_score_and_end_and_stats() {
        assert_eq!(parse_request("SCORE 5,6").unwrap(), WireRequest::Score { tokens: vec![5, 6] });
        assert_eq!(parse_request("END 3").unwrap(), WireRequest::End { session: 3 });
        assert_eq!(parse_request("STATS").unwrap(), WireRequest::Stats);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("GEN x 10 1").is_err());
        assert!(parse_request("GEN 1 0 1").is_err());
        assert!(parse_request("GEN 1 10 ").is_err());
        assert!(parse_request("SCORE 1").is_err());
        assert!(parse_request("FROB").is_err());
        assert!(parse_request("GEN 1 10 1,a,3").is_err());
    }

    #[test]
    fn token_format_roundtrip() {
        let toks = vec![1usize, 22, 333];
        assert_eq!(parse_tokens(&format_tokens(&toks)).unwrap(), toks);
    }
}
