//! Async event-loop front end: thousands of connections on a few threads.
//!
//! The thread-per-connection front end in [`super::tcp`] burns one OS
//! thread (and its stack) per client; at a few hundred idle sessions that
//! is the dominant cost of the server. This module multiplexes instead: a
//! small fixed pool of **loop threads**, each owning a level-triggered
//! [`Poller`] (raw `epoll` on Linux, `kqueue` on the BSDs — no external
//! crates, same std-only spirit as `exec/`) and a private set of
//! nonblocking [`Connection`]s.
//!
//! Topology and data flow:
//!
//! ```text
//!             accept            round-robin handoff
//!   listener ───────▶ loop 0 ──────────────────────▶ loop 1..N-1
//!                        │                               │
//!        read/frame/parse│            Work channel       │
//!                        └──────────────┬────────────────┘
//!                                       ▼
//!                                  batcher thread
//!                                       │ Respond::Sink(conn, serial)
//!                                       ▼
//!                        completions channel + Waker per loop
//! ```
//!
//! * **Loop 0** owns the nonblocking listener and accepts in a loop until
//!   `WouldBlock`, handing each stream to a loop thread round-robin over a
//!   channel followed by a [`Waker`] kick (a nonblocking socketpair write;
//!   the loop registers the read side with its own poller, so a wake is
//!   just one more readiness event).
//! * **Reads** append to the per-connection buffer and split complete
//!   lines incrementally — partial lines stay buffered, pipelined batches
//!   dispatch together. Each parsed request reserves an in-order reply
//!   slot ([`Connection::push_waiting`]) and goes to the batcher with
//!   `Respond::Sink { conn, serial }`; parse errors answer synchronously
//!   without a batcher round trip.
//! * **Completions** come back on the loop's mpsc channel (the
//!   [`ReplySink`] impl sends then wakes); the loop fills the reply slot,
//!   flushes as far as the socket allows, and toggles write interest only
//!   while unflushed bytes remain.
//! * **Backpressure** is layered: a connection with `MAX_PIPELINE`
//!   requests in flight stops being read (the client's TCP window fills),
//!   and the batcher itself sheds `GEN` work with `ERR BUSY` once its
//!   pending queue hits `queue_depth`.
//! * **Shutdown** ([`EventLoopServer::shutdown`]) flips a flag, wakes every
//!   loop, and joins the threads; dropping the loops closes their pollers
//!   and connections.

pub mod conn;
pub mod poller;

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::metrics::Counters;

use super::batcher::{Reply, ReplySink, Request, Respond, Work};
use super::faults::FaultPlan;
use super::health::HealthMonitor;
use super::protocol::{format_reply, parse_request, WireRequest};
use conn::Connection;
use poller::{PollEvent, Poller, WakeReader, Waker};

/// Poller token for the loop's wake pipe.
const WAKE: u64 = u64::MAX;
/// Poller token for the listener (loop 0 only).
const LISTEN: u64 = u64::MAX - 1;

#[derive(Clone, Debug, Default)]
pub struct EventLoopConfig {
    /// Number of loop threads; 0 = auto (2 when the machine has ≥2 cores).
    /// The loops only shuffle bytes and parse lines — decode compute lives
    /// on the batcher's exec pool — so a small number is plenty.
    pub loops: usize,
    /// Close a connection whose write buffer has been stuck non-empty this
    /// long (a slow-loris reader would otherwise pin its replies — and the
    /// memory behind them — forever). `None` disables the sweep.
    pub write_stall: Option<Duration>,
    /// Shared server counters; the loops bump `write_stall_closes` here.
    pub counters: Option<Arc<Counters>>,
    /// Injected fault plan (testing only; `None` in production).
    pub faults: Option<Arc<FaultPlan>>,
    /// Shared health monitor: `HEALTH` lines are answered loop-side,
    /// never via the work channel, so a wedged batcher cannot wedge the
    /// probe that reports it. `None` answers `ERR INTERNAL`.
    pub health: Option<Arc<HealthMonitor>>,
}

impl EventLoopConfig {
    fn resolved_loops(&self) -> usize {
        if self.loops > 0 {
            return self.loops;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(2)
    }
}

/// Handle to a running event-loop server.
pub struct EventLoopServer {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    wakers: Vec<Waker>,
    handles: Vec<JoinHandle<()>>,
}

impl EventLoopServer {
    /// Ask every loop to exit and join the threads. In-flight batcher work
    /// completes into closed channels harmlessly; open connections drop.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w.wake();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }

    /// Block until the loops exit (i.e. until some other handle on the
    /// shutdown flag flips it). Used by the CLI to serve forever.
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Reply sink handed to the batcher: enqueue the completion on the owning
/// loop's channel, then kick its waker so the loop notices immediately.
struct EventSink {
    tx: Sender<(u64, u64, Reply)>,
    waker: Waker,
}

impl ReplySink for EventSink {
    fn complete(&self, conn: u64, serial: u64, reply: Reply) {
        if self.tx.send((conn, serial, reply)).is_ok() {
            self.waker.wake();
        }
    }
}

/// Bind `addr` and spawn the loop threads. Returns once the listener is
/// bound; the returned handle exposes the resolved address (for `:0`
/// binds) and owns shutdown/join.
pub fn serve(addr: &str, work: Sender<Work>, config: EventLoopConfig) -> Result<EventLoopServer> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let addr = listener.local_addr().context("local_addr")?;
    let nloops = config.resolved_loops();
    let shutdown = Arc::new(AtomicBool::new(false));

    // Build every loop's plumbing up front so loop 0 can hold all the
    // handoff endpoints, and so poller/waker setup errors surface here
    // instead of inside a detached thread.
    let mut parts = Vec::with_capacity(nloops);
    let mut peers: Vec<(Sender<TcpStream>, Waker)> = Vec::with_capacity(nloops);
    for _ in 0..nloops {
        let poller = Poller::new().context("create poller")?;
        let (waker, wake_rx) = poller::waker().context("create waker")?;
        poller.register(wake_rx.fd(), WAKE, true, false).context("register waker")?;
        let (inc_tx, inc_rx) = channel::<TcpStream>();
        let (comp_tx, comp_rx) = channel::<(u64, u64, Reply)>();
        peers.push((inc_tx, waker.clone()));
        parts.push((poller, waker, wake_rx, inc_rx, comp_tx, comp_rx));
    }
    // The listener object itself moves into loop 0 below — register its fd
    // and hand over the same object, never a dup: kqueue drops a
    // registration when the registered fd number closes, so a
    // register-original/move-clone split would go deaf on the BSDs.
    poller_register_listener(&parts[0].0, &listener)?;
    let mut listener = Some(listener);

    let mut handles = Vec::with_capacity(nloops);
    let wakers: Vec<Waker> = peers.iter().map(|(_, w)| w.clone()).collect();
    for (id, (poller, waker, wake_rx, inc_rx, comp_tx, comp_rx)) in parts.into_iter().enumerate() {
        let ctx = LoopCtx {
            poller,
            wake_rx,
            incoming: inc_rx,
            completions: comp_rx,
            sink: Arc::new(EventSink { tx: comp_tx, waker }),
            work: work.clone(),
            shutdown: shutdown.clone(),
            listener: if id == 0 { listener.take() } else { None },
            peers: if id == 0 { peers.clone() } else { Vec::new() },
            write_stall: config.write_stall,
            counters: config.counters.clone(),
            faults: config.faults.clone(),
            health: config.health.clone(),
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("amq-loop-{id}"))
                .spawn(move || run_loop(id, ctx))
                .context("spawn loop thread")?,
        );
    }
    Ok(EventLoopServer { addr, shutdown, wakers, handles })
}

fn poller_register_listener(poller: &Poller, listener: &TcpListener) -> Result<()> {
    use std::os::fd::AsRawFd;
    poller.register(listener.as_raw_fd(), LISTEN, true, false).context("register listener")
}

/// Everything one loop thread owns.
struct LoopCtx {
    poller: Poller,
    wake_rx: WakeReader,
    /// Streams handed off by the acceptor (loop 0 round-robins here).
    incoming: Receiver<TcpStream>,
    /// Batcher completions routed back to this loop's connections.
    completions: Receiver<(u64, u64, Reply)>,
    sink: Arc<EventSink>,
    work: Sender<Work>,
    shutdown: Arc<AtomicBool>,
    /// Loop 0 only: the shared listener.
    listener: Option<TcpListener>,
    /// Loop 0 only: handoff endpoint + waker for every loop (self included).
    peers: Vec<(Sender<TcpStream>, Waker)>,
    /// Close connections whose write buffer has been stuck this long.
    write_stall: Option<Duration>,
    counters: Option<Arc<Counters>>,
    faults: Option<Arc<FaultPlan>>,
    health: Option<Arc<HealthMonitor>>,
}

fn run_loop(id: usize, mut ctx: LoopCtx) {
    let sink: Arc<dyn ReplySink> = ctx.sink.clone();
    let mut conns: HashMap<u64, Connection> = HashMap::new();
    let mut events: Vec<PollEvent> = Vec::new();
    let mut lines: Vec<String> = Vec::new();
    let mut next_token: u64 = 0;
    let mut rr: usize = id; // stagger so multi-listener setups interleave
    // With a stall bound the wait must tick even when no fd is ready, so a
    // clogged connection gets noticed; a quarter of the bound keeps the
    // close within ~25% of the configured deadline without busy-spinning.
    let poll_timeout = ctx
        .write_stall
        .map(|d| (d / 4).clamp(Duration::from_millis(10), Duration::from_millis(250)));

    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            return;
        }
        events.clear();
        if ctx.poller.wait(&mut events, poll_timeout).is_err() {
            return;
        }
        if ctx.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Drain (not index) so the arms may mutably borrow the rest of the
        // loop state; the buffer's allocation is kept for the next pass.
        for ev in events.drain(..) {
            match ev.token {
                WAKE => ctx.wake_rx.drain(),
                LISTEN => accept_all(&ctx, &mut conns, &mut next_token, &mut rr),
                token => {
                    if let Some(conn) = conns.get_mut(&token) {
                        if ev.readable {
                            let framing = conn.read_lines(&mut lines);
                            // Dispatch whatever parsed before any framing
                            // error — `lines` is shared across connections,
                            // so leaving them here would replay them on the
                            // next peer's read.
                            for line in lines.drain(..) {
                                dispatch_line(
                                    conn,
                                    token,
                                    &line,
                                    &ctx.work,
                                    &sink,
                                    ctx.health.as_deref(),
                                );
                            }
                            if let Err(e) = framing {
                                // Framing abuse (oversized line, non-UTF-8)
                                // or a dead socket: answer after the
                                // already-parsed pipelined replies, then
                                // treat the peer as closed — `finalize`
                                // reaps the connection once all replies
                                // flush (or the flush itself fails).
                                conn.push_ready(format!("ERR {e}"));
                                conn.eof = true;
                            }
                        }
                        // Writable readiness needs no explicit branch: the
                        // shared `finalize` below always attempts a flush.
                    }
                    finalize(&ctx.poller, &mut conns, token);
                }
            }
        }
        // Wake-driven queues, drained every pass (try_recv is cheap).
        while let Ok((token, serial, reply)) = ctx.completions.try_recv() {
            if let Some(conn) = conns.get_mut(&token) {
                conn.complete(serial, format_reply(&reply));
            }
            finalize(&ctx.poller, &mut conns, token);
        }
        while let Ok(stream) = ctx.incoming.try_recv() {
            register_conn(&ctx.poller, &ctx.faults, &mut conns, &mut next_token, stream);
        }
        // Write-stall sweep: a peer that stops reading while replies are
        // queued holds buffer memory and (for GEN) a just-finished slot's
        // reply hostage. Past the bound the connection is closed outright.
        if let Some(bound) = ctx.write_stall {
            let now = Instant::now();
            let stalled: Vec<u64> = conns
                .iter()
                .filter(|(_, c)| c.stalled_for(now).is_some_and(|d| d >= bound))
                .map(|(&t, _)| t)
                .collect();
            for token in stalled {
                if let Some(c) = &ctx.counters {
                    Counters::inc(&c.write_stall_closes, 1);
                }
                close(&ctx.poller, &mut conns, token);
            }
        }
    }
}

/// Accept until `WouldBlock`, spreading connections round-robin across the
/// loops. Level-triggered: anything left unaccepted re-fires next wait.
fn accept_all(
    ctx: &LoopCtx,
    conns: &mut HashMap<u64, Connection>,
    next_token: &mut u64,
    rr: &mut usize,
) {
    let Some(listener) = &ctx.listener else { return };
    let nloops = ctx.peers.len().max(1);
    loop {
        // Injected accept failure: behaves like a transient ECONNABORTED —
        // bail out of this pass and let level-triggering retry. Clients see
        // a delayed accept, never a refused connection.
        if ctx.faults.as_ref().is_some_and(|f| f.on_accept()) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let target = *rr % nloops;
                *rr = rr.wrapping_add(1);
                if target == 0 {
                    register_conn(&ctx.poller, &ctx.faults, conns, next_token, stream);
                } else {
                    let (tx, waker) = &ctx.peers[target];
                    if tx.send(stream).is_ok() {
                        waker.wake();
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            // Transient accept errors (ECONNABORTED, EMFILE): drop this
            // attempt; level-triggering retries on the next readiness.
            Err(_) => break,
        }
    }
}

fn register_conn(
    poller: &Poller,
    faults: &Option<Arc<FaultPlan>>,
    conns: &mut HashMap<u64, Connection>,
    next_token: &mut u64,
    stream: TcpStream,
) {
    let Ok(mut conn) = Connection::new(stream) else { return };
    conn.set_faults(faults.clone());
    let token = *next_token;
    *next_token += 1;
    if poller.register(conn.fd(), token, true, false).is_ok() {
        conns.insert(token, conn);
    }
}

/// Parse one request line and route it: malformed lines answer in place,
/// valid ones reserve an in-order reply slot and go to the batcher.
/// `HEALTH` answers loop-side from the shared monitor — it must respond
/// even when the batcher thread is wedged.
fn dispatch_line(
    conn: &mut Connection,
    token: u64,
    line: &str,
    work: &Sender<Work>,
    sink: &Arc<dyn ReplySink>,
    health: Option<&HealthMonitor>,
) {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(e) => {
            conn.push_ready(format!("ERR {e}"));
            return;
        }
    };
    if matches!(req, WireRequest::Health) {
        conn.push_ready(match health {
            Some(h) => format!("OK HEALTH {}", h.wire_line()),
            None => "ERR INTERNAL no health monitor wired to this front end".to_string(),
        });
        return;
    }
    let serial = conn.push_waiting();
    let respond = Respond::Sink { sink: sink.clone(), conn: token, serial };
    let w = match req {
        WireRequest::Generate { session, max_new, prime, model } => Work::Gen(Request {
            session,
            max_new,
            prime,
            model,
            respond,
            enqueued: Instant::now(),
        }),
        WireRequest::Score { tokens, model } => Work::Score { tokens, model, respond },
        WireRequest::End { session, model } => Work::End { session, model, respond },
        WireRequest::Stats { text } => Work::Stats { text, respond },
        WireRequest::Reload { model } => Work::Reload { model, respond },
        WireRequest::Drain => Work::Drain { respond },
        WireRequest::Health => unreachable!("HEALTH short-circuits above"),
    };
    if work.send(w).is_err() {
        conn.complete(serial, "ERR server shutting down".to_string());
    }
}

/// Flush what the socket will take, sync poller interest with what the
/// connection now wants, and reap it when finished or broken.
fn finalize(poller: &Poller, conns: &mut HashMap<u64, Connection>, token: u64) {
    let mut dead = false;
    if let Some(conn) = conns.get_mut(&token) {
        if conn.flush().is_err() || conn.finished() {
            dead = true;
        } else {
            let want = (conn.wants_read(), conn.wants_write());
            if want != conn.interest {
                if poller.modify(conn.fd(), token, want.0, want.1).is_ok() {
                    conn.interest = want;
                } else {
                    dead = true;
                }
            }
        }
    } else {
        return; // completion for an already-closed connection
    }
    if dead {
        close(poller, conns, token);
    }
}

fn close(poller: &Poller, conns: &mut HashMap<u64, Connection>, token: u64) {
    if let Some(conn) = conns.remove(&token) {
        let _ = poller.deregister(conn.fd());
        // `conn` drops here, closing the socket after deregistration.
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    /// A reply sink standing in for the batcher: echoes the work back so
    /// the loop plumbing can be tested without a model.
    fn echo_batcher(rx: Receiver<Work>) {
        while let Ok(w) = rx.recv() {
            match w {
                Work::Gen(req) => {
                    let mut toks = req.prime.clone();
                    toks.truncate(req.max_new);
                    req.respond.send(Reply::Gen(crate::server::batcher::Response {
                        tokens: toks,
                        queue_us: 0.0,
                        compute_us: 0.0,
                    }));
                }
                Work::Score { tokens, respond, .. } => {
                    respond.send(Reply::Score(tokens.len() as f64))
                }
                Work::End { session, respond, .. } => respond.send(Reply::End(session % 2 == 0)),
                Work::Stats { text, respond } => {
                    respond.send(Reply::Stats(if text { "text".into() } else { "{}".into() }))
                }
                Work::Reload { model, respond } => respond.send(Reply::Reloaded(model)),
                Work::Drain { respond } => {
                    respond.send(Reply::Drained { sessions: 0, path: "/dev/null".into() })
                }
                Work::Shutdown => break,
            }
        }
    }

    fn start_echo(loops: usize) -> (EventLoopServer, Sender<Work>, std::thread::JoinHandle<()>) {
        let (tx, rx) = channel();
        let bat = std::thread::spawn(move || echo_batcher(rx));
        let srv =
            serve("127.0.0.1:0", tx.clone(), EventLoopConfig { loops, ..Default::default() })
                .unwrap();
        (srv, tx, bat)
    }

    #[test]
    fn echo_roundtrip_and_pipelining() {
        let (srv, tx, bat) = start_echo(2);
        let mut cli = TcpStream::connect(srv.addr).unwrap();
        // One write carrying three pipelined requests plus a parse error.
        cli.write_all(b"GEN 1 2 7,8,9\nFROB\nSCORE 1,2,3\nSTATS\n").unwrap();
        let mut r = BufReader::new(cli.try_clone().unwrap());
        let mut line = String::new();
        let mut next = |r: &mut BufReader<TcpStream>, line: &mut String| {
            line.clear();
            r.read_line(line).unwrap();
            line.trim_end().to_string()
        };
        assert_eq!(next(&mut r, &mut line), "OK GEN 7,8");
        assert!(next(&mut r, &mut line).starts_with("ERR "), "parse error answers in order");
        assert_eq!(next(&mut r, &mut line), "OK SCORE 3.0000");
        assert_eq!(next(&mut r, &mut line), "OK STATS {}");
        drop(r);
        srv.shutdown();
        tx.send(Work::Shutdown).unwrap();
        bat.join().unwrap();
    }

    #[test]
    fn partial_writes_frame_correctly() {
        let (srv, tx, bat) = start_echo(1);
        let mut cli = TcpStream::connect(srv.addr).unwrap();
        cli.set_nodelay(true).unwrap();
        // Dribble one request across many writes, splitting mid-token.
        for chunk in ["GE", "N 5 3", " 10,2", "0,30,40", "\nEND 4\n"] {
            cli.write_all(chunk.as_bytes()).unwrap();
            cli.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let mut r = BufReader::new(cli);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK GEN 10,20,30");
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK END");
        drop(r);
        srv.shutdown();
        tx.send(Work::Shutdown).unwrap();
        bat.join().unwrap();
    }

    #[test]
    fn many_concurrent_connections_round_robin() {
        let (srv, tx, bat) = start_echo(2);
        let addr = srv.addr;
        let clients: Vec<_> = (0..32)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut cli = TcpStream::connect(addr).unwrap();
                    cli.write_all(format!("SCORE {}\n", vec!["1"; i + 2].join(",")).as_bytes())
                        .unwrap();
                    let mut r = BufReader::new(cli);
                    let mut line = String::new();
                    r.read_line(&mut line).unwrap();
                    assert_eq!(line.trim_end(), format!("OK SCORE {}.0000", i + 2));
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        srv.shutdown();
        tx.send(Work::Shutdown).unwrap();
        bat.join().unwrap();
    }

    #[test]
    fn shutdown_joins_cleanly_with_open_connection() {
        let (srv, tx, bat) = start_echo(2);
        let _idle = TcpStream::connect(srv.addr).unwrap();
        srv.shutdown(); // must not hang on the idle connection
        tx.send(Work::Shutdown).unwrap();
        bat.join().unwrap();
    }
}
