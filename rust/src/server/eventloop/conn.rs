//! Per-connection state for the event loop: nonblocking socket, incremental
//! line framing on the read side, a write buffer with partial-write resume,
//! and an **in-order pending-reply queue** so pipelined requests answer in
//! request order even though the batcher completes them asynchronously (a
//! quick `STATS` never overtakes the `GEN` sent before it).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::fd::{AsRawFd, RawFd};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::server::faults::{FaultPlan, WriteFault};
use crate::server::protocol::split_lines;
pub use crate::server::protocol::MAX_LINE;

/// Pipelined requests in flight per connection before the loop stops
/// reading from it (per-connection backpressure: the client's TCP window
/// fills instead of the server's memory).
pub const MAX_PIPELINE: usize = 128;

/// One slot in the in-order reply queue.
enum Pending {
    /// Reply text ready to flush (synchronous errors, completed work).
    Ready(String),
    /// Waiting for the batcher to complete serial number `n`.
    Waiting(u64),
}

/// A multiplexed client connection.
pub struct Connection {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    pending: VecDeque<Pending>,
    next_serial: u64,
    /// Peer closed its write side; finish in-flight work, flush, then close.
    pub eof: bool,
    /// Interest currently registered with the poller (readable, writable).
    pub interest: (bool, bool),
    /// Injected fault plan (testing only; `None` in production).
    faults: Option<Arc<FaultPlan>>,
    /// An injected `clog_write` fault made this socket permanently
    /// unwritable — every flush "would block" until the stall bound closes
    /// the connection.
    clogged: bool,
    /// When the write buffer first failed to drain fully (cleared the
    /// moment it empties). Feeds the event loop's `--write-stall-ms` sweep.
    stalled_since: Option<Instant>,
}

impl Connection {
    pub fn new(stream: TcpStream) -> io::Result<Self> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true)?;
        Ok(Connection {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            next_serial: 0,
            eof: false,
            interest: (true, false),
            faults: None,
            clogged: false,
            stalled_since: None,
        })
    }

    /// Attach an injected fault plan (read/write faults fire on this
    /// connection's socket operations).
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    pub fn fd(&self) -> RawFd {
        self.stream.as_raw_fd()
    }

    /// Drain the socket into the read buffer and extract complete lines.
    /// Returns `Err` when the connection is unusable (reset, non-UTF-8, or
    /// oversized line); EOF sets `self.eof` instead so queued replies still
    /// flush. Lines parsed before the error stay in `lines` — the caller
    /// serves them, then flushes the diagnostic and closes.
    ///
    /// Framing guard: complete lines are split off after **every** chunk,
    /// so `rbuf` only ever holds one partial line, and that tail is bounded
    /// by [`MAX_LINE`]. (Bounding the raw buffer instead, as this used to,
    /// disarms the guard whenever any earlier pipelined line left a newline
    /// in the buffer — an attacker could prefix `STATS\n` and stream
    /// unbounded junk.)
    pub fn read_lines(&mut self, lines: &mut Vec<String>) -> io::Result<()> {
        let mut chunk = [0u8; 4096];
        loop {
            // Injected short read: shrink the destination to one byte so the
            // kernel must deliver the stream in fragments (exercises the
            // incremental line framing exactly like a trickling client).
            let want = match &self.faults {
                Some(f) if f.on_conn_read() => 1,
                _ => chunk.len(),
            };
            match self.stream.read(&mut chunk[..want]) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    split_lines(&mut self.rbuf, lines)?;
                    if self.rbuf.len() > MAX_LINE {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "request line exceeds MAX_LINE",
                        ));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        split_lines(&mut self.rbuf, lines)
    }

    /// Queue a reply that is already known (parse errors, shutdown notices).
    pub fn push_ready(&mut self, text: String) {
        self.pending.push_back(Pending::Ready(text));
    }

    /// Reserve the next in-order reply slot for asynchronous work; returns
    /// the serial number the completion must quote.
    pub fn push_waiting(&mut self) -> u64 {
        let serial = self.next_serial;
        self.next_serial += 1;
        self.pending.push_back(Pending::Waiting(serial));
        serial
    }

    /// Fill a waiting slot with its completed reply. Unknown serials (slot
    /// dropped) are ignored.
    pub fn complete(&mut self, serial: u64, text: String) {
        for slot in self.pending.iter_mut() {
            if matches!(slot, Pending::Waiting(s) if *s == serial) {
                *slot = Pending::Ready(text);
                return;
            }
        }
    }

    /// Number of requests still in the reply queue (backpressure signal).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Move head-of-line `Ready` replies into the write buffer and push
    /// bytes to the socket. Returns `Err` when the peer is gone.
    pub fn flush(&mut self) -> io::Result<()> {
        while let Some(Pending::Ready(_)) = self.pending.front() {
            let Some(Pending::Ready(text)) = self.pending.pop_front() else { unreachable!() };
            self.wbuf.extend_from_slice(text.as_bytes());
            self.wbuf.push(b'\n');
        }
        while self.wpos < self.wbuf.len() {
            if self.clogged {
                break; // injected permanent WouldBlock: bytes never leave
            }
            let mut end = self.wbuf.len();
            match self.faults.as_ref().map_or(WriteFault::None, |f| f.on_conn_write()) {
                WriteFault::None => {}
                WriteFault::Short => end = self.wpos + 1,
                WriteFault::Error => return Err(io::ErrorKind::BrokenPipe.into()),
                WriteFault::Clog => {
                    self.clogged = true;
                    continue;
                }
            }
            match self.stream.write(&self.wbuf[self.wpos..end]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        // Track how long queued bytes have been stuck: set the stall mark on
        // the first flush that leaves the buffer non-empty, clear it the
        // moment the buffer drains.
        if self.wants_write() {
            if self.stalled_since.is_none() {
                self.stalled_since = Some(Instant::now());
            }
        } else {
            self.stalled_since = None;
        }
        Ok(())
    }

    /// How long the write buffer has been stuck non-empty, or `None` when
    /// everything flushed. The event loop closes connections stalled past
    /// `--write-stall-ms` (slow-loris readers holding batcher slots).
    pub fn stalled_for(&self, now: Instant) -> Option<Duration> {
        self.stalled_since.map(|since| now.saturating_duration_since(since))
    }

    /// Unflushed bytes remain (the loop should register write interest).
    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Reading more is useful: the peer is alive and the pipeline has room.
    pub fn wants_read(&self) -> bool {
        !self.eof && self.pending.len() < MAX_PIPELINE
    }

    /// Everything done: peer closed, no replies owed, buffer drained.
    pub fn finished(&self) -> bool {
        self.eof && self.pending.is_empty() && !self.wants_write()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn pending_queue_answers_in_request_order() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Connection::new(server).unwrap();

        // Three pipelined requests: slow async, sync error, fast async.
        let s0 = conn.push_waiting();
        conn.push_ready("ERR bogus".into());
        let s2 = conn.push_waiting();
        assert_eq!(conn.in_flight(), 3);

        // The fast request completes FIRST — nothing may flush yet because
        // the head of line is still waiting.
        conn.complete(s2, "OK STATS {}".into());
        conn.flush().unwrap();
        assert_eq!(conn.in_flight(), 3, "head-of-line reply must gate the queue");

        // Head completes: all three flush, in request order.
        conn.complete(s0, "OK GEN 1,2".into());
        conn.flush().unwrap();
        assert_eq!(conn.in_flight(), 0);
        drop(conn);

        let mut got = String::new();
        let mut r = std::io::BufReader::new(client);
        std::io::BufRead::read_line(&mut r, &mut got).unwrap();
        assert_eq!(got, "OK GEN 1,2\n");
        got.clear();
        std::io::BufRead::read_line(&mut r, &mut got).unwrap();
        assert_eq!(got, "ERR bogus\n");
        got.clear();
        std::io::BufRead::read_line(&mut r, &mut got).unwrap();
        assert_eq!(got, "OK STATS {}\n");
    }

    #[test]
    fn oversized_line_is_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Connection::new(server).unwrap();

        // Write from a helper thread: a blocking 68 KB write may not fit
        // the socket buffers until the server side starts draining.
        let writer = std::thread::spawn(move || {
            let junk = vec![b'x'; MAX_LINE + 4096];
            let _ = client.write_all(&junk);
            client
        });
        // Nonblocking read may need a few passes for all bytes to land.
        let mut lines = Vec::new();
        let mut rejected = false;
        for _ in 0..200 {
            match conn.read_lines(&mut lines) {
                Err(e) => {
                    assert_eq!(e.kind(), io::ErrorKind::InvalidData);
                    rejected = true;
                    break;
                }
                Ok(()) if conn.eof => break,
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(2)),
            }
        }
        assert!(rejected, "oversized request line must be rejected");
        assert!(lines.is_empty());
        drop(conn); // unblocks the writer if it was waiting on buffer space
        let _ = writer.join().unwrap();
    }

    #[test]
    fn oversized_line_behind_valid_pipelined_line_is_rejected() {
        // Regression: the guard used to check the raw buffer for *any*
        // newline, so a well-formed pipelined line in front disarmed it
        // and junk streamed in unbounded. The valid line must still parse;
        // the newline-free flood behind it must still reject.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Connection::new(server).unwrap();

        let writer = std::thread::spawn(move || {
            let mut payload = b"STATS\n".to_vec();
            payload.extend_from_slice(&vec![b'x'; MAX_LINE + 4096]);
            let _ = client.write_all(&payload);
            client
        });
        let mut lines = Vec::new();
        let mut rejected = false;
        for _ in 0..200 {
            match conn.read_lines(&mut lines) {
                Err(e) => {
                    assert_eq!(e.kind(), io::ErrorKind::InvalidData);
                    rejected = true;
                    break;
                }
                Ok(()) if conn.eof => break,
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(2)),
            }
        }
        assert!(rejected, "pipelined junk must not disarm the framing guard");
        assert_eq!(lines, vec!["STATS".to_string()], "the valid line still parses");
        drop(conn);
        let _ = writer.join().unwrap();
    }

    #[test]
    fn clogged_write_marks_the_connection_stalled() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Connection::new(server).unwrap();
        conn.set_faults(Some(Arc::new(FaultPlan::parse("clog_write=1").unwrap())));

        conn.push_ready("OK GEN 1,2".into());
        conn.flush().unwrap();
        assert!(conn.wants_write(), "clogged socket must keep its bytes queued");
        let first = conn.stalled_for(Instant::now()).expect("stall mark set");
        std::thread::sleep(Duration::from_millis(15));
        let later = conn.stalled_for(Instant::now()).unwrap();
        assert!(later > first, "stall age must grow while the buffer is stuck");
        // The mark survives repeated flush attempts (it dates the FIRST stall).
        conn.flush().unwrap();
        assert!(conn.stalled_for(Instant::now()).unwrap() >= later);
    }
}
