//! OS readiness polling behind one tiny interface — raw `epoll` (Linux) /
//! `kqueue` (macOS, BSDs) syscalls declared directly against libc, in the
//! same std-only spirit as `exec/`'s hand-rolled worker pool: no `mio`, no
//! `libc` crate, just the two dozen lines of FFI the server actually needs.
//!
//! Semantics are the least common denominator of the two backends:
//!
//! * **Level-triggered**: an fd with unread input (or writable space, when
//!   write interest is registered) reports ready on every [`Poller::wait`]
//!   until drained. The event loop never needs to read-until-`WouldBlock`
//!   for correctness — only for efficiency.
//! * One `u64` token per registration, echoed back in [`PollEvent`];
//!   errors/hangups surface as `readable` so the owner's `read()` observes
//!   the actual `io::Error` (or EOF) — there is no separate error path to
//!   keep correct.
//!
//! The [`Waker`] is a nonblocking `UnixStream` pair registered like any
//! other fd: any thread writes one byte to wake the loop. A full pipe means
//! a wake is already pending, so `WouldBlock` on the write side is success.

use std::io::{self, Read, Write};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::Arc;
use std::time::Duration;

/// One readiness event: the registered token plus what the fd is ready for.
#[derive(Clone, Copy, Debug)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Cross-thread wakeup for a [`Poller`] blocked in [`Poller::wait`]. Clone
/// freely; `wake` never blocks (a full buffer already guarantees a pending
/// wakeup).
#[derive(Clone)]
pub struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    pub fn wake(&self) {
        // WouldBlock ⇒ the buffer is full ⇒ the loop has an unread wake
        // byte already; any other error means the loop is gone. Both are
        // fine to ignore.
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// The read side the loop drains when the wake token fires.
pub struct WakeReader {
    rx: UnixStream,
}

impl WakeReader {
    /// Drain pending wake bytes (nonblocking; level-triggered re-fires if
    /// more arrive mid-drain).
    pub fn drain(&mut self) {
        let mut buf = [0u8; 64];
        while matches!(self.rx.read(&mut buf), Ok(n) if n > 0) {}
    }

    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }
}

/// Build a connected waker pair: write side for other threads, read side
/// for the loop to register with its poller.
pub fn waker() -> io::Result<(Waker, WakeReader)> {
    let (rx, tx) = UnixStream::pair()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((Waker { tx: Arc::new(tx) }, WakeReader { rx }))
}

pub use sys::Poller;

#[cfg(target_os = "linux")]
mod sys {
    use super::PollEvent;
    use std::ffi::c_int;
    use std::io;
    use std::os::fd::RawFd;
    use std::time::Duration;

    // Layout of `struct epoll_event`: packed on x86 only (the kernel ABI).
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CLOEXEC: c_int = 0x80000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
            -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn interest(readable: bool, writable: bool, token: u64) -> EpollEvent {
            let mut events = EPOLLRDHUP;
            if readable {
                events |= EPOLLIN;
            }
            if writable {
                events |= EPOLLOUT;
            }
            EpollEvent { events, data: token }
        }

        fn ctl(&self, op: c_int, fd: RawFd, mut ev: EpollEvent) -> io::Result<()> {
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, Self::interest(readable, writable, token))
        }

        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, Self::interest(readable, writable, token))
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // A dummy event for pre-2.6.9 kernels that reject NULL.
            self.ctl(EPOLL_CTL_DEL, fd, EpollEvent { events: 0, data: 0 })
        }

        /// Block for readiness (forever when `timeout` is `None`), append
        /// decoded events to `out`. EINTR retries internally.
        pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            let ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
            };
            loop {
                let n = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as c_int, ms) };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n as usize) {
                    // Copy out of the (possibly packed) struct first.
                    let events = ev.events;
                    let token = ev.data;
                    out.push(PollEvent {
                        token,
                        // Errors and hangups count as readable so the
                        // owner's read() surfaces them.
                        readable: events & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                        writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(any(
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
mod sys {
    use super::PollEvent;
    use std::ffi::{c_int, c_void};
    use std::io;
    use std::os::fd::RawFd;
    use std::ptr;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct KEvent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: isize,
        tv_nsec: isize,
    }

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x1;
    const EV_DELETE: u16 = 0x2;

    extern "C" {
        fn kqueue() -> c_int;
        fn kevent(
            kq: c_int,
            changelist: *const KEvent,
            nchanges: c_int,
            eventlist: *mut KEvent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// kqueue instance (level-triggered by default, like epoll without
    /// EPOLLET).
    pub struct Poller {
        kq: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { kq })
        }

        fn change(&self, fd: RawFd, token: u64, filter: i16, flags: u16) -> io::Result<()> {
            let kev = KEvent {
                ident: fd as usize,
                filter,
                flags,
                fflags: 0,
                data: 0,
                udata: token as *mut c_void,
            };
            let rc = unsafe { kevent(self.kq, &kev, 1, ptr::null_mut(), 0, ptr::null()) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        fn apply(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
            for (want, filter) in [(readable, EVFILT_READ), (writable, EVFILT_WRITE)] {
                if want {
                    self.change(fd, token, filter, EV_ADD)?;
                } else {
                    // Deleting an unregistered filter is a no-op here.
                    let _ = self.change(fd, token, filter, EV_DELETE);
                }
            }
            Ok(())
        }

        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.apply(fd, token, readable, writable)
        }

        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.apply(fd, token, readable, writable)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let _ = self.change(fd, 0, EVFILT_READ, EV_DELETE);
            let _ = self.change(fd, 0, EVFILT_WRITE, EV_DELETE);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [KEvent {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: ptr::null_mut(),
            }; 64];
            let ts;
            let ts_ptr = match timeout {
                None => ptr::null(),
                Some(d) => {
                    ts = Timespec {
                        tv_sec: d.as_secs().min(isize::MAX as u64) as isize,
                        tv_nsec: d.subsec_nanos() as isize,
                    };
                    &ts as *const Timespec
                }
            };
            loop {
                let n = unsafe {
                    kevent(self.kq, ptr::null(), 0, buf.as_mut_ptr(), buf.len() as c_int, ts_ptr)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n as usize) {
                    out.push(PollEvent {
                        token: ev.udata as u64,
                        readable: ev.filter == EVFILT_READ,
                        writable: ev.filter == EVFILT_WRITE,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.kq);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn poller_reports_readable_after_write() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 7, true, false).unwrap();

        // Nothing to read yet: a short wait returns no events for it.
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));

        client.write_all(b"ping\n").unwrap();
        client.flush().unwrap();
        let mut events = Vec::new();
        // Allow a couple of waits for delivery.
        for _ in 0..50 {
            poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
        }
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
        poller.deregister(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn waker_unblocks_wait() {
        let poller = Poller::new().unwrap();
        let (waker, mut wake_rx) = waker().unwrap();
        const WAKE: u64 = u64::MAX;
        poller.register(wake_rx.fd(), WAKE, true, false).unwrap();

        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
            waker.wake(); // double wake coalesces into ≥1 readable byte
        });
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == WAKE && e.readable), "{events:?}");
        wake_rx.drain();
        handle.join().unwrap();
    }

    #[test]
    fn write_interest_toggles() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        // Read-only interest: an idle writable socket must NOT report.
        poller.register(server.as_raw_fd(), 1, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| !(e.token == 1 && e.writable)), "{events:?}");
        // Add write interest: an empty socket buffer reports writable.
        poller.modify(server.as_raw_fd(), 1, true, true).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(200))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.writable), "{events:?}");
    }
}
