//! Watchdog health: liveness signals the serving loop publishes and the
//! front ends read **without going through the work channel** — a `HEALTH`
//! probe must answer even when the batcher thread is wedged, which is
//! exactly the situation it exists to report.
//!
//! The batcher beats [`HealthMonitor::beat_loop`] once per scheduling pass
//! and [`HealthMonitor::beat_lane`] once per lane timestep. The verdict is
//! load-aware: a silent loop with no occupied decode slots is just idle
//! (`ok`), the same silence with sessions mid-decode is `degraded` with
//! the stuck lane named. `DRAIN`/SIGTERM flips the monitor to `draining`,
//! which wins over everything else — probes and load balancers see the
//! instance leave rotation before admission actually stops.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Silence threshold on an occupied lane before `HEALTH` reports
/// `degraded`. Generously above any sane timestep (which is µs–ms scale).
pub const DEFAULT_STUCK: Duration = Duration::from_secs(2);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthStatus {
    Ok,
    Degraded,
    Draining,
}

impl fmt::Display for HealthStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            HealthStatus::Ok => "ok",
            HealthStatus::Degraded => "degraded",
            HealthStatus::Draining => "draining",
        })
    }
}

/// Last-seen progress of one model lane.
struct LaneBeat {
    name: String,
    /// `now_ms` at the lane's last completed timestep (0 = never stepped).
    last_ms: u64,
    steps: u64,
    occupied: usize,
}

/// Shared liveness state: one writer (the batcher thread), many readers
/// (front-end connections answering `HEALTH`, the monitor thread in
/// `main`). Atomics plus one short-critical-section mutex — reading a
/// verdict never blocks on decode work.
pub struct HealthMonitor {
    started: Instant,
    stuck_after_ms: u64,
    /// `now_ms + 1` at the loop's last pass (0 = never beat).
    loop_beat_ms: AtomicU64,
    draining: AtomicBool,
    lanes: Mutex<Vec<LaneBeat>>,
}

impl Default for HealthMonitor {
    fn default() -> Self {
        Self::new(DEFAULT_STUCK)
    }
}

impl HealthMonitor {
    pub fn new(stuck_after: Duration) -> Self {
        HealthMonitor {
            started: Instant::now(),
            stuck_after_ms: stuck_after.as_millis().max(1) as u64,
            loop_beat_ms: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            lanes: Mutex::new(Vec::new()),
        }
    }

    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// The batcher's scheduling pass heartbeat.
    pub fn beat_loop(&self) {
        self.loop_beat_ms.store(self.now_ms() + 1, Ordering::Relaxed);
    }

    /// One lane finished a timestep (or reported its idle occupancy).
    pub fn beat_lane(&self, name: &str, steps: u64, occupied: usize) {
        let now = self.now_ms();
        let mut lanes = self.lanes.lock().unwrap();
        match lanes.iter_mut().find(|l| l.name == name) {
            Some(l) => {
                l.last_ms = now;
                l.steps = steps;
                l.occupied = occupied;
            }
            None => lanes.push(LaneBeat { name: name.to_string(), last_ms: now, steps, occupied }),
        }
    }

    /// A lane was dropped (quarantine, eviction): forget its beat so a
    /// dead lane cannot keep the verdict degraded forever.
    pub fn lane_gone(&self, name: &str) {
        self.lanes.lock().unwrap().retain(|l| l.name != name);
    }

    /// Flip to draining: wins over every other verdict, never unflips.
    pub fn set_draining(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Current verdict plus a human detail string.
    pub fn status(&self) -> (HealthStatus, String) {
        if self.is_draining() {
            return (HealthStatus::Draining, String::new());
        }
        let now = self.now_ms();
        let lanes = self.lanes.lock().unwrap();
        // A lane with occupied slots must keep stepping; silence past the
        // threshold means the decode thread is wedged (or a step is
        // pathologically slow — equally worth paging about).
        let mut worst: Option<(&str, u64)> = None;
        for l in lanes.iter().filter(|l| l.occupied > 0) {
            let silent = now.saturating_sub(l.last_ms);
            if silent > self.stuck_after_ms {
                match worst {
                    Some((_, w)) if silent <= w => {}
                    _ => worst = Some((&l.name, silent)),
                }
            }
        }
        if let Some((name, silent)) = worst {
            return (HealthStatus::Degraded, format!("lane={name} stuck_ms={silent}"));
        }
        let occupied: usize = lanes.iter().map(|l| l.occupied).sum();
        let loop_beat = self.loop_beat_ms.load(Ordering::Relaxed);
        if occupied > 0 && loop_beat > 0 {
            let silent = now.saturating_sub(loop_beat - 1);
            if silent > self.stuck_after_ms {
                return (HealthStatus::Degraded, format!("loop stuck_ms={silent}"));
            }
        }
        (HealthStatus::Ok, String::new())
    }

    /// The `HEALTH` wire payload (after `OK HEALTH `).
    pub fn wire_line(&self) -> String {
        let (status, detail) = self.status();
        let uptime = self.started.elapsed().as_secs();
        if detail.is_empty() {
            format!("{status} uptime={uptime}s")
        } else {
            format!("{status} {detail} uptime={uptime}s")
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn idle_silence_is_ok_but_occupied_silence_degrades() {
        let m = HealthMonitor::new(Duration::from_millis(20));
        m.beat_loop();
        m.beat_lane("alpha", 1, 0);
        assert_eq!(m.status().0, HealthStatus::Ok, "no occupancy, silence is idle");

        m.beat_lane("alpha", 2, 3); // three slots mid-decode...
        std::thread::sleep(Duration::from_millis(40)); // ...then silence
        let (status, detail) = m.status();
        assert_eq!(status, HealthStatus::Degraded);
        assert!(detail.starts_with("lane=alpha stuck_ms="), "{detail}");
        assert!(m.wire_line().starts_with("degraded lane=alpha "), "{}", m.wire_line());

        // Progress resumes: verdict recovers without any reset call.
        m.beat_lane("alpha", 3, 3);
        m.beat_loop();
        assert_eq!(m.status().0, HealthStatus::Ok);

        // The lane drains to empty: silence is fine again.
        m.beat_lane("alpha", 4, 0);
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(m.status().0, HealthStatus::Ok);
    }

    #[test]
    fn removed_lanes_stop_counting_and_draining_wins() {
        let m = HealthMonitor::new(Duration::from_millis(10));
        m.beat_lane("beta", 5, 2);
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(m.status().0, HealthStatus::Degraded);
        m.lane_gone("beta");
        assert_eq!(m.status().0, HealthStatus::Ok, "quarantined lane must not page forever");

        m.beat_lane("beta", 6, 2);
        m.set_draining();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(m.status().0, HealthStatus::Draining, "draining wins over degraded");
        assert!(m.is_draining());
        assert!(m.wire_line().starts_with("draining"));
    }
}
