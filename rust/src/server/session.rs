//! Per-client session cache: the RNN analogue of a KV-cache manager.
//!
//! Each conversation keeps its recurrent state (`h`, `c`) server-side so a
//! follow-up request continues where the last one stopped. Bounded with LRU
//! eviction; evictions are surfaced in the metrics.
//!
//! Alongside the state, the store keeps a short **token history** per
//! session (the most recent [`HISTORY_CAP`] prime + generated tokens).
//! History lives in its own map so it survives the `take`/`put` cycle a
//! session goes through while occupying a decode slot; it dies with the
//! session (END, LRU eviction, TTL reaping). Drain-time snapshots persist
//! it next to the state so a restored server can show where each revived
//! session left off.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::model::lm::LmState;

/// Most recent tokens retained per session (prime + generated, oldest
/// dropped first). Bounds snapshot size without touching decode state.
pub const HISTORY_CAP: usize = 64;

/// One stored session: logical recency for LRU, wall-clock recency for
/// TTL reaping, and the recurrent state itself.
struct Entry {
    last_used: u64,
    touched: Instant,
    state: LmState,
}

/// LRU session store keyed by client-chosen session id.
pub struct SessionStore {
    max_sessions: usize,
    clock: u64,
    map: HashMap<u64, Entry>,
    /// Token history, kept out of `Entry` so it survives `take`.
    histories: HashMap<u64, Vec<usize>>,
    pub evictions: u64,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> Self {
        assert!(max_sessions >= 1);
        SessionStore {
            max_sessions,
            clock: 0,
            map: HashMap::new(),
            histories: HashMap::new(),
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch a session's state (bumps recency), or `None` for new sessions.
    /// The session's history stays behind — it is rejoined on `put`.
    pub fn take(&mut self, id: u64) -> Option<LmState> {
        self.clock += 1;
        self.map.remove(&id).map(|e| e.state)
    }

    /// Store a session's state, evicting the least-recently-used if full.
    pub fn put(&mut self, id: u64, state: LmState) {
        self.clock += 1;
        if !self.map.contains_key(&id) && self.map.len() >= self.max_sessions {
            if let Some((&lru, _)) = self.map.iter().min_by_key(|(_, e)| e.last_used) {
                self.map.remove(&lru);
                self.histories.remove(&lru);
                self.evictions += 1;
            }
        }
        self.map.insert(id, Entry { last_used: self.clock, touched: Instant::now(), state });
    }

    /// Append decoded tokens to a stored session's history, keeping the
    /// most recent [`HISTORY_CAP`]. Call after `put` — history for a
    /// session with no stored state would leak.
    pub fn append_history(&mut self, id: u64, tokens: &[usize]) {
        if !self.map.contains_key(&id) {
            return;
        }
        let h = self.histories.entry(id).or_default();
        h.extend_from_slice(tokens);
        if h.len() > HISTORY_CAP {
            let excess = h.len() - HISTORY_CAP;
            h.drain(..excess);
        }
    }

    pub fn remove(&mut self, id: u64) -> bool {
        self.histories.remove(&id);
        self.map.remove(&id).is_some()
    }

    /// Drop every session idle (wall clock) for at least `ttl`, exactly as
    /// if `END` had arrived for each. Returns how many were reaped.
    pub fn reap_idle(&mut self, ttl: Duration, now: Instant) -> usize {
        let before = self.map.len();
        let histories = &mut self.histories;
        self.map.retain(|id, e| {
            let keep = now.duration_since(e.touched) < ttl;
            if !keep {
                histories.remove(id);
            }
            keep
        });
        before - self.map.len()
    }

    /// Every stored session with its state and history, in unspecified
    /// order (drain snapshots sort by id for determinism).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &LmState, &[usize])> {
        self.map.iter().map(|(&id, e)| {
            (id, &e.state, self.histories.get(&id).map_or(&[][..], Vec::as_slice))
        })
    }

    /// Revive a snapshotted session: state + history in one call.
    pub fn restore(&mut self, id: u64, state: LmState, history: Vec<usize>) {
        self.put(id, state);
        if !history.is_empty() {
            self.histories.insert(id, history);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::lstm::LstmState;

    fn st(h: f32) -> LmState {
        LmState::Lstm(vec![LstmState { h: vec![h], c: vec![h] }])
    }

    #[test]
    fn take_put_roundtrip() {
        let mut s = SessionStore::new(4);
        assert!(s.take(1).is_none());
        s.put(1, st(0.5));
        let got = s.take(1).unwrap();
        assert_eq!(got, st(0.5));
        // take removes — second take misses.
        assert!(s.take(1).is_none());
    }

    #[test]
    fn lru_eviction_order() {
        let mut s = SessionStore::new(2);
        s.put(1, st(1.0));
        s.put(2, st(2.0));
        // Touch 1 so 2 becomes LRU.
        let one = s.take(1).unwrap();
        s.put(1, one);
        s.put(3, st(3.0));
        assert_eq!(s.evictions, 1);
        assert!(s.take(2).is_none(), "2 was LRU and must be evicted");
        assert!(s.take(1).is_some());
        assert!(s.take(3).is_some());
    }

    #[test]
    fn capacity_never_exceeded_property() {
        let mut s = SessionStore::new(8);
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..1000 {
            let id = rng.below(32) as u64;
            if rng.f32() < 0.5 {
                s.put(id, st(id as f32));
            } else {
                if let Some(state) = s.take(id) {
                    s.put(id, state);
                }
            }
            assert!(s.len() <= 8);
        }
    }

    #[test]
    fn remove_existing() {
        let mut s = SessionStore::new(2);
        s.put(7, st(1.0));
        assert!(s.remove(7));
        assert!(!s.remove(7));
    }

    #[test]
    fn reap_idle_drops_only_stale_sessions() {
        let mut s = SessionStore::new(8);
        s.put(1, st(1.0));
        s.put(2, st(2.0));
        let now = Instant::now();
        assert_eq!(s.reap_idle(Duration::from_secs(60), now), 0, "fresh sessions survive");
        // Re-touch 2 "later", then reap with a horizon that only 1 missed.
        std::thread::sleep(Duration::from_millis(30));
        let two = s.take(2).unwrap();
        s.put(2, two);
        let reaped = s.reap_idle(Duration::from_millis(20), Instant::now());
        assert_eq!(reaped, 1);
        assert!(s.take(1).is_none(), "1 was idle past the TTL");
        assert!(s.take(2).is_some(), "2 was touched recently");
    }

    #[test]
    fn history_survives_take_put_and_caps_at_the_limit() {
        let mut s = SessionStore::new(4);
        s.put(1, st(1.0));
        s.append_history(1, &[10, 11, 12]);
        // A decode cycle: the state leaves for a slot and comes back.
        let state = s.take(1).unwrap();
        s.put(1, state);
        s.append_history(1, &[13]);
        let got: Vec<(u64, Vec<usize>)> =
            s.iter().map(|(id, _, h)| (id, h.to_vec())).collect();
        assert_eq!(got, vec![(1, vec![10, 11, 12, 13])]);

        // Overflow keeps only the most recent HISTORY_CAP tokens.
        let many: Vec<usize> = (0..HISTORY_CAP + 9).collect();
        s.append_history(1, &many);
        let (_, _, h) = s.iter().next().unwrap();
        assert_eq!(h.len(), HISTORY_CAP);
        assert_eq!(h[h.len() - 1], HISTORY_CAP + 8, "newest token retained");

        // History dies with the session.
        s.remove(1);
        s.put(1, st(2.0));
        let (_, _, h) = s.iter().next().unwrap();
        assert!(h.is_empty(), "END must clear history");

        // Histories are never appended for unknown sessions.
        s.append_history(99, &[1]);
        assert!(s.iter().all(|(id, _, _)| id != 99));
    }

    #[test]
    fn restore_revives_state_and_history_together() {
        let mut s = SessionStore::new(4);
        s.restore(5, st(0.25), vec![7, 8]);
        let (_, _, h) = s.iter().next().unwrap();
        assert_eq!(h, &[7, 8]);
        assert_eq!(s.take(5).unwrap(), st(0.25));
    }
}
