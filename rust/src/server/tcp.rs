//! TCP front end: accept loop + thread-per-connection router that parses
//! the wire protocol and forwards work to the batcher thread.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Sender};
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Request, Work};
use super::protocol::{format_tokens, parse_request, WireRequest};

/// Bind and serve forever (spawns a thread per connection). Returns the
/// bound local address via the callback before blocking (tests bind ":0").
pub fn serve(addr: &str, work: Sender<Work>, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = work.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(s, tx);
                });
            }
            Err(_) => continue,
        }
    }
    Ok(())
}

/// Serve one connection: line in, line out.
pub fn handle_conn(stream: TcpStream, work: Sender<Work>) -> Result<()> {
    let peer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut writer = peer;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &work);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// Pure request→reply step (unit-testable without sockets).
pub fn handle_line(line: &str, work: &Sender<Work>) -> String {
    match parse_request(line) {
        Err(e) => format!("ERR {e}"),
        Ok(WireRequest::Generate { session, max_new, prime }) => {
            let (tx, rx) = mpsc::channel();
            let req = Request { session, max_new, prime, respond: tx, enqueued: Instant::now() };
            if work.send(Work::Gen(req)).is_err() {
                return "ERR server shutting down".into();
            }
            match rx.recv() {
                Ok(resp) => format!("OK GEN {}", format_tokens(&resp.tokens)),
                Err(_) => "ERR batcher dropped request".into(),
            }
        }
        Ok(WireRequest::Score { tokens }) => {
            let (tx, rx) = mpsc::channel();
            if work.send(Work::Score { tokens, respond: tx }).is_err() {
                return "ERR server shutting down".into();
            }
            match rx.recv() {
                Ok(ppw) => format!("OK SCORE {ppw:.4}"),
                Err(_) => "ERR batcher dropped request".into(),
            }
        }
        Ok(WireRequest::End { session }) => {
            let (tx, rx) = mpsc::channel();
            if work.send(Work::End { session, respond: tx }).is_err() {
                return "ERR server shutting down".into();
            }
            match rx.recv() {
                Ok(true) => "OK END".into(),
                Ok(false) => "OK END (no such session)".into(),
                Err(_) => "ERR batcher dropped request".into(),
            }
        }
        Ok(WireRequest::Stats) => {
            let (tx, rx) = mpsc::channel();
            if work.send(Work::Stats { respond: tx }).is_err() {
                return "ERR server shutting down".into();
            }
            match rx.recv() {
                Ok(s) => format!("OK STATS {s}"),
                Err(_) => "ERR batcher dropped request".into(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy, RnnKind};
    use crate::model::RnnLm;
    use crate::server::batcher::{BatcherConfig, InferenceServer};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::Arc;

    fn spawn_server() -> (Sender<Work>, std::thread::JoinHandle<()>) {
        let lm = RnnLm::random(
            LmConfig { kind: RnnKind::Gru, vocab: 30, hidden: 12, layers: 1 },
            11,
            PrecisionPolicy::quantized(2, 2),
        );
        let server = InferenceServer::new(Arc::new(lm), BatcherConfig::default());
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || server.run(rx));
        (tx, h)
    }

    #[test]
    fn handle_line_gen_and_score() {
        let (tx, h) = spawn_server();
        let r = handle_line("GEN 1 3 2,3", &tx);
        assert!(r.starts_with("OK GEN "), "{r}");
        let toks = r.trim_start_matches("OK GEN ").split(',').count();
        assert_eq!(toks, 3);
        let r = handle_line("SCORE 1,2,3,4,5", &tx);
        assert!(r.starts_with("OK SCORE "), "{r}");
        let r = handle_line("junk", &tx);
        assert!(r.starts_with("ERR "), "{r}");
        tx.send(Work::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn tcp_end_to_end() {
        let (tx, h) = spawn_server();
        let (addr_tx, addr_rx) = mpsc::channel();
        let tx2 = tx.clone();
        std::thread::spawn(move || {
            let _ = serve("127.0.0.1:0", tx2, move |a| {
                let _ = addr_tx.send(a);
            });
        });
        let addr = addr_rx.recv().unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"GEN 7 4 1,2\nSTATS\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK GEN "), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK STATS "), "{line}");
        tx.send(Work::Shutdown).unwrap();
        h.join().unwrap();
    }
}
