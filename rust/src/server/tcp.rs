//! Thread-per-connection TCP front end: simple, blocking, one OS thread
//! per client. Fine for a handful of sessions; the event-loop front end
//! (`super::eventloop`, `--event-loop`) scales to thousands. Both parse
//! the same wire protocol with the same framing ([`split_lines`]) and
//! forward to the same batcher over the `Work` channel.
//!
//! Shutdown is cooperative: the accept loop and every connection handler
//! poll the shared `shutdown` flag (accept is nonblocking, connection
//! reads carry a short timeout), and `serve` **joins every handler thread
//! before returning** — no leaked threads holding sockets past shutdown.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Request, Respond, Work};
use super::health::HealthMonitor;
use super::protocol::{format_reply, parse_request, split_lines, WireRequest, MAX_LINE};

/// Bind and serve until `shutdown` flips (spawns a thread per connection,
/// all joined before returning). Reports the bound local address via the
/// callback before entering the accept loop (tests bind ":0").
pub fn serve(
    addr: &str,
    work: Sender<Work>,
    shutdown: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_with_health(addr, work, shutdown, None, on_bound)
}

/// [`serve`] with a shared [`HealthMonitor`]: `HEALTH` lines are answered
/// directly by the connection handler — never via the work channel — so a
/// wedged batcher thread cannot wedge the probe that reports it.
pub fn serve_with_health(
    addr: &str,
    work: Sender<Work>,
    shutdown: Arc<AtomicBool>,
    health: Option<Arc<HealthMonitor>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = work.clone();
                let flag = shutdown.clone();
                let hm = health.clone();
                handlers.push(std::thread::spawn(move || {
                    let _ = handle_conn_with(stream, tx, flag, hm);
                }));
                // Reap finished handlers so the vec stays proportional to
                // *live* connections, not connections ever accepted.
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// Serve one connection: line in, line out, until EOF or shutdown.
///
/// Framing errors (a line past [`MAX_LINE`] without its newline, or bytes
/// that are not UTF-8) serve whatever pipelined lines already parsed, send
/// the `ERR` diagnostic, and close — same semantics as the event-loop
/// front end. The tail is bounded after every [`split_lines`], so one
/// valid pipelined line cannot disarm the oversize guard and a client
/// cannot grow the buffer without bound.
pub fn handle_conn(stream: TcpStream, work: Sender<Work>, shutdown: Arc<AtomicBool>) -> Result<()> {
    handle_conn_with(stream, work, shutdown, None)
}

/// [`handle_conn`] with the shared health monitor (see
/// [`serve_with_health`]).
pub fn handle_conn_with(
    stream: TcpStream,
    work: Sender<Work>,
    shutdown: Arc<AtomicBool>,
    health: Option<Arc<HealthMonitor>>,
) -> Result<()> {
    // A short read timeout keeps the handler responsive to shutdown while
    // the client is idle.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut lines: Vec<String> = Vec::new();
    let mut chunk = [0u8; 4096];
    while !shutdown.load(Ordering::SeqCst) {
        let mut framing: Option<String> = None;
        match reader.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                match split_lines(&mut buf, &mut lines) {
                    Err(e) => framing = Some(e.to_string()),
                    Ok(()) if buf.len() > MAX_LINE => {
                        framing = Some("request line exceeds MAX_LINE".to_string());
                    }
                    Ok(()) => {}
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
        for line in lines.drain(..) {
            let reply = handle_line_with(&line, &work, health.as_deref());
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
        }
        if let Some(msg) = framing {
            writer.write_all(format!("ERR {msg}\n").as_bytes())?;
            writer.flush()?;
            break;
        }
        writer.flush()?;
    }
    Ok(())
}

/// Pure request→reply step (unit-testable without sockets): parse, send to
/// the batcher with a rendezvous channel, block for the reply, format it.
pub fn handle_line(line: &str, work: &Sender<Work>) -> String {
    handle_line_with(line, work, None)
}

/// [`handle_line`] with the shared health monitor. `HEALTH` short-circuits
/// here — it must answer even when the batcher thread is wedged, so it
/// never enters the work channel.
pub fn handle_line_with(
    line: &str,
    work: &Sender<Work>,
    health: Option<&HealthMonitor>,
) -> String {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(e) => return format!("ERR {e}"),
    };
    if matches!(req, WireRequest::Health) {
        return match health {
            Some(h) => format!("OK HEALTH {}", h.wire_line()),
            None => "ERR INTERNAL no health monitor wired to this front end".into(),
        };
    }
    let (tx, rx) = mpsc::channel();
    let respond = Respond::Channel(tx);
    let w = match req {
        WireRequest::Generate { session, max_new, prime, model } => Work::Gen(Request {
            session,
            max_new,
            prime,
            model,
            respond,
            enqueued: Instant::now(),
        }),
        WireRequest::Score { tokens, model } => Work::Score { tokens, model, respond },
        WireRequest::End { session, model } => Work::End { session, model, respond },
        WireRequest::Stats { text } => Work::Stats { text, respond },
        WireRequest::Reload { model } => Work::Reload { model, respond },
        WireRequest::Drain => Work::Drain { respond },
        WireRequest::Health => unreachable!("HEALTH short-circuits above"),
    };
    if work.send(w).is_err() {
        return "ERR server shutting down".into();
    }
    match rx.recv() {
        Ok(reply) => format_reply(&reply),
        Err(_) => "ERR batcher dropped request".into(),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::lm::{LmConfig, PrecisionPolicy, RnnKind};
    use crate::model::RnnLm;
    use crate::server::batcher::{BatcherConfig, InferenceServer};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::Arc;

    fn spawn_server() -> (Sender<Work>, std::thread::JoinHandle<()>) {
        let lm = RnnLm::random(
            LmConfig { kind: RnnKind::Gru, vocab: 30, hidden: 12, layers: 1 },
            11,
            PrecisionPolicy::quantized(2, 2),
        );
        let server = InferenceServer::new(Arc::new(lm), BatcherConfig::default());
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || server.run(rx));
        (tx, h)
    }

    #[test]
    fn handle_line_gen_and_score() {
        let (tx, h) = spawn_server();
        let r = handle_line("GEN 1 3 2,3", &tx);
        assert!(r.starts_with("OK GEN "), "{r}");
        let toks = r.trim_start_matches("OK GEN ").split(',').count();
        assert_eq!(toks, 3);
        let r = handle_line("SCORE 1,2,3,4,5", &tx);
        assert!(r.starts_with("OK SCORE "), "{r}");
        let r = handle_line("junk", &tx);
        assert!(r.starts_with("ERR "), "{r}");
        tx.send(Work::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn tcp_end_to_end_with_clean_shutdown() {
        let (tx, h) = spawn_server();
        let (addr_tx, addr_rx) = mpsc::channel();
        let tx2 = tx.clone();
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let srv = std::thread::spawn(move || {
            serve("127.0.0.1:0", tx2, flag, move |a| {
                let _ = addr_tx.send(a);
            })
        });
        let addr = addr_rx.recv().unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"GEN 7 4 1,2\nSTATS\nSTATS TEXT\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK GEN "), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK STATS {"), "default STATS is JSON: {line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK STATS latency:"), "STATS TEXT is human form: {line}");
        assert!(line.contains("mode=grouped"), "{line}");
        // Cooperative shutdown must join the open connection's handler.
        shutdown.store(true, Ordering::SeqCst);
        srv.join().unwrap().unwrap();
        tx.send(Work::Shutdown).unwrap();
        h.join().unwrap();
    }
}
