//! Experiment harness: one entry point per table of the paper.
//!
//! Each `table*` function regenerates the corresponding table's rows on the
//! synthetic substrates (DESIGN.md §4) and prints them in the paper's
//! layout. Absolute values differ from the paper (different corpus/testbed);
//! the *shape* — method ordering, gaps, crossovers — is the reproduction
//! target and is what EXPERIMENTS.md records.

pub mod quant_tables;
pub mod image_tables;
pub mod kernel_tables;
pub mod lm_tables;

pub use image_tables::{table7, table8, table9};
pub use kernel_tables::{
    costmodel, fused_vs_pairwise_sweep, gemm_backend_sweep, gemm_batch_sweep, gemm_thread_sweep,
    render_backend_sweep, render_batch_sweep, render_fused_sweep, render_roof,
    render_scalar_floor, render_thread_sweep, render_tiled_sweep, scalar_fp_floor, stream_roof,
    table6, tiled_vs_untiled_sweep, BandwidthRoof, TiledSweepRow,
};
pub use lm_tables::{table3_4_5, train_tag};
pub use quant_tables::table1_2;
