//! Tables 3–5: PPW of quantized-retrained LSTM/GRU language models, driven
//! through the AOT artifacts (Layer 2 training graphs with STE quantization
//! baked in) on the synthetic corpora.
//!
//! Artifact tags follow `python/compile/aot.py`: `{lstm,gru}_{fp,w2a2,w2a3,w3a3}`.
//! All tags share one reduced geometry (vocab 2000, hidden 200, batch 20,
//! unroll 30) so a single `make artifacts` covers the three datasets; the
//! corpora differ (ptb-like / wt2-like / text8-like, vocab-scaled to 2000).
//! This substitution is documented in DESIGN.md §4.

use std::path::Path;

use anyhow::Result;

use crate::data::{Corpus, DatasetSpec};
use crate::train::{LmTrainer, SgdSchedule};

/// The W/A settings of Tables 3–5, in column order.
pub const SETTINGS: [(&str, &str); 4] = [
    ("w2a2", "2/2"),
    ("w2a3", "2/3"),
    ("w3a3", "3/3"),
    ("fp", "FP/FP"),
];

/// Which corpora the three tables use (scaled to the shared artifact
/// geometry: vocab 2000).
pub fn dataset_for_table(table: usize, scale_div: usize) -> DatasetSpec {
    match table {
        3 => DatasetSpec::ptb_like().scaled(scale_div, 5),
        // vocab forced to the shared artifact geometry (2000); DESIGN.md §4.
        4 => DatasetSpec::wt2_like().scaled(scale_div * 2, 17).with_vocab(2000),
        5 => DatasetSpec::text8_like().scaled(scale_div * 16, 21).with_vocab(2000),
        _ => panic!("tables 3..=5 only"),
    }
}

/// Train one tag on a corpus for a bounded budget; returns (best val PPW,
/// test PPW at the end).
#[allow(clippy::too_many_arguments)]
pub fn train_tag(
    artifact_dir: &Path,
    tag: &str,
    corpus: &Corpus,
    epochs: usize,
    steps_per_epoch: usize,
    eval_steps: usize,
    lr0: f64,
    mut log: impl FnMut(String),
) -> Result<(f64, f64)> {
    let mut trainer = LmTrainer::load(artifact_dir, tag)?;
    if corpus.spec.vocab != trainer.manifest.vocab {
        anyhow::bail!(
            "corpus vocab {} != artifact vocab {} (tag {tag})",
            corpus.spec.vocab,
            trainer.manifest.vocab
        );
    }
    // The §5 schedule, with lr0 scaled for the reduced geometry (the paper's
    // lr=20 pairs with vocab 10K; pass --lr to override).
    let schedule = SgdSchedule::new(lr0, 1.2, 1e-3, 80);
    let report = trainer.fit(
        &corpus.train,
        &corpus.valid,
        schedule,
        epochs,
        Some(steps_per_epoch),
        Some(eval_steps),
        |epoch, loss, val, lr| {
            log(format!(
                "  [{tag}] epoch {epoch:>2}  train-nll {loss:.3}  val-ppw {val:.1}  lr {lr:.3}"
            ));
        },
    )?;
    let test_ppw = trainer.evaluate(&corpus.test, Some(eval_steps))?;
    Ok((report.best_val_ppw, test_ppw))
}

/// Run one of Tables 3–5 across kinds × settings. Skips cleanly (with an
/// instruction) when artifacts are missing.
#[allow(clippy::too_many_arguments)]
pub fn table3_4_5(
    table: usize,
    artifact_dir: &Path,
    scale_div: usize,
    epochs: usize,
    steps_per_epoch: usize,
    eval_steps: usize,
    lr0: f64,
    mut log: impl FnMut(String),
) -> Result<String> {
    let spec = dataset_for_table(table, scale_div);
    let corpus = Corpus::generate(spec.clone());
    let mut s = format!(
        "Table {table} — testing PPW after quantized retraining on {} ({} train tokens, vocab {})\n",
        spec.name,
        corpus.train.len(),
        spec.vocab
    );
    s.push_str(&format!("{:<8}{:>10}{:>10}{:>10}{:>10}\n", "", "2/2", "2/3", "3/3", "FP/FP"));
    for kind in ["lstm", "gru"] {
        let mut row = format!("{kind:<8}");
        for (setting, _) in SETTINGS {
            let tag = format!("{kind}_{setting}");
            match train_tag(
                artifact_dir,
                &tag,
                &corpus,
                epochs,
                steps_per_epoch,
                eval_steps,
                lr0,
                &mut log,
            ) {
                Ok((_, test_ppw)) => row.push_str(&format!("{test_ppw:>10.1}")),
                Err(e) => {
                    if e.to_string().contains("make artifacts") {
                        return Ok(format!(
                            "Table {table}: artifacts missing — run `make artifacts` first ({e})"
                        ));
                    }
                    row.push_str(&format!("{:>10}", "ERR"));
                    log(format!("  [{tag}] error: {e}"));
                }
            }
        }
        s.push_str(&row);
        s.push('\n');
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_mapping_matches_tables() {
        assert!(dataset_for_table(3, 8).name.starts_with("ptb-like"));
        assert!(dataset_for_table(4, 8).name.starts_with("wt2-like"));
        assert!(dataset_for_table(5, 8).name.starts_with("text8-like"));
    }

    #[test]
    #[should_panic(expected = "tables 3..=5 only")]
    fn bad_table_panics() {
        dataset_for_table(6, 1);
    }

    #[test]
    fn settings_cover_paper_columns() {
        let cols: Vec<&str> = SETTINGS.iter().map(|(_, c)| *c).collect();
        assert_eq!(cols, vec!["2/2", "2/3", "3/3", "FP/FP"]);
    }
}
