//! Table 6 (Appendix A): binary matrix–vector timing on CPU, with the
//! online quantization cost broken out, plus the §3/§4 analytic cost model,
//! the batched-GEMM sweep over B, the worker-pool thread-scaling sweep,
//! the kernel-backend sweep (scalar vs AVX2/NEON, bit-identical outputs,
//! wall time only), and the fused-vs-pairwise sweep of the count
//! primitive itself (one fused block call vs per-plane-pair passes, with
//! the block micro-model's predicted ratio).

use crate::exec::{Exec, ExecConfig};
use crate::kernels::{binary, cost, dense, Kernel};
use crate::quant::{Method, QuantizedBatch, RowQuantized};
use crate::util::timer::{bench_fn, black_box};
use crate::util::Rng;

/// One row of Table 6.
#[derive(Clone, Debug)]
pub struct Table6Row {
    pub m: usize,
    pub n: usize,
    pub bits: Option<usize>, // None = FP
    pub total_ms: f64,
    pub quant_ms: f64,
    pub accel: f64,
}

/// Run Table 6 for the paper's two shapes (hidden-state product 4096×1024
/// and Text8 softmax 42000×1024) at 2/2, 3/3 and FP. `samples` controls
/// bench precision; shapes can be scaled down for quick checks.
pub fn table6(shapes: &[(usize, usize)], samples: usize) -> Vec<Table6Row> {
    let mut rows = Vec::new();
    for &(m, n) in shapes {
        let mut rng = Rng::new(0xBEEF + m as u64);
        let w = rng.normal_vec(m * n, 0.05);
        let x = rng.normal_vec(n, 0.5);
        // FP baseline.
        let mut y = vec![0.0f32; m];
        let fp = bench_fn(&format!("fp {m}x{n}"), samples, || {
            dense::gemv(&w, m, n, &x, &mut y);
            black_box(&y);
        });
        let fp_ms = fp.median_ms();
        rows.push(Table6Row { m, n, bits: None, total_ms: fp_ms, quant_ms: 0.0, accel: 1.0 });
        for k in [2usize, 3] {
            let wq = binary::PreparedGemm::new(&RowQuantized::quantize(
                &w,
                m,
                n,
                k,
                Method::Alternating { t: 2 },
            ));
            // Online quantization alone (the "Quant" column).
            let q = bench_fn(&format!("quant k={k} n={n}"), samples, || {
                black_box(binary::quantize_activations(&x, k));
            });
            // Full online path: quantize + binary GEMV (the serving layout).
            let mut yq = vec![0.0f32; m];
            let tot = bench_fn(&format!("binary {m}x{n} k={k}"), samples, || {
                wq.online_gemv(&x, k, &mut yq);
                black_box(&yq);
            });
            rows.push(Table6Row {
                m,
                n,
                bits: Some(k),
                total_ms: tot.median_ms(),
                quant_ms: q.median_ms(),
                accel: fp_ms / tot.median_ms(),
            });
        }
    }
    rows
}

pub fn render_table6(rows: &[Table6Row]) -> String {
    let mut s = String::from(
        "Table 6 — binary GEMV on CPU (alternating online quant, T=2)\n\
         Weight Size      W/A bits   Total(ms)   Quant(ms)  Quant/Total  Accel\n",
    );
    for r in rows {
        let bits = match r.bits {
            Some(k) => format!("{k}/{k}"),
            None => "FP/FP".into(),
        };
        let share = if r.total_ms > 0.0 { r.quant_ms / r.total_ms * 100.0 } else { 0.0 };
        s.push_str(&format!(
            "{:>7}x{:<7}  {:>7}   {:>9.3}   {:>9.3}   {:>9.1}%  {:>5.1}x\n",
            r.m, r.n, bits, r.total_ms, r.quant_ms, share, r.accel
        ));
    }
    s
}

/// One row of the batched-GEMM sweep: `B` activation vectors served by one
/// sweep over the packed weight planes ([`binary::PreparedGemm::gemm`],
/// Fig. 3 right), online quantization included.
#[derive(Clone, Debug)]
pub struct BatchSweepRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    /// Median wall time of one batched online GEMM.
    pub total_ms: f64,
    /// Activation vectors completed per second (`batch / total`).
    pub vecs_per_sec: f64,
}

/// Sweep the batched XNOR/popcount GEMM over batch sizes — the measurement
/// behind the batch-first serving API: per-vector cost must fall as `B`
/// grows because the weight planes are streamed once per batch.
pub fn gemm_batch_sweep(
    shapes: &[(usize, usize)],
    batches: &[usize],
    k: usize,
    samples: usize,
) -> Vec<BatchSweepRow> {
    let mut rows = Vec::new();
    for &(m, n) in shapes {
        let mut rng = Rng::new(0xFACE + m as u64);
        let w = rng.normal_vec(m * n, 0.05);
        let prep = binary::PreparedGemm::new(&RowQuantized::quantize(
            &w,
            m,
            n,
            k,
            Method::Alternating { t: 2 },
        ));
        for &b in batches {
            let x = rng.normal_vec(b * n, 0.5);
            let mut y = vec![0.0f32; b * m];
            let r = bench_fn(&format!("gemm {m}x{n} k={k} b={b}"), samples, || {
                prep.online_gemm(&x, b, k, &mut y);
                black_box(&y);
            });
            let total_ms = r.median_ms();
            rows.push(BatchSweepRow {
                m,
                n,
                k,
                batch: b,
                total_ms,
                vecs_per_sec: b as f64 / (total_ms / 1e3),
            });
        }
    }
    rows
}

pub fn render_batch_sweep(rows: &[BatchSweepRow]) -> String {
    let mut s = String::from(
        "Batched binary GEMM sweep (one weight-plane sweep per batch)\n\
         Weight Size      W/A bits  Batch   Total(ms)     vec/s   ms/vec   vs B=1\n",
    );
    for r in rows {
        let base = rows
            .iter()
            .find(|q| q.m == r.m && q.n == r.n && q.k == r.k && q.batch == 1)
            .map(|q| q.total_ms)
            .unwrap_or(r.total_ms / r.batch as f64);
        let speedup = (base * r.batch as f64) / r.total_ms;
        s.push_str(&format!(
            "{:>7}x{:<7}  {:>5}/{:<2}  {:>5}   {:>9.3}  {:>8.0}  {:>7.4}  {:>6.2}x\n",
            r.m,
            r.n,
            r.k,
            r.k,
            r.batch,
            r.total_ms,
            r.vecs_per_sec,
            r.total_ms / r.batch as f64,
            speedup
        ));
    }
    s
}

/// One row of the thread-scaling sweep: the same row-sharded batched GEMM
/// ([`binary::PreparedGemm::gemm_exec`]) on a `threads`-wide worker pool.
#[derive(Clone, Debug)]
pub struct ThreadSweepRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    pub threads: usize,
    /// Median wall time of one batched GEMM (activations pre-quantized, so
    /// this isolates the kernel's scaling).
    pub total_ms: f64,
    /// Speedup vs the `threads = 1` row of the same shape.
    pub speedup: f64,
}

/// Sweep the row-sharded batched GEMM over worker-pool sizes — the scaling
/// curve of the execution engine. The activation batch is quantized once up
/// front; every thread count computes the bit-identical output (pinned by
/// `rust/tests/exec_parity.rs`), so the only variable is wall time.
pub fn gemm_thread_sweep(
    shapes: &[(usize, usize)],
    batch: usize,
    k: usize,
    threads: &[usize],
    samples: usize,
) -> Vec<ThreadSweepRow> {
    let mut rows = Vec::new();
    for &(m, n) in shapes {
        let mut rng = Rng::new(0xD00D + m as u64);
        let w = rng.normal_vec(m * n, 0.05);
        let prep = binary::PreparedGemm::new(&RowQuantized::quantize(
            &w,
            m,
            n,
            k,
            Method::Alternating { t: 2 },
        ));
        let x = rng.normal_vec(batch * n, 0.5);
        let xq = QuantizedBatch::quantize(&x, batch, n, k);
        let mut shape_rows = Vec::new();
        for &t in threads {
            let exec = Exec::new(ExecConfig::with_threads(t.max(1)));
            let mut y = vec![0.0f32; batch * m];
            let r = bench_fn(&format!("gemm {m}x{n} k={k} b={batch} t={t}"), samples, || {
                prep.gemm_exec(&xq, &mut y, &exec);
                black_box(&y);
            });
            shape_rows.push(ThreadSweepRow {
                m,
                n,
                k,
                batch,
                threads: exec.threads(),
                total_ms: r.median_ms(),
                speedup: 1.0,
            });
        }
        let base = shape_rows
            .iter()
            .find(|r| r.threads == 1)
            .map(|r| r.total_ms)
            .unwrap_or_else(|| shape_rows.first().map(|r| r.total_ms).unwrap_or(0.0));
        for r in &mut shape_rows {
            r.speedup = if r.total_ms > 0.0 { base / r.total_ms } else { 1.0 };
        }
        rows.extend(shape_rows);
    }
    rows
}

pub fn render_thread_sweep(rows: &[ThreadSweepRow]) -> String {
    let mut s = String::from(
        "Row-sharded binary GEMM thread scaling (disjoint output-row ranges)\n\
         Weight Size      W/A bits  Batch  Threads   Total(ms)   vs 1 thread\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>7}x{:<7}  {:>5}/{:<2}  {:>5}  {:>7}   {:>9.3}   {:>9.2}x\n",
            r.m, r.n, r.k, r.k, r.batch, r.threads, r.total_ms, r.speedup
        ));
    }
    s
}

/// Packed bytes one batched GEMM touches: every weight plane streams once
/// (`m·k` planes) and every activation plane is read once per weight-row
/// pass in the cache-resident ideal (`batch·k` planes, counted once) —
/// the *useful* traffic, which is what effective GB/s should charge.
fn gemm_packed_bytes(m: usize, n: usize, k: usize, batch: usize) -> f64 {
    let wpp = n.div_ceil(64);
    ((m * k + batch * k) * wpp * 8) as f64
}

/// Effective GB/s from packed bytes touched and a median wall time.
fn effective_gbps(bytes: f64, ms: f64) -> f64 {
    if ms > 0.0 {
        bytes / (ms / 1e3) / 1e9
    } else {
        0.0
    }
}

/// One row of the kernel-backend sweep: the same batched GEMM forced onto
/// one backend ([`binary::PreparedGemm::set_kernel`]).
#[derive(Clone, Debug)]
pub struct BackendSweepRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    pub backend: &'static str,
    /// Median wall time of one batched GEMM (activations pre-quantized).
    pub total_ms: f64,
    /// Speedup vs the scalar row of the same shape.
    pub speedup_vs_scalar: f64,
    /// Effective bandwidth: packed bytes touched / wall time.
    pub gbps: f64,
    /// `gbps / roof_gbps` — how close this shape runs to the measured
    /// stream-bandwidth roof (0 when no roof was probed). ≥ ~0.5 means
    /// the kernel is memory-bound and more SIMD cannot help.
    pub roof_fraction: f64,
}

/// Sweep the batched GEMM over every kernel backend this host can run —
/// the measurement behind the runtime-dispatch layer. All backends compute
/// the bit-identical output (asserted here per shape, and pinned at full
/// grid by `rust/tests/kernel_parity.rs`); only wall time differs.
/// `roof_gbps` is the measured stream roof ([`stream_roof`]; pass 0.0 to
/// skip the roof fraction).
pub fn gemm_backend_sweep(
    shapes: &[(usize, usize)],
    batch: usize,
    k: usize,
    samples: usize,
    roof_gbps: f64,
) -> Vec<BackendSweepRow> {
    let mut rows = Vec::new();
    for &(m, n) in shapes {
        let mut rng = Rng::new(0xFEED + m as u64);
        let w = rng.normal_vec(m * n, 0.05);
        let mut prep = binary::PreparedGemm::with_kernel(
            &RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 }),
            Kernel::Scalar,
        );
        let x = rng.normal_vec(batch * n, 0.5);
        let xq = QuantizedBatch::quantize(&x, batch, n, k);
        let bytes = gemm_packed_bytes(m, n, k, batch);
        let mut reference: Option<Vec<f32>> = None;
        let mut shape_rows = Vec::new();
        for kernel in Kernel::available() {
            prep.set_kernel(kernel);
            let mut y = vec![0.0f32; batch * m];
            let r = bench_fn(&format!("gemm {m}x{n} k={k} b={batch} {kernel}"), samples, || {
                prep.gemm(&xq, &mut y);
                black_box(&y);
            });
            match &reference {
                None => reference = Some(y.clone()),
                // Exactness sanity: backends agree bit-for-bit.
                Some(want) => assert_eq!(&y, want, "backend {kernel} diverged at {m}x{n}"),
            }
            let total_ms = r.median_ms();
            let gbps = effective_gbps(bytes, total_ms);
            shape_rows.push(BackendSweepRow {
                m,
                n,
                k,
                batch,
                backend: kernel.name(),
                total_ms,
                speedup_vs_scalar: 1.0,
                gbps,
                roof_fraction: if roof_gbps > 0.0 { gbps / roof_gbps } else { 0.0 },
            });
        }
        let base = shape_rows
            .iter()
            .find(|r| r.backend == "scalar")
            .map(|r| r.total_ms)
            .unwrap_or(1.0);
        for r in &mut shape_rows {
            r.speedup_vs_scalar = if r.total_ms > 0.0 { base / r.total_ms } else { 1.0 };
        }
        rows.extend(shape_rows);
    }
    rows
}

pub fn render_backend_sweep(rows: &[BackendSweepRow]) -> String {
    let mut s = String::from(
        "Kernel-backend sweep (bit-identical outputs, wall time only)\n\
         Weight Size      W/A bits  Batch  Backend   Total(ms)   vs scalar    GB/s  of roof\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>7}x{:<7}  {:>5}/{:<2}  {:>5}  {:>7}   {:>9.3}   {:>7.2}x  {:>6.1}  {:>6.1}%\n",
            r.m,
            r.n,
            r.k,
            r.k,
            r.batch,
            r.backend,
            r.total_ms,
            r.speedup_vs_scalar,
            r.gbps,
            r.roof_fraction * 100.0
        ));
    }
    s
}

/// The measured memory-bandwidth roof of this host: the best of a large
/// `memcpy` and a STREAM-style triad over buffers far larger than any
/// cache, in GB/s. The backend and tiled sweeps report each shape's
/// effective bandwidth as a fraction of this roof, making "are we
/// memory-bound yet?" a tracked number instead of a guess.
#[derive(Clone, Debug)]
pub struct BandwidthRoof {
    /// `memcpy` bandwidth (2 bytes moved per byte of buffer: read+write).
    pub memcpy_gbps: f64,
    /// Triad `a[i] = b[i] + 3·c[i]` bandwidth (3 streams).
    pub triad_gbps: f64,
    /// `max(memcpy, triad)` — the roof the fractions are measured against.
    pub roof_gbps: f64,
    /// Buffer size probed (bytes per stream).
    pub buffer_bytes: usize,
}

/// Probe the stream-bandwidth roof. `quick` uses 16 MB streams (CI), full
/// uses 64 MB — both far beyond L2/L3 slices, so the probe measures DRAM,
/// not cache.
pub fn stream_roof(samples: usize, quick: bool) -> BandwidthRoof {
    let buffer_bytes: usize = if quick { 16 << 20 } else { 64 << 20 };
    // memcpy over u64 words.
    let words = buffer_bytes / 8;
    let src: Vec<u64> = (0..words as u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
    let mut dst = vec![0u64; words];
    let mc = bench_fn("roof memcpy", samples, || {
        dst.copy_from_slice(&src);
        black_box(&dst);
    });
    // STREAM triad over f32.
    let floats = buffer_bytes / 4;
    let b: Vec<f32> = (0..floats).map(|i| (i % 113) as f32).collect();
    let c: Vec<f32> = (0..floats).map(|i| (i % 127) as f32).collect();
    let mut a = vec![0.0f32; floats];
    let tr = bench_fn("roof triad", samples, || {
        for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
            *ai = *bi + 3.0 * *ci;
        }
        black_box(&a);
    });
    let memcpy_gbps = effective_gbps(2.0 * buffer_bytes as f64, mc.median_ms());
    let triad_gbps = effective_gbps(3.0 * buffer_bytes as f64, tr.median_ms());
    BandwidthRoof {
        memcpy_gbps,
        triad_gbps,
        roof_gbps: memcpy_gbps.max(triad_gbps),
        buffer_bytes,
    }
}

pub fn render_roof(r: &BandwidthRoof) -> String {
    format!(
        "Stream-bandwidth roof ({} MB streams): memcpy {:.1} GB/s, triad {:.1} GB/s -> roof {:.1} GB/s\n",
        r.buffer_bytes >> 20,
        r.memcpy_gbps,
        r.triad_gbps,
        r.roof_gbps
    )
}

/// One row of the tiled-vs-untiled sweep: the same batched GEMM on the
/// detected backend, with the column-tile budget forced per row
/// ([`binary::PreparedGemm::set_l2_budget`]). All configurations produce
/// byte-identical outputs (asserted in the sweep); only DRAM traffic —
/// and so wall time — differs.
#[derive(Clone, Debug)]
pub struct TiledSweepRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    /// `"untiled"` (single tile via a `usize::MAX` budget), `"auto"` (the
    /// detected/overridden L2 budget), or `"tiny"` (64 KB — many tiles).
    pub config: &'static str,
    /// The tile width (columns) this config resolved to.
    pub tile_cols: usize,
    pub total_ms: f64,
    /// Speedup vs the untiled row of the same shape.
    pub speedup_vs_untiled: f64,
    /// Effective bandwidth: packed bytes touched / wall time.
    pub gbps: f64,
    /// `gbps / roof_gbps` (0 when no roof was probed).
    pub roof_fraction: f64,
    /// The traffic model's predicted untiled/tiled DRAM-byte ratio for
    /// this config's budget ([`cost::tiled_traffic_advantage`]; 1.0 for
    /// the untiled row itself).
    pub predicted: f64,
}

/// Measure cache tiling at one shape on the detected backend: untiled
/// (one tile), the auto budget, and a deliberately tiny budget. Outputs
/// are asserted byte-identical across configs — tiling only reorders
/// whole output elements — so the sweep doubles as a parity check at
/// bench shapes.
pub fn tiled_vs_untiled_sweep(
    m: usize,
    n: usize,
    k: usize,
    batch: usize,
    samples: usize,
    roof_gbps: f64,
) -> Vec<TiledSweepRow> {
    let mut rng = Rng::new(0x711E + m as u64);
    let w = rng.normal_vec(m * n, 0.05);
    let mut prep = binary::PreparedGemm::new(&RowQuantized::quantize(
        &w,
        m,
        n,
        k,
        Method::Alternating { t: 2 },
    ));
    let x = rng.normal_vec(batch * n, 0.5);
    let xq = QuantizedBatch::quantize(&x, batch, n, k);
    let bytes = gemm_packed_bytes(m, n, k, batch);
    let wpp = n.div_ceil(64);
    let configs: [(&'static str, usize); 3] =
        [("untiled", usize::MAX), ("auto", cost::l2_bytes()), ("tiny", 64 * 1024)];
    let mut reference: Option<Vec<f32>> = None;
    let mut rows = Vec::new();
    for (config, budget) in configs {
        prep.set_l2_budget(budget);
        let mut y = vec![0.0f32; batch * m];
        let r = bench_fn(&format!("tiled {m}x{n} b={batch} {config}"), samples, || {
            prep.gemm(&xq, &mut y);
            black_box(&y);
        });
        match &reference {
            None => reference = Some(y.clone()),
            // Exactness: tiling must be bit-neutral at bench shapes too.
            Some(want) => assert_eq!(&y, want, "tiling config {config} diverged at {m}x{n}"),
        }
        let total_ms = r.median_ms();
        let gbps = effective_gbps(bytes, total_ms);
        let predicted = if config == "untiled" {
            1.0
        } else {
            cost::tiled_traffic_advantage(
                m as u64,
                wpp as u64,
                k as u64,
                k as u64,
                batch as u64,
                budget as u64,
                4,
            )
        };
        rows.push(TiledSweepRow {
            m,
            n,
            k,
            batch,
            config,
            tile_cols: prep.tile_cols(k),
            total_ms,
            speedup_vs_untiled: 1.0,
            gbps,
            roof_fraction: if roof_gbps > 0.0 { gbps / roof_gbps } else { 0.0 },
            predicted,
        });
    }
    let base = rows
        .iter()
        .find(|r| r.config == "untiled")
        .map(|r| r.total_ms)
        .unwrap_or(1.0);
    for r in &mut rows {
        r.speedup_vs_untiled = if r.total_ms > 0.0 { base / r.total_ms } else { 1.0 };
    }
    rows
}

pub fn render_tiled_sweep(rows: &[TiledSweepRow]) -> String {
    let mut s = String::from(
        "Cache-tiled batched GEMM (byte-identical outputs, traffic only)\n\
         Weight Size      W/A bits  Batch  Config    Tile   Total(ms)  vs untiled    GB/s  of roof  Predicted\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>7}x{:<7}  {:>5}/{:<2}  {:>5}  {:>7}  {:>5}   {:>9.3}  {:>9.2}x  {:>6.1}  {:>6.1}%  {:>8.2}x\n",
            r.m,
            r.n,
            r.k,
            r.k,
            r.batch,
            r.config,
            r.tile_cols,
            r.total_ms,
            r.speedup_vs_untiled,
            r.gbps,
            r.roof_fraction * 100.0,
            r.predicted
        ));
    }
    s
}

/// One row of the fused-vs-pairwise sweep: the same batch block of counts
/// computed through the single count primitive either as **one fused
/// block** (one call, per-chain accumulators, one reduction per chain) or
/// as **pairwise plane passes** (one 1×1×1 call per (column, w-plane,
/// x-plane) chain — the decomposition the backends used before the fused
/// kernel), plus the block micro-model's predicted ratio
/// ([`cost::fused_block_advantage`]).
#[derive(Clone, Debug)]
pub struct FusedSweepRow {
    /// Words per plane (the serving shape 1024 cols = 16 words; 128 words
    /// is the Harley–Seal regime where both layouts converge).
    pub words: usize,
    pub k: usize,
    pub batch: usize,
    pub backend: &'static str,
    pub fused_ms: f64,
    pub pairwise_ms: f64,
    /// `pairwise_ms / fused_ms` — this PR's headline number at short planes.
    pub speedup: f64,
    /// The micro-model's predicted ratio: 1.0 for scalar; for AVX2 — and
    /// the AVX-512 LUT arm — the cutoff model (1.0 in the Harley–Seal
    /// long-plane regime, where both layouts share a code path); for NEON
    /// and the AVX-512 `vpopcntq` arm the raw ratio (their fused kernels
    /// run at every plane length).
    pub predicted: f64,
}

/// Measure the fused block primitive against its pairwise decomposition
/// at the count-kernel level, per backend and plane length. Both layouts
/// produce identical counts (asserted) — only the pass structure differs.
///
/// Caveat: the pairwise layout is *emulated* through the same single
/// primitive (one 1×1×1 call per chain), so each pair also pays the
/// dispatch + accumulator-setup cost of a full `block_counts` call —
/// overhead the pre-fusion in-backend pairwise loops partially avoided.
/// The ratio is therefore an upper bound on the fusion win alone; the
/// end-to-end gate that matters (detected SIMD vs forced scalar at the
/// serving shape, backend sweep) measures through `PreparedGemm::gemm`
/// and carries no such bias.
pub fn fused_vs_pairwise_sweep(
    plane_words: &[usize],
    batch: usize,
    k: usize,
    samples: usize,
) -> Vec<FusedSweepRow> {
    use crate::kernels::backend;
    const ROWS: usize = 64;
    let mut out = Vec::new();
    let mut rng = Rng::new(0xF05E);
    for &words in plane_words {
        let wdata: Vec<Vec<u64>> = (0..ROWS * k)
            .map(|_| (0..words).map(|_| rng.next_u64()).collect())
            .collect();
        let xdata: Vec<Vec<u64>> = (0..batch * k)
            .map(|_| (0..words).map(|_| rng.next_u64()).collect())
            .collect();
        let wrows: Vec<Vec<&[u64]>> = (0..ROWS)
            .map(|r| (0..k).map(|t| &wdata[r * k + t][..]).collect())
            .collect();
        let cols: Vec<Vec<&[u64]>> = (0..batch)
            .map(|j| (0..k).map(|s| &xdata[j * k + s][..]).collect())
            .collect();
        let x_block: Vec<&[&[u64]]> = cols.iter().map(|c| &c[..]).collect();
        let chains = batch * k * k;
        let mut fused_counts = vec![0u32; chains];
        let mut pair_counts = vec![0u32; chains];
        let run_fused = |kernel, counts: &mut [u32]| {
            for wr in &wrows {
                counts.fill(0);
                backend::block_counts(kernel, wr, &x_block, counts);
            }
        };
        let run_pairwise = |kernel, counts: &mut [u32]| {
            for wr in &wrows {
                counts.fill(0);
                for (j, xj) in x_block.iter().enumerate() {
                    for (t, wt) in wr.iter().enumerate() {
                        for (s, xs) in xj.iter().enumerate() {
                            let pair_w: [&[u64]; 1] = [*wt];
                            let pair_x: [&[u64]; 1] = [*xs];
                            let pair_col: [&[&[u64]]; 1] = [&pair_x];
                            let c = (j * k + t) * k + s;
                            backend::block_counts(
                                kernel,
                                &pair_w,
                                &pair_col,
                                &mut counts[c..c + 1],
                            );
                        }
                    }
                }
            }
        };
        for kernel in Kernel::available() {
            // Exactness sanity: both layouts are the same integers.
            run_fused(kernel, &mut fused_counts);
            run_pairwise(kernel, &mut pair_counts);
            assert_eq!(fused_counts, pair_counts, "{kernel} words={words}");
            let f = bench_fn(&format!("fused {kernel} w={words}"), samples, || {
                run_fused(kernel, &mut fused_counts);
                black_box(&fused_counts);
            });
            let p = bench_fn(&format!("pairwise {kernel} w={words}"), samples, || {
                run_pairwise(kernel, &mut pair_counts);
                black_box(&pair_counts);
            });
            let (fused_ms, pairwise_ms) = (f.median_ms(), p.median_ms());
            let (w64, k64, b64) = (words as u64, k as u64, batch as u64);
            let predicted = match kernel {
                // The micro-model is a SIMD model; scalar's two layouts
                // differ only in loop fusion.
                Kernel::Scalar => 1.0,
                // AVX2 falls back to the same Harley–Seal pairwise pass on
                // long planes, so its predicted advantage has a cutoff.
                Kernel::Avx2 => cost::fused_block_advantage(w64, k64, k64, b64),
                // AVX-512 is arm-dependent: the vpopcntq arm runs fused at
                // every plane length (512-bit raw ratio), the LUT arm has
                // the same Harley–Seal cutoff as AVX2.
                Kernel::Avx512 => {
                    if backend::avx512_arm() == Some("vpopcntq") {
                        cost::fused_block_ratio_512(w64, k64, k64, b64)
                    } else {
                        cost::fused_block_advantage_512(w64, k64, k64, b64)
                    }
                }
                // NEON runs the fused kernel at every plane length.
                Kernel::Neon => cost::fused_block_ratio(w64, k64, k64, b64),
            };
            out.push(FusedSweepRow {
                words,
                k,
                batch,
                backend: kernel.name(),
                fused_ms,
                pairwise_ms,
                speedup: if fused_ms > 0.0 { pairwise_ms / fused_ms } else { 1.0 },
                predicted,
            });
        }
    }
    out
}

pub fn render_fused_sweep(rows: &[FusedSweepRow]) -> String {
    let mut s = String::from(
        "Fused block primitive vs pairwise plane passes (identical counts)\n\
         Words/plane  W/A bits  Block  Backend   Fused(ms)  Pairwise(ms)  Speedup  Predicted\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>11}  {:>5}/{:<2}  {:>5}  {:>7}  {:>9.3}  {:>12.3}  {:>6.2}x  {:>8.2}x\n",
            r.words, r.k, r.k, r.batch, r.backend, r.fused_ms, r.pairwise_ms, r.speedup, r.predicted
        ));
    }
    s
}

/// The scalar absolute-speed floor (the ROADMAP item open since the fused
/// kernel refactor dropped scalar's const-generic specialization): the
/// forced-**scalar** quantized GEMV against the dense f32 GEMV on the same
/// shape. `kernel_ratio > 1` means the portable scalar backend alone still
/// delivers the paper's quantized-beats-FP win — the floor that protects
/// scalar-only hosts, where runtime dispatch has nothing better to offer.
/// `online_ratio` additionally charges the online activation quantization
/// (the full Table 6 request path), reported for context.
#[derive(Clone, Debug)]
pub struct ScalarFloorRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub fp_ms: f64,
    pub scalar_ms: f64,
    pub online_ms: f64,
    /// `fp_ms / scalar_ms` — prequantized GEMV, the kernel floor (gated).
    pub kernel_ratio: f64,
    /// `fp_ms / online_ms` — quantize + GEMV (reported, not gated).
    pub online_ratio: f64,
}

/// Measure the scalar floor at one shape (the bench gates it on the
/// long-plane serving shape, where the win is structural).
pub fn scalar_fp_floor(m: usize, n: usize, k: usize, samples: usize) -> ScalarFloorRow {
    let mut rng = Rng::new(0xF100 + m as u64);
    let w = rng.normal_vec(m * n, 0.05);
    let x = rng.normal_vec(n, 0.5);
    let prep = binary::PreparedGemm::with_kernel(
        &RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 }),
        Kernel::Scalar,
    );
    let xq = binary::quantize_activations(&x, k);
    let mut y = vec![0.0f32; m];
    let fp = bench_fn(&format!("floor fp {m}x{n}"), samples, || {
        dense::gemv(&w, m, n, &x, &mut y);
        black_box(&y);
    });
    let sc = bench_fn(&format!("floor scalar {m}x{n} k={k}"), samples, || {
        prep.gemv(&xq, &mut y);
        black_box(&y);
    });
    let on = bench_fn(&format!("floor scalar online {m}x{n} k={k}"), samples, || {
        prep.online_gemv(&x, k, &mut y);
        black_box(&y);
    });
    let (fp_ms, scalar_ms, online_ms) = (fp.median_ms(), sc.median_ms(), on.median_ms());
    ScalarFloorRow {
        m,
        n,
        k,
        fp_ms,
        scalar_ms,
        online_ms,
        kernel_ratio: if scalar_ms > 0.0 { fp_ms / scalar_ms } else { 1.0 },
        online_ratio: if online_ms > 0.0 { fp_ms / online_ms } else { 1.0 },
    }
}

pub fn render_scalar_floor(r: &ScalarFloorRow) -> String {
    format!(
        "Scalar absolute-speed floor (forced scalar vs dense f32 GEMV)\n\
         {:>7}x{:<7}  {}/{} bits:  fp={:.3}ms  scalar={:.3}ms  online={:.3}ms  \
         kernel {:.2}x  online {:.2}x\n",
        r.m, r.n, r.k, r.k, r.fp_ms, r.scalar_ms, r.online_ms, r.kernel_ratio, r.online_ratio
    )
}

/// The §4 cost-model table: theoretical γ vs measured acceleration.
pub fn costmodel(shapes: &[(usize, usize)], measured: &[Table6Row]) -> String {
    let mut s = String::from("Cost model (§4): theoretical gamma vs measured acceleration\n");
    for &(m, n) in shapes {
        for k in [2usize, 3] {
            let gamma = cost::theoretical_speedup(m as u64, n as u64, k as u64, k as u64);
            let meas = measured
                .iter()
                .find(|r| r.m == m && r.n == n && r.bits == Some(k))
                .map(|r| r.accel)
                .unwrap_or(f64::NAN);
            let mem = cost::memory_saving(m as u64, n as u64, k as u64);
            s.push_str(&format!(
                "{m:>7}x{n:<7} k={k}:  gamma={gamma:>5.2}x  measured={meas:>5.2}x  memory={mem:>5.1}x\n"
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_small_shapes_run_and_accelerate() {
        // Scaled shapes keep test time bounded; the acceleration claim at
        // full shape is validated in the bench run (EXPERIMENTS.md).
        let rows = table6(&[(512, 1024)], 5);
        assert_eq!(rows.len(), 3);
        let fp = &rows[0];
        let k2 = &rows[1];
        assert!(fp.bits.is_none() && k2.bits == Some(2));
        assert!(k2.total_ms > 0.0 && fp.total_ms > 0.0);
        // 2-bit binary GEMV must beat FP on a 512x1024 matrix.
        assert!(k2.accel > 1.0, "accel {:.2}", k2.accel);
        // Quant share must be well below total (paper: 2-20%).
        assert!(k2.quant_ms < k2.total_ms, "{rows:?}");
    }

    #[test]
    fn batch_sweep_runs_and_renders() {
        let rows = gemm_batch_sweep(&[(128, 256)], &[1, 4], 2, 3);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.total_ms > 0.0 && r.vecs_per_sec > 0.0));
        let s = render_batch_sweep(&rows);
        assert!(s.contains("vs B=1"), "{s}");
    }

    #[test]
    fn thread_sweep_runs_and_renders() {
        let rows = gemm_thread_sweep(&[(96, 200)], 4, 2, &[1, 2], 3);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].threads, 1);
        assert_eq!(rows[1].threads, 2);
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.total_ms > 0.0 && r.speedup > 0.0));
        let s = render_thread_sweep(&rows);
        assert!(s.contains("vs 1 thread"), "{s}");
    }

    #[test]
    fn backend_sweep_covers_available_backends_and_renders() {
        let rows = gemm_backend_sweep(&[(64, 256)], 4, 2, 3, 10.0);
        let available = Kernel::available();
        assert_eq!(rows.len(), available.len());
        assert_eq!(rows[0].backend, "scalar");
        assert!((rows[0].speedup_vs_scalar - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.total_ms > 0.0 && r.speedup_vs_scalar > 0.0));
        // Effective bandwidth and roof fraction are populated and
        // consistent (roof passed as 10 GB/s here).
        for r in &rows {
            assert!(r.gbps > 0.0, "{r:?}");
            assert!((r.roof_fraction - r.gbps / 10.0).abs() < 1e-9, "{r:?}");
        }
        let s = render_backend_sweep(&rows);
        assert!(s.contains("vs scalar"), "{s}");
        assert!(s.contains("of roof"), "{s}");
        // roof = 0 means "not probed": fraction 0, not NaN/inf.
        let rows0 = gemm_backend_sweep(&[(32, 128)], 2, 2, 2, 0.0);
        assert!(rows0.iter().all(|r| r.roof_fraction == 0.0));
    }

    #[test]
    fn stream_roof_probe_runs() {
        // Tiny sample count; quick buffers. The roof must be positive and
        // the max of its two probes.
        let r = stream_roof(2, true);
        assert!(r.memcpy_gbps > 0.0 && r.triad_gbps > 0.0);
        assert!((r.roof_gbps - r.memcpy_gbps.max(r.triad_gbps)).abs() < 1e-12);
        assert_eq!(r.buffer_bytes, 16 << 20);
        let s = render_roof(&r);
        assert!(s.contains("roof"), "{s}");
    }

    #[test]
    fn tiled_sweep_bit_matches_and_renders() {
        // Small shape: the identical-outputs assert runs inside the sweep;
        // here we check row structure, tile widths, and the predicted
        // column. Untiled must resolve to a single tile covering the batch.
        let rows = tiled_vs_untiled_sweep(48, 256, 2, 16, 2, 5.0);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].config, "untiled");
        assert!((rows[0].speedup_vs_untiled - 1.0).abs() < 1e-9);
        assert!((rows[0].predicted - 1.0).abs() < 1e-9);
        assert!(rows[0].tile_cols >= 16);
        assert!(rows.iter().all(|r| r.total_ms > 0.0 && r.gbps > 0.0 && r.predicted >= 1.0));
        let s = render_tiled_sweep(&rows);
        assert!(s.contains("vs untiled"), "{s}");
        assert!(s.contains("Predicted"), "{s}");
    }

    #[test]
    fn fused_sweep_runs_and_renders() {
        let rows = fused_vs_pairwise_sweep(&[16], 4, 2, 2);
        assert_eq!(rows.len(), Kernel::available().len());
        assert!(rows
            .iter()
            .all(|r| r.fused_ms > 0.0 && r.pairwise_ms > 0.0 && r.speedup > 0.0));
        // The micro-model predicts a strict fused win for SIMD backends at
        // the serving plane length (exact counts are asserted inside the
        // sweep itself).
        for r in rows.iter().filter(|r| r.backend != "scalar") {
            assert!(r.predicted > 1.0, "{r:?}");
        }
        let s = render_fused_sweep(&rows);
        assert!(s.contains("Predicted"), "{s}");
    }

    #[test]
    fn scalar_floor_runs_and_renders() {
        // Small shape just exercises the plumbing; the >1 floor itself is
        // gated in the bench at the long-plane shape.
        let r = scalar_fp_floor(64, 256, 2, 3);
        assert!(r.fp_ms > 0.0 && r.scalar_ms > 0.0 && r.online_ms > 0.0);
        assert!(r.kernel_ratio > 0.0 && r.online_ratio > 0.0);
        let s = render_scalar_floor(&r);
        assert!(s.contains("kernel"), "{s}");
    }

    #[test]
    fn render_contains_rows() {
        let rows = vec![Table6Row { m: 8, n: 8, bits: Some(2), total_ms: 1.0, quant_ms: 0.1, accel: 2.0 }];
        let s = render_table6(&rows);
        assert!(s.contains("2/2"));
        let cm = costmodel(&[(8, 8)], &rows);
        assert!(cm.contains("gamma"));
    }
}
