//! Tables 7–9 (Appendix B): image-classification tasks comparing
//! full-precision, Refined, and Alternating quantized training (plus Greedy
//! for Table 8, XNOR-style 1-bit for Table 9) on the synthetic image
//! substrates.

use crate::data::images::{cifar_like, mnist_like};
use crate::model::mlp::QuantSpec;
use crate::quant::Method;
use crate::train::native::{CnnTrainer, MlpConfig, MlpTrainer, SeqLstmTrainer};

/// A (method label, test error) result row.
pub type ErrRow = (String, f64);

/// Table 7: LSTM on sequential MNIST-like rows — 1-bit input, 2-bit
/// weights, 2-bit activations. Full precision vs Refined vs Alternating.
pub fn table7(train_n: usize, test_n: usize, hidden: usize, epochs: usize) -> Vec<ErrRow> {
    let train = mnist_like(train_n, 701);
    let test = mnist_like(test_n, 702);
    let mut rows = Vec::new();
    let runs: Vec<(&str, QuantSpec, Option<usize>)> = vec![
        ("Full Precision", QuantSpec::full(), None),
        ("Refined", QuantSpec::wa(2, 2, Method::Refined), Some(1)),
        ("Alternating", QuantSpec::wa(2, 2, Method::Alternating { t: 2 }), Some(1)),
    ];
    for (name, spec, input_bits) in runs {
        let mut t = SeqLstmTrainer::new(28, hidden, 10, spec, input_bits, 2e-3, 703);
        let err = t.fit(&train, &test, epochs, 704);
        rows.push((name.to_string(), err));
    }
    rows
}

/// Table 8: MLP on MNIST-like — 2-bit input, 2-bit weights, 1-bit
/// activations. Full precision vs Greedy vs Refined vs Alternating.
pub fn table8(train_n: usize, test_n: usize, hidden: usize, epochs: usize) -> Vec<ErrRow> {
    let train = mnist_like(train_n, 801);
    let test = mnist_like(test_n, 802);
    let mut rows = Vec::new();
    let runs: Vec<(&str, QuantSpec, Option<usize>)> = vec![
        ("Full Precision", QuantSpec::full(), None),
        ("Greedy", QuantSpec::wa(2, 1, Method::Greedy), Some(2)),
        ("Refined", QuantSpec::wa(2, 1, Method::Refined), Some(2)),
        ("Alternating", QuantSpec::wa(2, 1, Method::Alternating { t: 2 }), Some(2)),
    ];
    for (name, spec, input_bits) in runs {
        let mut t = MlpTrainer::new(
            MlpConfig {
                // Paper: 3 hidden layers of 4096; scaled for the CPU budget.
                layer_sizes: vec![784, hidden, hidden, hidden, 10],
                spec,
                input_bits,
                lr: 1e-3,
                batch: 50,
            },
            803,
        );
        let err = t.fit(&train, &test, epochs, 804);
        rows.push((name.to_string(), err));
    }
    rows
}

/// Table 9: VGG-like CNN on CIFAR-like — 2-bit weights, 1-bit activations.
/// Full precision vs XNOR (1-bit W/A) vs Refined vs Alternating.
pub fn table9(train_n: usize, test_n: usize, base: usize, epochs: usize) -> Vec<ErrRow> {
    let train = cifar_like(train_n, 901);
    let test = cifar_like(test_n, 902);
    let mut rows = Vec::new();
    let runs: Vec<(&str, QuantSpec)> = vec![
        ("Full Precision", QuantSpec::full()),
        ("XNOR-Net (1-bit)", QuantSpec::wa(1, 1, Method::Greedy)),
        ("Refined", QuantSpec::wa(2, 1, Method::Refined)),
        ("Alternating", QuantSpec::wa(2, 1, Method::Alternating { t: 2 })),
    ];
    for (name, spec) in runs {
        let mut t = CnnTrainer::new(base, 8 * base, spec, 1e-3, 903);
        let err = t.fit(&train, &test, epochs, 904);
        rows.push((name.to_string(), err));
    }
    rows
}

pub fn render(table: usize, rows: &[ErrRow], setting: &str) -> String {
    let mut s = format!("Table {table} — {setting}\n");
    for (name, err) in rows {
        s.push_str(&format!("{name:<22} {:.2} %\n", err * 100.0));
    }
    s
}

/// The paper's qualitative claim for all three tables: Alternating beats
/// the other quantized baselines (FP may or may not be beaten).
pub fn check_alternating_best_quantized(rows: &[ErrRow]) -> Result<(), String> {
    let alt = rows
        .iter()
        .find(|(n, _)| n.starts_with("Alternating"))
        .ok_or("missing Alternating row")?
        .1;
    for (name, err) in rows {
        if name.starts_with("Alternating") || name.starts_with("Full") {
            continue;
        }
        if alt > *err + 1e-9 {
            return Err(format!("Alternating ({alt}) worse than {name} ({err})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_tiny_runs_and_orders() {
        // Tiny run: just verifies all four variants train and produce
        // error rates in (0, 1); the ordering claim needs the bench-scale
        // run (recorded in EXPERIMENTS.md).
        let rows = table8(400, 100, 64, 2);
        assert_eq!(rows.len(), 4);
        for (n, e) in &rows {
            assert!((0.0..=1.0).contains(e), "{n}: {e}");
        }
        let fp = rows[0].1;
        assert!(fp < 0.6, "fp error {fp} suspicious");
    }

    #[test]
    fn table7_tiny_runs() {
        let rows = table7(80, 40, 24, 1);
        assert_eq!(rows.len(), 3);
        for (_, e) in &rows {
            assert!((0.0..=1.0).contains(e));
        }
    }

    #[test]
    fn render_format() {
        let rows = vec![("Full Precision".to_string(), 0.011)];
        let s = render(7, &rows, "test");
        assert!(s.contains("1.10 %"));
    }
}
