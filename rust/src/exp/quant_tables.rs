//! Tables 1–2: approximation quality of the five quantization methods on a
//! trained model's weight matrices — relative MSE (left) and testing PPW of
//! the weight-quantized model (right; no activation quantization, no
//! retraining).

use crate::data::checkpoint::Checkpoint;
use crate::data::{Corpus, DatasetSpec};
use crate::model::lm::{LmConfig, LmWeights, PrecisionPolicy, RnnKind, RnnLm};
use crate::model::linear::Precision;
use crate::model::Linear;
use crate::quant::{Method, RowQuantized};
use crate::util::Rng;

/// Where the weights come from: a trained checkpoint if available (produced
/// by `amq train` / the train_lm example), else a deterministic surrogate
/// with trained-weight statistics (Laplace rows of varying scale — the
/// standard model for trained LM weights; documented in EXPERIMENTS.md).
pub fn load_or_surrogate_weights(
    ckpt_path: Option<&std::path::Path>,
    config: &LmConfig,
    seed: u64,
) -> (LmWeights, &'static str) {
    if let Some(p) = ckpt_path {
        if p.exists() {
            if let Ok(c) = Checkpoint::load(p) {
                if let Ok(w) = crate::train::trainer::weights_from_checkpoint(&c, config) {
                    return (w, "trained-checkpoint");
                }
            }
        }
    }
    let mut rng = Rng::new(seed);
    let g = config.kind.gates();
    let (v, h) = (config.vocab, config.hidden);
    // Trained-like statistics: per-row Laplace with row-dependent scale.
    let mat = |rows: usize, cols: usize, rng: &mut Rng| -> Vec<f32> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let scale = 0.02 + 0.1 * ((r * 2654435761) % 97) as f32 / 97.0;
            out.extend(rng.laplace_vec(cols, scale));
        }
        out
    };
    let w = LmWeights {
        embedding: mat(v, h, &mut rng),
        wx: vec![mat(g * h, h, &mut rng)],
        wh: vec![mat(g * h, h, &mut rng)],
        bias: vec![vec![0.0; g * h]],
        softmax_w: mat(v, h, &mut rng),
        softmax_b: vec![0.0; v],
    };
    (w, "laplace-surrogate")
}

/// One row of Table 1/2 for a given method: (rmse per k, ppw per k).
pub struct MethodRow {
    pub method: Method,
    pub rmse: Vec<f64>,
    pub ppw: Vec<f64>,
}

/// Run Table 1 (LSTM) or Table 2 (GRU).
///
/// `bits` is the paper's {2, 3, 4}; `eval_tokens` bounds the PPW pass.
pub fn table1_2(
    kind: RnnKind,
    corpus: &Corpus,
    config: &LmConfig,
    weights: &LmWeights,
    bits: &[usize],
    eval_tokens: usize,
) -> (Vec<MethodRow>, f64) {
    let g = kind.gates();
    let h = config.hidden;
    // The matrices the paper quantizes for the MSE measure: the recurrent
    // gate products (W_x, W_h concatenated row space).
    let measure: Vec<(&[f32], usize, usize)> = vec![
        (&weights.wx[0], g * h, h),
        (&weights.wh[0], g * h, h),
    ];
    let test = &corpus.test[..eval_tokens.min(corpus.test.len())];

    let fp_model = RnnLm::from_weights(*config, weights, PrecisionPolicy::full());
    let fp_ppw = fp_model.ppw(test);

    let mut rows = Vec::new();
    for method in Method::table_order() {
        let mut rmse = Vec::new();
        let mut ppw = Vec::new();
        for &k in bits {
            // Relative MSE over the gate matrices (sum of squared errors /
            // sum of squares, pooled).
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for &(w, r, c) in &measure {
                let q = RowQuantized::quantize(w, r, c, k, method);
                let d = q.dequantize();
                num += w
                    .iter()
                    .zip(&d)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
                den += w.iter().map(|&a| (a as f64).powi(2)).sum::<f64>();
            }
            rmse.push(num / den);
            // PPW with weight-only quantization (activations full precision).
            let model = quantized_weights_model(config, weights, k, method);
            ppw.push(model.ppw(test));
        }
        rows.push(MethodRow { method, rmse, ppw });
    }
    (rows, fp_ppw)
}

/// Build a model whose weight matrices are quantized by `method` but whose
/// activations stay full precision (the Table 1/2 protocol): quantize +
/// dequantize the weights, then run dense.
fn quantized_weights_model(config: &LmConfig, w: &LmWeights, k: usize, method: Method) -> RnnLm {
    let g = config.kind.gates();
    let h = config.hidden;
    let v = config.vocab;
    let deq = |w: &[f32], rows: usize, cols: usize| -> Vec<f32> {
        RowQuantized::quantize(w, rows, cols, k, method).dequantize()
    };
    let wq = LmWeights {
        embedding: deq(&w.embedding, v, h),
        wx: vec![deq(&w.wx[0], g * h, h)],
        wh: vec![deq(&w.wh[0], g * h, h)],
        bias: w.bias.clone(),
        softmax_w: deq(&w.softmax_w, v, h),
        softmax_b: w.softmax_b.clone(),
    };
    RnnLm::from_weights(*config, &wq, PrecisionPolicy::full())
}

/// Render rows in the paper's layout.
pub fn render(kind: RnnKind, rows: &[MethodRow], fp_ppw: f64, bits: &[usize], source: &str) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Table {} — {} on ptb-like (weights: {source})\n",
        if kind == RnnKind::Lstm { 1 } else { 2 },
        kind.name()
    ));
    s.push_str(&format!(
        "{:<14}{}|{}   FP\n",
        "",
        bits.iter().map(|k| format!(" rMSE k={k}  ")).collect::<String>(),
        bits.iter().map(|k| format!("  PPW k={k}  ")).collect::<String>()
    ));
    for row in rows {
        s.push_str(&format!("{:<14}", row.method.name()));
        for e in &row.rmse {
            s.push_str(&format!(" {e:>9.3}  "));
        }
        s.push('|');
        for p in &row.ppw {
            s.push_str(&format!(" {p:>9.1}  "));
        }
        s.push('\n');
    }
    s.push_str(&format!("{:<14}full-precision PPW = {fp_ppw:.1}\n", ""));
    s
}

/// Verify the paper's qualitative claims on the produced rows (used by the
/// integration test and the bench harness's self-check):
/// Alternating ≤ Refined ≤ Greedy on rMSE for every k, and rule-based
/// methods are far worse at k = 2.
pub fn check_shape(rows: &[MethodRow]) -> Result<(), String> {
    let find = |m: &str| rows.iter().find(|r| r.method.name() == m).unwrap();
    let (alt, refined, greedy) = (find("Alternating"), find("Refined"), find("Greedy"));
    let (uniform, balanced) = (find("Uniform"), find("Balanced"));
    for i in 0..alt.rmse.len() {
        if alt.rmse[i] > refined.rmse[i] + 1e-9 {
            return Err(format!("k index {i}: alternating rMSE above refined"));
        }
        if refined.rmse[i] > greedy.rmse[i] + 1e-6 {
            return Err(format!("k index {i}: refined rMSE above greedy"));
        }
    }
    if !(alt.rmse[0] < uniform.rmse[0] && alt.rmse[0] < balanced.rmse[0]) {
        return Err("alternating not beating rule-based at k=2".into());
    }
    Ok(())
}

/// Assemble the default ptb-like setup (scaled) and run both tables.
pub fn run_default(scale_div: usize, vocab_div: usize, eval_tokens: usize, ckpt_dir: &std::path::Path) -> String {
    let spec = DatasetSpec::ptb_like().scaled(scale_div, vocab_div);
    let corpus = Corpus::generate(spec.clone());
    let bits = [2usize, 3, 4];
    let mut out = String::new();
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        let config = LmConfig { kind, vocab: spec.vocab, hidden: 200, layers: 1 };
        let tag = if kind == RnnKind::Lstm { "lstm_fp" } else { "gru_fp" };
        let ckpt = ckpt_dir.join(format!("{tag}.amqt"));
        let (weights, source) = load_or_surrogate_weights(Some(&ckpt), &config, 7 + kind.gates() as u64);
        let (rows, fp) = table1_2(kind, &corpus, &config, &weights, &bits, eval_tokens);
        if let Err(e) = check_shape(&rows) {
            out.push_str(&format!("!! shape check failed: {e}\n"));
        }
        out.push_str(&render(kind, &rows, fp, &bits, source));
        out.push('\n');
    }
    out
}

/// Sanity helper used in tests: surrogate weights must make a functioning
/// model.
pub fn surrogate_model(kind: RnnKind) -> RnnLm {
    let config = LmConfig { kind, vocab: 300, hidden: 48, layers: 1 };
    let (w, _) = load_or_surrogate_weights(None, &config, 3);
    RnnLm::from_weights(config, &w, PrecisionPolicy::full())
}

/// A quantized linear layer built from surrogate softmax weights — exercises
/// the full packed path (used by table-level tests).
pub fn surrogate_quant_linear(k: usize) -> Linear {
    let mut rng = Rng::new(11);
    let w = rng.laplace_vec(64 * 128, 0.1);
    Linear::new(w, 64, 128, Precision::Quantized { k_w: k, k_a: k })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table12_small_has_paper_shape() {
        let spec = DatasetSpec::ptb_like().scaled(400, 40); // tiny: 2.3K tokens, 250 vocab
        let corpus = Corpus::generate(spec.clone());
        let config = LmConfig { kind: RnnKind::Lstm, vocab: spec.vocab, hidden: 64, layers: 1 };
        let (w, src) = load_or_surrogate_weights(None, &config, 5);
        assert_eq!(src, "laplace-surrogate");
        let (rows, fp) = table1_2(RnnKind::Lstm, &corpus, &config, &w, &[2, 3], 400);
        check_shape(&rows).unwrap();
        assert!(fp.is_finite() && fp > 1.0);
        // PPW of alternating should be the closest to FP among all methods
        // at k=3 (paper: 93.8 vs 89.8 FP while balanced is ~9000).
        let alt = rows.iter().find(|r| r.method.name() == "Alternating").unwrap();
        let bal = rows.iter().find(|r| r.method.name() == "Balanced").unwrap();
        assert!(alt.ppw[1] < bal.ppw[1], "alt {} vs balanced {}", alt.ppw[1], bal.ppw[1]);
    }

    #[test]
    fn render_contains_all_methods() {
        let spec = DatasetSpec::ptb_like().scaled(400, 40);
        let corpus = Corpus::generate(spec.clone());
        let config = LmConfig { kind: RnnKind::Gru, vocab: spec.vocab, hidden: 32, layers: 1 };
        let (w, _) = load_or_surrogate_weights(None, &config, 6);
        let (rows, fp) = table1_2(RnnKind::Gru, &corpus, &config, &w, &[2], 200);
        let text = render(RnnKind::Gru, &rows, fp, &[2], "test");
        for m in ["Uniform", "Balanced", "Greedy", "Refined", "Alternating"] {
            assert!(text.contains(m), "{text}");
        }
    }
}
