//! Execution engine: a std-only persistent worker pool that spreads the
//! serving hot path across CPU cores.
//!
//! The paper's ~6× CPU acceleration at 2 bits (§1, Table 6) is a
//! *single-core* kernel number; the serving claim — "large scale concurrent
//! requests" per machine — additionally needs the machine's other cores.
//! This module supplies the substrate:
//!
//! * [`ThreadPool`] — persistent `std::thread` workers around one shared
//!   job queue, with **help-while-waiting** fork/join (`scope`), so nested
//!   parallel sections never deadlock and no core idles while a scope
//!   waits.
//! * [`Exec`] — a cheap cloneable handle threaded through the kernels,
//!   quantizers, cells and the batcher. `threads = 1` carries no pool at
//!   all and is byte-for-byte today's serial path.
//! * [`ExecConfig`] — the `threads` knob (`0` = auto: `AMQ_THREADS` env or
//!   `available_parallelism`), carried by `server::BatcherConfig` and the
//!   `--threads` CLI flag.
//!
//! **Exactness contract:** parallelism only ever *shards* work along
//! boundaries that the serial path already treats independently — output
//! rows of a GEMM, rows of a matrix quantization, columns of a batch. Each
//! output element is produced by the identical scalar reduction as the
//! serial path, so results are **bit-exact for every thread count** (pinned
//! by `rust/tests/exec_parity.rs`). Sharding never changes what a client
//! sees; it only changes how many cores produce it.

mod pool;

pub use pool::ThreadPool;

use std::sync::Arc;

/// How many threads the engine may use.
///
/// `threads = 0` means "auto": the `AMQ_THREADS` environment variable if
/// set, else `std::thread::available_parallelism()`. `threads = 1`
/// degenerates to the exact serial path (no pool, no worker threads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    pub threads: usize,
}

impl ExecConfig {
    /// The serial engine: one thread, no pool.
    pub const fn serial() -> Self {
        ExecConfig { threads: 1 }
    }

    /// Resolve thread count at startup (env / hardware).
    pub const fn auto() -> Self {
        ExecConfig { threads: 0 }
    }

    /// An explicit thread count (`0` = auto).
    pub const fn with_threads(threads: usize) -> Self {
        ExecConfig { threads }
    }

    /// The concrete thread count this config resolves to.
    pub fn resolve(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        if let Ok(v) = std::env::var("AMQ_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig::auto()
    }
}

/// A cloneable handle to the execution engine: the serial path, or a shared
/// persistent [`ThreadPool`]. Clones share the same pool.
#[derive(Clone)]
pub struct Exec {
    pool: Option<Arc<ThreadPool>>,
}

impl Exec {
    /// Build an engine from the config (`resolve() <= 1` ⇒ serial, no
    /// worker threads are spawned).
    pub fn new(config: ExecConfig) -> Self {
        let threads = config.resolve();
        if threads <= 1 {
            Exec { pool: None }
        } else {
            Exec { pool: Some(Arc::new(ThreadPool::new(threads))) }
        }
    }

    /// The serial engine (today's single-thread path, bit for bit).
    pub fn serial() -> Self {
        Exec { pool: None }
    }

    /// Total concurrency (1 for the serial engine).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Whether a worker pool is attached.
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// Shard `0..n` into at most `threads()` contiguous chunks (sizes
    /// differ by ≤ 1) and run `body(lo, hi)` for each. `min_chunk` bounds
    /// the *number of tasks* (≤ `⌈n / min_chunk⌉`), not a per-chunk
    /// minimum — remainder chunks may be smaller. Chunks are disjoint and
    /// cover `0..n` exactly; the serial engine makes the single call
    /// `body(0, n)`. Oversubscription (`threads > n`) degenerates to `n`
    /// single-item chunks.
    ///
    /// `body` runs concurrently on different ranges — it must only write
    /// state that is disjoint per chunk (see [`SendPtr`]).
    pub fn run_chunks(&self, n: usize, min_chunk: usize, body: &(dyn Fn(usize, usize) + Sync)) {
        self.run_chunks_indexed(n, min_chunk, &|_, lo, hi| body(lo, hi));
    }

    /// [`Self::run_chunks`] with the chunk's task index passed as the first
    /// argument (`body(task, lo, hi)`, `task < threads()`). The index lets
    /// each task claim a disjoint slot of caller-owned scratch (e.g. one
    /// [`crate::quant::QuantScratch`] per worker) without any locking — the
    /// partitioning is identical to [`Self::run_chunks`].
    pub fn run_chunks_indexed(
        &self,
        n: usize,
        min_chunk: usize,
        body: &(dyn Fn(usize, usize, usize) + Sync),
    ) {
        if n == 0 {
            return;
        }
        let Some(pool) = self.pool.as_deref() else {
            body(0, 0, n);
            return;
        };
        let tasks = pool.threads().min(n.div_ceil(min_chunk.max(1)));
        if tasks <= 1 {
            body(0, 0, n);
            return;
        }
        let base = n / tasks;
        let rem = n % tasks;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(tasks);
        let mut lo = 0;
        for i in 0..tasks {
            let hi = lo + base + usize::from(i < rem);
            jobs.push(Box::new(move || body(i, lo, hi)));
            lo = hi;
        }
        pool.scope(jobs);
    }

    /// Run two independent closures — in parallel when a pool is attached,
    /// sequentially (`a` then `b`) on the serial engine. The closures may
    /// themselves use this engine (nested scopes are deadlock-free).
    pub fn join<'a>(&self, a: impl FnOnce() + Send + 'a, b: impl FnOnce() + Send + 'a) {
        match self.pool.as_deref() {
            None => {
                a();
                b();
            }
            Some(pool) => pool.scope(vec![Box::new(a), Box::new(b)]),
        }
    }
}

impl std::fmt::Debug for Exec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Exec({} threads)", self.threads())
    }
}

/// A raw mutable pointer into an output buffer that workers write at
/// **disjoint** indices (e.g. disjoint output-row ranges of a row-sharded
/// GEMM). Exists because handing each worker a `&mut` to the same slice
/// would alias; raw-pointer writes at provably disjoint indices are sound.
pub struct SendPtr<T>(*mut T);

// SAFETY: the pointer itself is just an address; the sharding callers
// guarantee disjoint index ranges per task and that the buffer outlives the
// scope (it borrows from the caller's stack, and `scope` blocks).
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(slice: &mut [T]) -> Self {
        SendPtr(slice.as_mut_ptr())
    }

    /// Write `val` at index `idx`.
    ///
    /// # Safety
    /// `idx` must be in bounds of the original slice, and no other task may
    /// read or write `idx` concurrently (tasks must own disjoint index
    /// sets).
    #[inline]
    pub unsafe fn write(&self, idx: usize, val: T) {
        *self.0.add(idx) = val;
    }

    /// Reborrow the disjoint sub-range `start..start + len` as a mutable
    /// slice.
    ///
    /// # Safety
    /// The range must be in bounds of the original slice and no other task
    /// may touch any index in it while the returned borrow lives.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn serial_engine_runs_inline() {
        let exec = Exec::serial();
        assert_eq!(exec.threads(), 1);
        assert!(!exec.is_parallel());
        let calls = Mutex::new(Vec::new());
        exec.run_chunks(10, 1, &|lo, hi| calls.lock().unwrap().push((lo, hi)));
        assert_eq!(*calls.lock().unwrap(), vec![(0, 10)]);
    }

    #[test]
    fn chunks_partition_exactly() {
        for threads in [2usize, 3, 8] {
            let exec = Exec::new(ExecConfig::with_threads(threads));
            for n in [1usize, 2, 7, 64, 65, 130] {
                let calls = Mutex::new(Vec::new());
                exec.run_chunks(n, 1, &|lo, hi| calls.lock().unwrap().push((lo, hi)));
                let mut got = calls.into_inner().unwrap();
                got.sort_unstable();
                // Disjoint, contiguous, covering 0..n.
                let mut expect_lo = 0;
                for &(lo, hi) in &got {
                    assert_eq!(lo, expect_lo, "threads={threads} n={n} {got:?}");
                    assert!(hi > lo, "empty chunk: threads={threads} n={n} {got:?}");
                    expect_lo = hi;
                }
                assert_eq!(expect_lo, n, "threads={threads} n={n} {got:?}");
                assert!(got.len() <= threads.min(n));
            }
        }
    }

    #[test]
    fn indexed_chunks_match_plain_chunks_with_distinct_indices() {
        for threads in [1usize, 3, 8] {
            let exec = Exec::new(ExecConfig::with_threads(threads));
            for n in [1usize, 7, 65] {
                let plain = Mutex::new(Vec::new());
                exec.run_chunks(n, 1, &|lo, hi| plain.lock().unwrap().push((lo, hi)));
                let indexed = Mutex::new(Vec::new());
                exec.run_chunks_indexed(n, 1, &|i, lo, hi| {
                    indexed.lock().unwrap().push((i, lo, hi))
                });
                let mut plain = plain.into_inner().unwrap();
                let mut indexed = indexed.into_inner().unwrap();
                plain.sort_unstable();
                indexed.sort_unstable_by_key(|&(_, lo, _)| lo);
                // Same partition, indices distinct and < threads.
                assert_eq!(plain.len(), indexed.len(), "threads={threads} n={n}");
                let mut seen = std::collections::HashSet::new();
                for (&(lo, hi), &(i, ilo, ihi)) in plain.iter().zip(&indexed) {
                    assert_eq!((lo, hi), (ilo, ihi), "threads={threads} n={n}");
                    assert!(i < threads, "threads={threads} n={n} i={i}");
                    assert!(seen.insert(i), "duplicate task index {i}");
                }
            }
        }
    }

    #[test]
    fn min_chunk_bounds_task_count() {
        let exec = Exec::new(ExecConfig::with_threads(8));
        let calls = Mutex::new(Vec::new());
        exec.run_chunks(10, 5, &|lo, hi| calls.lock().unwrap().push((lo, hi)));
        assert!(calls.into_inner().unwrap().len() <= 2, "10 items / min 5 per chunk");
    }

    #[test]
    fn join_runs_both_sides() {
        for exec in [Exec::serial(), Exec::new(ExecConfig::with_threads(2))] {
            let a = AtomicUsize::new(0);
            let b = AtomicUsize::new(0);
            exec.join(
                || {
                    a.store(7, Ordering::Relaxed);
                },
                || {
                    b.store(9, Ordering::Relaxed);
                },
            );
            assert_eq!((a.load(Ordering::Relaxed), b.load(Ordering::Relaxed)), (7, 9));
        }
    }

    #[test]
    fn config_resolution() {
        assert_eq!(ExecConfig::serial().resolve(), 1);
        assert_eq!(ExecConfig::with_threads(5).resolve(), 5);
        assert!(ExecConfig::auto().resolve() >= 1);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let exec = Exec::new(ExecConfig::with_threads(4));
        let n = 257;
        let mut out = vec![0usize; n];
        let ptr = SendPtr::new(&mut out);
        let ptr = &ptr;
        exec.run_chunks(n, 1, &|lo, hi| {
            for i in lo..hi {
                // SAFETY: chunks are disjoint and in bounds.
                unsafe { ptr.write(i, i * 3) };
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }
}
