//! A persistent fork–join worker pool on `std::thread` + channels-free
//! shared queue (no external deps).
//!
//! Design:
//!
//! * Workers block on a `Condvar` over one shared FIFO of jobs.
//! * [`ThreadPool::scope`] submits a batch of borrowed closures and then
//!   **helps**: while its batch is unfinished, the submitting thread pops
//!   and runs queued jobs itself. Help-while-waiting makes nested scopes (a
//!   pooled task that itself calls `scope`, e.g. a cell's gate GEMM that
//!   row-shards) deadlock-free — a blocked waiter drains the queue instead
//!   of holding an execution slot hostage.
//! * Completion is tracked by a per-batch atomic counter; the last task of
//!   a batch notifies the shared condvar (one condvar serves both "new
//!   job" and "batch done" — waiters re-check their predicate).
//! * Dropping the pool sets the shutdown flag and joins every worker.
//!   Scopes borrow the pool and block until their tasks finish, so the
//!   queue is always empty by the time `Drop` can run and shutdown is
//!   prompt — no leaked threads, no deadlock on drop.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A queued unit of work (lifetime erased; see [`ThreadPool::scope`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

/// Completion state of one `scope` call.
struct Batch {
    remaining: AtomicUsize,
    /// First panic payload of the batch — re-raised by the scope owner via
    /// `resume_unwind`, so a pooled panic looks like a serial one.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// The persistent worker pool behind [`super::Exec`].
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Spawn a pool whose total concurrency is `threads`: `threads − 1` OS
    /// workers plus the scope-calling thread itself (which helps while it
    /// waits). `threads` must be ≥ 2 — a 1-thread "pool" is the serial path
    /// and needs no pool at all (see [`super::Exec`]).
    pub fn new(threads: usize) -> Self {
        assert!(threads >= 2, "a 1-thread pool is the serial path");
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("amq-exec-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn exec worker")
            })
            .collect();
        ThreadPool { shared, handles, threads }
    }

    /// Total concurrency: OS workers + the helping scope caller.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, in parallel, with the caller helping.
    ///
    /// Tasks may borrow from the caller's stack: `scope` does not return
    /// until every task has finished running, so the erased lifetimes can
    /// never dangle. A panic inside a task is caught (keeping the worker
    /// alive) and re-raised here after the whole batch has completed.
    pub fn scope<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if tasks.is_empty() {
            return;
        }
        let batch = Arc::new(Batch {
            remaining: AtomicUsize::new(tasks.len()),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks {
                // SAFETY: `scope` blocks until `remaining` hits zero, i.e.
                // until this closure has run to completion, so every borrow
                // inside it outlives its use. Tasks are never dropped
                // unexecuted: shutdown only happens on pool drop, which
                // cannot run while a scope borrows the pool.
                let task: Job =
                    unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(task) };
                let batch = batch.clone();
                let shared = self.shared.clone();
                q.jobs.push_back(Box::new(move || {
                    if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(task)) {
                        let mut slot = batch.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    if batch.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        // Last task of the batch: take the lock so the scope
                        // owner cannot miss the wakeup between its predicate
                        // check and its wait.
                        drop(shared.queue.lock().unwrap());
                        shared.cv.notify_all();
                    }
                }));
            }
            self.shared.cv.notify_all();
        }
        // Help while waiting: run whatever is queued (this batch or a
        // nested one) instead of blocking an execution slot.
        let mut q = self.shared.queue.lock().unwrap();
        while batch.remaining.load(Ordering::Acquire) != 0 {
            if let Some(job) = q.jobs.pop_front() {
                drop(q);
                job();
                q = self.shared.queue.lock().unwrap();
            } else {
                q = self.shared.cv.wait(q).unwrap();
            }
        }
        drop(q);
        if let Some(payload) = batch.panic.lock().unwrap().take() {
            panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job<'a>(f: impl FnOnce() + Send + 'a) -> Box<dyn FnOnce() + Send + 'a> {
        Box::new(f)
    }

    #[test]
    fn scope_runs_all_tasks() {
        let pool = ThreadPool::new(3);
        let hits = AtomicUsize::new(0);
        let tasks = (0..10)
            .map(|_| {
                job(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn tasks_can_borrow_and_write_disjoint_state() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        let tasks = out
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                job(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = i * 16 + j;
                    }
                })
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // Every task spawns its own sub-scope on the same (tiny) pool; the
        // help-while-waiting loop must keep everything flowing.
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        let tasks = (0..4)
            .map(|_| {
                let (pool, hits) = (&pool, &hits);
                job(move || {
                    let subtasks = (0..3)
                        .map(|_| {
                            job(|| {
                                hits.fetch_add(1, Ordering::Relaxed);
                            })
                        })
                        .collect();
                    pool.scope(subtasks);
                })
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = ThreadPool::new(4);
        pool.scope(vec![job(|| {})]);
        drop(pool); // must return promptly with no worker left behind
    }

    #[test]
    fn task_panic_propagates_after_batch_completes() {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks = vec![
                job(|| panic!("boom")),
                job(|| {
                    done.fetch_add(1, Ordering::Relaxed);
                }),
            ];
            pool.scope(tasks);
        }));
        assert!(result.is_err(), "panic must propagate to the scope owner");
        assert_eq!(done.load(Ordering::Relaxed), 1, "other tasks still ran");
        // The pool stays usable after a task panic.
        let hits = AtomicUsize::new(0);
        pool.scope(vec![job(|| {
            hits.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
